"""Pure-jnp reference implementation of microscaling quantization.

This module is the single source of truth for the quantization numerics of
the whole repository:

  * the L1 Pallas kernel (`microscale.py`) is asserted bit-identical to it
    by pytest + hypothesis (`python/tests/test_kernel.py`);
  * the L2 model (`model.py`) calls these functions directly so that the
    lowered HLO artifacts embed exactly these semantics;
  * the Rust quantizer (`rust/src/quant/`) is asserted bit-identical to it
    via golden vectors emitted by `aot.py` (`rust/tests/golden.rs`).

Everything is float32, deterministic, and implemented with exact
power-of-two arithmetic (bitcast exponent extraction + round-half-even on
an exact power-of-two-scaled value), so the Rust port can match it
bit-for-bit.

Formats are described by `MiniFloat(m_bits, e_min, max_val)`:

  * the representable non-negative values are 0 and
    ``r * 2**(e - m_bits)`` for integers r in [2**m_bits, 2**(m_bits+1))
    and exponents e >= e_min (normals), plus the subnormal grid
    ``r * 2**(e_min - m_bits)`` for r in [0, 2**m_bits);
  * rounding is round-to-nearest-even on that grid;
  * values above `max_val` saturate to `max_val` (hardware cast behaviour).

The concrete formats of the paper (Sec. 2.1, 5.2, App. H/J):

  ===========  ======  =====  ========  ==========================
  format       m_bits  e_min  max_val   min subnormal (paper)
  ===========  ======  =====  ========  ==========================
  FP4  E2M1    1       0      6.0       0.5
  UE4M3        3       -6     448.0     2**-9    (Sec. 2.1)
  UE5M3        3       -14    122880.0  2**-17   (Sec. 5.2, ours)
  UE4M4        4       -6     496.0     2**-10   (App. J)
  UE5M1 (FP6)  1       -14    98304.0   2**-15   (App. H)
  UE4M2 (FP6)  2       -6     448.0     2**-8    (App. H)
  E8M0  (PoT)  0       -127   2**127    --       (OCP MX)
  BF16-ish     7       -126   ~3.39e38  "non-quantized" scales
  ===========  ======  =====  ========  ==========================
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MiniFloat:
    """A saturating, unsigned-magnitude minifloat grid (see module doc)."""

    m_bits: int
    e_min: int
    max_val: float
    name: str = ""

    def as_tuple(self) -> Tuple[int, int, float]:
        return (self.m_bits, self.e_min, self.max_val)


# -- the paper's format registry ------------------------------------------

FP4_E2M1 = MiniFloat(1, 0, 6.0, "fp4_e2m1")
FP6_E2M3 = MiniFloat(3, 0, 7.5, "fp6_e2m3")      # OCP MXFP6 element format
FP6_E3M2 = MiniFloat(2, -2, 28.0, "fp6_e3m2")    # OCP MXFP6 element format
FP8_E4M3 = MiniFloat(3, -6, 448.0, "fp8_e4m3")   # OCP MXFP8 element format
UE4M3 = MiniFloat(3, -6, 448.0, "ue4m3")
UE5M3 = MiniFloat(3, -14, 122880.0, "ue5m3")
UE4M4 = MiniFloat(4, -6, 496.0, "ue4m4")
UE5M1 = MiniFloat(1, -14, 98304.0, "ue5m1")
UE4M2 = MiniFloat(2, -6, 448.0, "ue4m2")
# OCP E8M0 spans 2**-127..2**128; we clamp to the normal-f32 range
# [2**-126, 2**127] because the fake-quant pipeline carries values in f32
# (and XLA CPU flushes f32 subnormals to zero anyway).
E8M0 = MiniFloat(0, -126, 2.0**127, "e8m0")
BF16_SCALE = MiniFloat(7, -126, 3.3895313892515355e38, "bf16")

SCALE_FORMATS = {
    f.name: f for f in (UE4M3, UE5M3, UE4M4, UE5M1, UE4M2, E8M0, BF16_SCALE)
}
ELEM_FORMATS = {f.name: f for f in (FP4_E2M1, FP6_E2M3, FP6_E3M2, FP8_E4M3)}

# INT4 elements quantize to integers in [-7, 7] (App. G).
INT4_MAX = 7.0


def _pow2(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2**e for integer e in [-126, 127], constructed by bitcast.

    jnp.exp2 is an *approximation* on the XLA CPU backend (observed
    |rel err| ~ 5e-10), which would corrupt the bit-exact grid; building
    the IEEE754 representation directly is exact.
    """
    bits = ((e + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _ldexp2(x: jnp.ndarray, e) -> jnp.ndarray:
    """Exact x * 2**e for integer e with |e| <= 252 (two-step bitcast pow2).

    Single-step multiply overflows to inf for e > 127 even when the product
    is representable; splitting keeps every factor finite and exact.
    Mirrored by `util::ldexp2` on the Rust side.
    """
    e = jnp.asarray(e, jnp.int32)
    e1 = jnp.clip(e, -126, 126)
    e2 = e - e1
    return x * _pow2(e1) * _pow2(e2)


def _floor_log2(x: jnp.ndarray) -> jnp.ndarray:
    """floor(log2(x)) for x > 0 via exponent-field extraction (exact).

    f32 subnormal inputs report -127 which is always <= any e_min we use,
    so they land on the target subnormal grid as intended.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return ((bits >> 23) & 0xFF).astype(jnp.int32) - 127


def cast_minifloat(x: jnp.ndarray, m_bits, e_min, max_val) -> jnp.ndarray:
    """Round non-negative f32 `x` to the MiniFloat(m_bits, e_min, max_val) grid.

    Round-to-nearest-even, saturating at max_val. Accepts traced scalars for
    the format parameters so one lowered HLO serves every scale format
    (DESIGN.md L2 notes).
    """
    x = x.astype(jnp.float32)
    m_bits = jnp.asarray(m_bits, jnp.int32)
    e_min = jnp.asarray(e_min, jnp.int32)
    max_val = jnp.asarray(max_val, jnp.float32)

    xc = jnp.minimum(x, max_val)
    # DAZ: XLA CPU flushes f32 subnormals; make that part of the contract
    # so the Rust port (which is strict-IEEE) matches bit-for-bit.
    xc = jnp.where(xc >= jnp.float32(1.1754944e-38), xc, 0.0)
    g = _floor_log2(jnp.where(xc > 0, xc, 1.0))
    p = jnp.maximum(g, e_min) - m_bits  # grid exponent: quantum = 2**p
    y = _ldexp2(xc, -p)
    r = jnp.round(y)  # jnp.round is round-half-even
    out = _ldexp2(r, p)
    return jnp.where(xc > 0, out, 0.0).astype(jnp.float32)


def cast_signed_minifloat(x, m_bits, e_min, max_val):
    """Signed-magnitude minifloat cast (used for FP4/FP6 elements)."""
    return jnp.sign(x) * cast_minifloat(jnp.abs(x), m_bits, e_min, max_val)


def cast_int_symmetric(x: jnp.ndarray, int_max) -> jnp.ndarray:
    """INT-k element cast: round-half-even then clamp to [-int_max, int_max]."""
    int_max = jnp.asarray(int_max, jnp.float32)
    return jnp.clip(jnp.round(x.astype(jnp.float32)), -int_max, int_max)


# -- block microscaling ------------------------------------------------------


def block_scales(x_blocks, elem_max, scale_m, scale_emin, scale_max):
    """Per-block quantized scales s = Q_scale(absmax(block) / elem_max).

    `x_blocks` has blocks on the last axis; returns one scale per block
    (last axis reduced). Sec. 2.1 of the paper.
    """
    absmax = jnp.max(jnp.abs(x_blocks), axis=-1)
    raw = absmax / jnp.asarray(elem_max, jnp.float32)
    return cast_minifloat(raw, scale_m, scale_emin, scale_max)


def fake_quant_blocks(
    x_blocks,
    elem_is_int,
    elem_m,
    elem_emin,
    elem_max,
    scale_m,
    scale_emin,
    scale_max,
):
    """Quantize-dequantize blocks (last axis = block of size N).

    Implements Sec. 2.1: s = Q_scale(absmax / elem_max), q = Q_elem(x / s),
    xhat = s * q, with the s == 0 edge case (whole block rounds to zero,
    App. F.3) handled explicitly.
    """
    s = block_scales(x_blocks, elem_max, scale_m, scale_emin, scale_max)
    s_b = s[..., None]
    y = jnp.where(s_b > 0, x_blocks / jnp.where(s_b > 0, s_b, 1.0), 0.0)
    q_fp = cast_signed_minifloat(y, elem_m, elem_emin, elem_max)
    q_int = cast_int_symmetric(y, elem_max)
    q = jnp.where(jnp.asarray(elem_is_int, jnp.bool_), q_int, q_fp)
    return (s_b * q).astype(jnp.float32)


def fake_quant(
    x: jnp.ndarray,
    block_size: int,
    elem_is_int,
    elem_m,
    elem_emin,
    elem_max,
    scale_m,
    scale_emin,
    scale_max,
    per_tensor=False,
    scale_fmt_max=448.0,
) -> jnp.ndarray:
    """Microscaling fake-quant of `x` with blocks along the last axis.

    `per_tensor` enables the UE4M3-S global pre-scaling of eq. 11:
    s_T = (elem_max * scale_fmt_max) / absmax(x); the tensor is multiplied
    by s_T before block quantization and divided back after.
    """
    shape = x.shape
    assert shape[-1] % block_size == 0, (shape, block_size)
    per_tensor = jnp.asarray(per_tensor, jnp.bool_)
    absmax = jnp.max(jnp.abs(x))
    s_t_raw = (
        jnp.asarray(elem_max, jnp.float32)
        * jnp.asarray(scale_fmt_max, jnp.float32)
        / jnp.where(absmax > 0, absmax, 1.0)
    )
    s_t = jnp.where(per_tensor & (absmax > 0), s_t_raw, 1.0)
    xb = (x * s_t).reshape(shape[:-1] + (shape[-1] // block_size, block_size))
    xq = fake_quant_blocks(
        xb, elem_is_int, elem_m, elem_emin, elem_max,
        scale_m, scale_emin, scale_max,
    )
    return (xq.reshape(shape) / s_t).astype(jnp.float32)


def ue5m3_edge_blocks(block_size: int = 8, elem_max: float = 6.0) -> list:
    """Crafted corner-case blocks for the UE5M3 scale grid (golden edges).

    One motif per corner the paper's proposed format lives or dies on:
    amax = 0 blocks, absmax at/below the s_min/2 collapse tie, subnormal
    scales, the scale-overflow clamp with element saturation, and live
    blocks containing values that quantize to signed zeros. Returned as
    a flat list whose length is ``8 * block_size`` (eight blocks).

    The boundary motifs are built as exact power-of-two multiples of
    ``elem_max`` — the element format's ``C`` in ``s = Q(absmax / C)`` —
    so ties and clamp points are hit bit-exactly *for that format*; pass
    the matching ``elem_max`` (6.0 for FP4, 448.0 for FP8 E4M3). The
    interior motifs deliberately use non-dyadic values (0.99, 0.55, …)
    to exercise ordinary rounding alongside the boundaries.

    `aot.py --golden-only` emits these under ``tag: "ue5m3_edge"`` and
    `rust/tests/golden.rs` pins the Rust quantizer, the packed-tensor
    codec, and the GEMM operand encoder to them.
    """
    C = float(elem_max)
    smax = 122880.0  # UE5M3 max_val
    motifs = [
        # amax = 0: scale quantizes to 0, block stays zero
        [0.0] * 8,
        # absmax/C just below s_min/2: whole block collapses (App. F.3)
        [C * 2.0 ** -18 * 0.99 * (1 if i % 2 == 0 else -1)
         for i in range(8)],
        # absmax/C exactly s_min/2: round-half-even tie -> 0
        [C * 2.0 ** -18] * 8,
        # absmax/C = 1.5 * s_min: subnormal scale, live block whose tiny
        # members quantize to signed zeros
        [C * 1.5 * 2.0 ** -17, -C * 1.5 * 2.0 ** -17,
         1e-9, -1e-9, C * 1.5 * 2.0 ** -17, -1e-9, 1e-9, 0.0],
        # mid subnormal-scale region (the paper's granite territory)
        [C * 2.0 ** -15 * v
         for v in (1.0, -0.6, 0.3, -0.05, 1.0, -0.6, 0.3, -0.05)],
        # scale overflow: absmax/C far above max_val -> scale clamps to
        # 122880 and the elements saturate at the element-format max
        [C * smax * 4.0, -C * smax * 4.0,
         C * smax * 2.8, -C * smax * 2.8,
         C * smax * 4.0 * 1e-8, -C * smax * 4.0 * 1e-8,
         0.0, 1e-3],
        # absmax/C exactly at the scale max: boundary, no clamp
        [C * smax, -C * smax, C * smax * 0.5,
         -C * smax * 0.25, C * smax, 0.0, 1.0, -1.0],
        # narrow-σ regime (granite-like), non-trivial mantissas
        [2.0 ** -13 * v
         for v in (0.9, -0.8, 0.55, -0.33, 0.21, -0.13, 0.08, -0.05)],
    ]
    reps = -(-block_size // 8)  # ceil
    out: list = []
    for m in motifs:
        out.extend((m * reps)[:block_size])
    return out


def quantized_matmul(x, w, block_size: int, qcfg: dict):
    """matmul(FQ(x), FQ(w)) with microscaling blocks along the contraction dim.

    `x`: (..., K); `w`: (K, F). Weights are blocked along K per output
    column (transposed view), as hardware microscaling GEMMs do.
    """
    xq = fake_quant(x, block_size, **qcfg)
    wq = fake_quant(w.T, block_size, **qcfg).T
    return xq @ wq


def default_qcfg(
    elem: str = "fp4_e2m1",
    scale: str = "ue4m3",
    per_tensor: bool = False,
) -> dict:
    """Build a concrete (python-scalar) qcfg dict from format names."""
    if elem == "int4":
        e = dict(elem_is_int=True, elem_m=0, elem_emin=0, elem_max=INT4_MAX)
    else:
        f = ELEM_FORMATS[elem]
        e = dict(
            elem_is_int=False, elem_m=f.m_bits, elem_emin=f.e_min,
            elem_max=f.max_val,
        )
    s = SCALE_FORMATS[scale]
    return dict(
        **e,
        scale_m=s.m_bits,
        scale_emin=s.e_min,
        scale_max=s.max_val,
        per_tensor=per_tensor,
        scale_fmt_max=s.max_val,
    )
