"""L1 — Pallas kernels for the microscaling hot-spot.

Two kernels:

  * ``fake_quant_pallas`` — tiled block fake-quantize (quantize-dequantize)
    of a 2-D tensor with microscaling blocks along the last axis;
  * ``quantized_matmul_pallas`` — fused "quantize both operands in VMEM,
    then matmul" kernel, the paper's quantized-GEMM datapath.

Hardware adaptation (DESIGN.md §2): the paper's formats target CUDA-style
microscaling tensor-core units. Here the same insight is expressed for a
TPU-like memory hierarchy: each grid step stages a (TILE_M, K) activation
strip and a (K, TILE_N) weight strip in VMEM via BlockSpec (the HBM→VMEM
schedule CUDA expresses with threadblocks), extracts per-block scales in
registers/VMEM scratch without ever round-tripping them to HBM, and feeds
the MXU-style ``jnp.dot`` with the dequantized tiles.

Kernels are lowered with ``interpret=True`` only: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Format parameters
are *static* per instantiation (they specialize the kernel, exactly like a
hardware format select), while `model.py` uses the identical `ref.py` math
with *runtime* format scalars; pytest asserts kernel == ref bit-for-bit.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fq_block_body(x, qcfg: dict):
    """Fake-quant an array whose last axis is the block axis (static qcfg)."""
    return ref.fake_quant_blocks(
        x,
        qcfg["elem_is_int"], qcfg["elem_m"], qcfg["elem_emin"],
        qcfg["elem_max"], qcfg["scale_m"], qcfg["scale_emin"],
        qcfg["scale_max"],
    )


def _fake_quant_kernel(x_ref, o_ref, *, block_size: int, qcfg: dict):
    """Kernel body: VMEM tile (TILE_M, K) -> blocks -> fake-quant -> out."""
    x = x_ref[...]
    tm, k = x.shape
    xb = x.reshape(tm, k // block_size, block_size)
    o_ref[...] = _fq_block_body(xb, qcfg).reshape(tm, k)


def fake_quant_pallas(
    x: jnp.ndarray,
    block_size: int,
    qcfg: dict,
    tile_m: int = 64,
) -> jnp.ndarray:
    """Tiled microscaling fake-quant of a 2-D (M, K) tensor.

    Grid over row-tiles; each step owns a (tile_m, K) VMEM strip. K must be
    a multiple of block_size; M a multiple of tile_m (callers pad).
    """
    m, k = x.shape
    assert k % block_size == 0 and m % tile_m == 0, (x.shape, block_size, tile_m)
    kern = functools.partial(
        _fake_quant_kernel, block_size=block_size, qcfg=qcfg
    )
    return pl.pallas_call(
        kern,
        grid=(m // tile_m,),
        in_specs=[pl.BlockSpec((tile_m, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_m, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


def _qmatmul_kernel(x_ref, w_ref, o_ref, *, block_size: int, qcfg: dict):
    """Fused kernel body: quantize x-tile and w-tile in VMEM, then dot.

    x tile: (TILE_M, K) with blocks along K.
    w tile: (K, TILE_N); microscaling blocks run along the contraction dim,
    so the weight strip is quantized on its transposed view, matching the
    per-output-column block layout of hardware microscaling GEMMs.
    """
    x = x_ref[...]
    w = w_ref[...]
    tm, k = x.shape
    _, tn = w.shape
    xq = _fq_block_body(
        x.reshape(tm, k // block_size, block_size), qcfg
    ).reshape(tm, k)
    wq = _fq_block_body(
        w.T.reshape(tn, k // block_size, block_size), qcfg
    ).reshape(tn, k).T
    o_ref[...] = jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def quantized_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    block_size: int,
    qcfg: dict,
    tile_m: int = 64,
    tile_n: int = 64,
) -> jnp.ndarray:
    """Fused microscaling GEMM: matmul(FQ(x), FQ(w)) for (M,K) @ (K,N).

    The grid is (M/tile_m, N/tile_n); each step stages a (tile_m, K)
    activation strip and a (K, tile_n) weight strip in VMEM, quantizes both
    in-register, and emits one output tile. The whole-K strip keeps scale
    extraction local to a single grid step (no partial-block seams and no
    scale traffic to HBM); see DESIGN.md §Perf for the VMEM budget.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and k % block_size == 0, (x.shape, w.shape, block_size)
    assert m % tile_m == 0 and n % tile_n == 0, (x.shape, w.shape)
    kern = functools.partial(_qmatmul_kernel, block_size=block_size, qcfg=qcfg)
    return pl.pallas_call(
        kern,
        grid=(m // tile_m, n // tile_n),
        in_specs=[
            pl.BlockSpec((tile_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def vmem_footprint_bytes(
    tile_m: int, tile_n: int, k: int, block_size: int
) -> Tuple[int, dict]:
    """Estimated VMEM bytes per grid step of the fused GEMM kernel.

    Used by DESIGN.md/EXPERIMENTS.md §Perf to size tiles against a ~16 MiB
    TPU VMEM budget. f32 staging for activations/weights/output plus the
    per-block scale vectors (one scale per block per row/column).
    """
    act = tile_m * k * 4
    wgt = k * tile_n * 4
    out = tile_m * tile_n * 4
    scales = (tile_m + tile_n) * (k // block_size) * 4
    total = act + wgt + out + scales
    return total, {"act": act, "wgt": wgt, "out": out, "scales": scales}
