"""L2 — JAX decoder-only transformer LM with in-graph microscaling quantization.

This is the model substrate for every perplexity/accuracy experiment of the
paper (Figs. 1, 4, 5, 14, 16, 17; Tables 1-3). Following the paper's
protocol (App. A):

  * the weights AND activations of every linear layer are fake-quantized
    with the selected microscaling format — except the model head;
  * attention matmuls (QK^T, PV) are NOT quantized;
  * perplexity is next-token NLL on held-out data.

The quantization configuration is NOT baked into the graph: it is a vector
of 11 runtime f32 scalars (`QV_*` below), so a single lowered HLO per block
size serves every (element format, scale format, per-tensor-scaling,
BF16-baseline) combination in the paper. Block size changes tensor shapes
and is therefore static per artifact (`aot.py` lowers one HLO per block
size).

σ-transformed model zoo support: each quantized weight tensor carries a
per-tensor `gain` γ. The stored tensor is w̃ = w/γ and the forward computes
γ·(FQ(x) @ FQ(w̃)), which preserves the learned function exactly while
letting the *stored* tensor σ be dialed to mimic the per-tensor σ spectra
of the paper's models (granite-narrow vs llama-2-wide vs mamba-ultranarrow)
— see DESIGN.md §1 and `rust/src/model/zoo.rs`.

Everything here is build-time only; `aot.py` lowers it to HLO text that the
Rust runtime executes via PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref

# -- runtime quant-config vector layout (f32 scalars) -----------------------
QV_QUANT_ON = 0      # 0.0 => exact BF16-path baseline (no fake-quant at all)
QV_ELEM_IS_INT = 1   # 1.0 => INT4 elements (App. G), else minifloat elements
QV_ELEM_M = 2        # element minifloat mantissa bits
QV_ELEM_EMIN = 3     # element minifloat min normal exponent
QV_ELEM_MAX = 4      # element max (6.0 FP4; 7.0 INT4)
QV_SCALE_M = 5       # scale minifloat mantissa bits
QV_SCALE_EMIN = 6    # scale minifloat min normal exponent
QV_SCALE_MAX = 7     # scale minifloat max value
QV_PER_TENSOR = 8    # 1.0 => UE4M3-S-style global pre-scaling (eq. 11)
QV_SCALE_FMT_MAX = 9 # max(scale fmt) used in the eq. 11 numerator
QV_ACT_QUANT = 10    # 1.0 => quantize activations too (paper default)
QV_LEN = 11


def qvec(
    elem: str = "fp4_e2m1",
    scale: str = "ue4m3",
    per_tensor: bool = False,
    quant_on: bool = True,
    act_quant: bool = True,
):
    """Build the runtime quant-config vector from format names (host side)."""
    import numpy as np

    c = ref.default_qcfg(elem if elem != "int4" else "int4", scale, per_tensor)
    v = np.zeros(QV_LEN, dtype=np.float32)
    v[QV_QUANT_ON] = 1.0 if quant_on else 0.0
    v[QV_ELEM_IS_INT] = 1.0 if c["elem_is_int"] else 0.0
    v[QV_ELEM_M] = c["elem_m"]
    v[QV_ELEM_EMIN] = c["elem_emin"]
    v[QV_ELEM_MAX] = c["elem_max"]
    v[QV_SCALE_M] = c["scale_m"]
    v[QV_SCALE_EMIN] = c["scale_emin"]
    v[QV_SCALE_MAX] = c["scale_max"]
    v[QV_PER_TENSOR] = 1.0 if per_tensor else 0.0
    v[QV_SCALE_FMT_MAX] = c["scale_fmt_max"]
    v[QV_ACT_QUANT] = 1.0 if act_quant else 0.0
    return v


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration.

    Defaults are the `tiny` preset used throughout the reproduction
    (sized for the single-core CPU sandbox; see DESIGN.md §7). All K
    (contraction) dimensions are multiples of 128 so that microscaling
    block sizes up to 128 divide evenly.
    """

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    seq_len: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def init_specs(cfg: ModelConfig) -> Dict[str, dict]:
    """Shape/init spec for every parameter tensor (consumed by Rust init).

    Layer tensors are stacked on a leading n_layers axis (scanned in the
    forward pass). `init` kinds: normal(std), zeros, ones.
    """
    L, D, F, V, S = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    std = 0.02
    out_std = std / (2.0 * L) ** 0.5  # GPT-2-style residual-out scaling
    return {
        "embed": dict(shape=(V, D), init="normal", std=std, decay=True),
        "pos": dict(shape=(S, D), init="normal", std=std, decay=True),
        "ln1_g": dict(shape=(L, D), init="ones", decay=False),
        "ln1_b": dict(shape=(L, D), init="zeros", decay=False),
        "wq": dict(shape=(L, D, D), init="normal", std=std, decay=True),
        "wk": dict(shape=(L, D, D), init="normal", std=std, decay=True),
        "wv": dict(shape=(L, D, D), init="normal", std=std, decay=True),
        "wo": dict(shape=(L, D, D), init="normal", std=out_std, decay=True),
        "ln2_g": dict(shape=(L, D), init="ones", decay=False),
        "ln2_b": dict(shape=(L, D), init="zeros", decay=False),
        "w1": dict(shape=(L, D, F), init="normal", std=std, decay=True),
        "w2": dict(shape=(L, F, D), init="normal", std=out_std, decay=True),
        "gains": dict(shape=(L, 6), init="ones", decay=False),
        "lnf_g": dict(shape=(D,), init="ones", decay=False),
        "lnf_b": dict(shape=(D,), init="zeros", decay=False),
        "head": dict(shape=(D, V), init="normal", std=std, decay=True),
    }


PARAM_ORDER = tuple(sorted(init_specs(ModelConfig()).keys()))


def _fq(x: jnp.ndarray, block_size: int, qv: jnp.ndarray) -> jnp.ndarray:
    """Runtime-configured microscaling fake-quant (blocks on last axis)."""
    xq = ref.fake_quant(
        x,
        block_size,
        elem_is_int=qv[QV_ELEM_IS_INT] > 0.5,
        elem_m=qv[QV_ELEM_M].astype(jnp.int32),
        elem_emin=qv[QV_ELEM_EMIN].astype(jnp.int32),
        elem_max=qv[QV_ELEM_MAX],
        scale_m=qv[QV_SCALE_M].astype(jnp.int32),
        scale_emin=qv[QV_SCALE_EMIN].astype(jnp.int32),
        scale_max=qv[QV_SCALE_MAX],
        per_tensor=qv[QV_PER_TENSOR] > 0.5,
        scale_fmt_max=qv[QV_SCALE_FMT_MAX],
    )
    return jnp.where(qv[QV_QUANT_ON] > 0.5, xq, x)


def _qlinear(x, w, gain, block_size: int, qv: jnp.ndarray):
    """y = γ · (FQ(x) @ FQ(w̃)): the paper's quantized linear layer.

    x: (..., K); w: (K, F) stored tensor w̃; gain: scalar γ. Weight blocks
    run along K on the transposed view (per-output-column), activations
    along their last axis.
    """
    act_on = qv[QV_ACT_QUANT] > 0.5
    xq = jnp.where(act_on, _fq(x, block_size, qv), x)
    wq = _fq(w.T, block_size, qv).T
    return (xq @ wq) * gain


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def forward(
    params: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,
    qv: jnp.ndarray,
    cfg: ModelConfig,
    block_size: int,
) -> jnp.ndarray:
    """Logits (B, S, V) for int32 tokens (B, S) under quant config `qv`."""
    B, S = tokens.shape
    D, H = cfg.d_model, cfg.n_heads
    hd = cfg.head_dim
    x = params["embed"][tokens] + params["pos"][None, :S, :]
    mask = jnp.tril(jnp.ones((S, S), jnp.bool_))

    layer_keys = (
        "ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
        "ln2_g", "ln2_b", "w1", "w2", "gains",
    )

    def layer(x, lp):
        h = _ln(x, lp["ln1_g"], lp["ln1_b"])
        g = lp["gains"]
        q = _qlinear(h, lp["wq"], g[0], block_size, qv)
        k = _qlinear(h, lp["wk"], g[1], block_size, qv)
        v = _qlinear(h, lp["wv"], g[2], block_size, qv)
        q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
        # attention matmuls are full-precision (paper App. A)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
        x = x + _qlinear(o, lp["wo"], g[3], block_size, qv)
        h2 = _ln(x, lp["ln2_g"], lp["ln2_b"])
        h2 = _qlinear(h2, lp["w1"], g[4], block_size, qv)
        h2 = jax.nn.gelu(h2)
        x = x + _qlinear(h2, lp["w2"], g[5], block_size, qv)
        return x, None

    stacked = {k: params[k] for k in layer_keys}
    x, _ = jax.lax.scan(layer, x, stacked)
    x = _ln(x, params["lnf_g"], params["lnf_b"])
    # model head is NOT quantized (paper App. A)
    return x @ params["head"]


def nll_loss(
    params, tokens, qv, cfg: ModelConfig, block_size: int
) -> jnp.ndarray:
    """Mean next-token NLL (nats) over a (B, S+1) token batch.

    Perplexity = exp(mean NLL aggregated over batches) — the Rust eval
    driver aggregates sums, so we also return the token count.
    """
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = forward(params, inp, qv, cfg, block_size)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# -- training (AdamW, full precision: we reproduce PTQ like the paper) ------


def adamw_step(
    params, m, v, step, tokens, lr, wd, cfg: ModelConfig
) -> Tuple[Any, Any, Any, jnp.ndarray]:
    """One full-precision AdamW step on the unquantized model.

    step is the 1-based f32 step index (for bias correction). Weight decay
    applies only to tensors flagged decay=True in `init_specs`.
    """
    b1, b2, eps = 0.9, 0.999, 1e-8
    qv_off = jnp.zeros((QV_LEN,), jnp.float32)  # quant_on = 0

    def loss_fn(p):
        return nll_loss(p, tokens, qv_off, cfg, block_size=8)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    specs = init_specs(cfg)
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = b1 * m[k] + (1 - b1) * g
        vk = b2 * v[k] + (1 - b2) * jnp.square(g)
        mhat = mk / (1 - b1**step)
        vhat = vk / (1 - b2**step)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        if specs[k]["decay"]:
            upd = upd + wd * params[k]
        new_p[k] = params[k] - lr * upd
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v, loss
