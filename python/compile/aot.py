"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Run once via `make artifacts` (no-op when up to date). Python never runs on
the experiment path: the Rust binary loads `artifacts/*.hlo.txt` through
the PJRT CPU client (`rust/src/runtime/`).

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emits:
  artifacts/loss_bs{2,4,8,16,32,64,128}.hlo.txt   eval NLL per block size
  artifacts/logits_bs{8,16}.hlo.txt               logits for probes
  artifacts/train_step.hlo.txt                    AdamW step
  artifacts/kernel_fq.hlo.txt                     L1 Pallas fake-quant demo
  artifacts/kernel_qmm.hlo.txt                    L1 Pallas fused GEMM demo
  artifacts/manifest.json                         shapes/param-init contract
  artifacts/golden/quant_golden.json              Rust bit-exactness vectors
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import microscale as mk
from .kernels import ref

EVAL_BATCH = 8
TRAIN_BATCH = 16
BLOCK_SIZES = (2, 4, 8, 16, 32, 64, 128)
LOGITS_BLOCK_SIZES = (8, 16)
KERNEL_SHAPE = (128, 128)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_spec(name: str, x) -> dict:
    return {
        "name": name,
        "shape": list(x.shape),
        "dtype": str(x.dtype),
    }


def _param_leaves(cfg: M.ModelConfig) -> List[str]:
    """Flattened param order: jax flattens dicts by sorted key."""
    return sorted(M.init_specs(cfg).keys())


def _example_params(cfg: M.ModelConfig):
    specs = M.init_specs(cfg)
    return {
        k: jnp.zeros(tuple(s["shape"]), jnp.float32) for k, s in specs.items()
    }


def lower_artifacts(out_dir: str, cfg: M.ModelConfig) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
        },
        "eval_batch": EVAL_BATCH,
        "train_batch": TRAIN_BATCH,
        "block_sizes": list(BLOCK_SIZES),
        "qvec_len": M.QV_LEN,
        "params": {},
        "artifacts": {},
    }
    for k, s in M.init_specs(cfg).items():
        manifest["params"][k] = {
            "shape": list(s["shape"]),
            "init": s["init"],
            "std": s.get("std", 0.0),
            "decay": s["decay"],
        }
    manifest["param_order"] = _param_leaves(cfg)

    params = _example_params(cfg)
    qv = jnp.zeros((M.QV_LEN,), jnp.float32)

    def emit(name: str, lowered, inputs: List[dict], outputs: List[dict]):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  {name}: {len(text)/1e6:.2f} MB HLO text")

    param_inputs = [
        _leaf_spec(k, params[k]) for k in _param_leaves(cfg)
    ]

    # -- eval loss per block size -------------------------------------
    tokens_eval = jnp.zeros((EVAL_BATCH, cfg.seq_len + 1), jnp.int32)
    for bs in BLOCK_SIZES:
        fn = lambda p, t, q, _bs=bs: (M.nll_loss(p, t, q, cfg, _bs),)
        lowered = jax.jit(fn).lower(params, tokens_eval, qv)
        emit(
            f"loss_bs{bs}",
            lowered,
            param_inputs
            + [_leaf_spec("tokens", tokens_eval), _leaf_spec("qv", qv)],
            [{"shape": [], "dtype": "float32"}],
        )

    # -- logits for downstream probes ----------------------------------
    tokens_fwd = jnp.zeros((EVAL_BATCH, cfg.seq_len), jnp.int32)
    for bs in LOGITS_BLOCK_SIZES:
        fn = lambda p, t, q, _bs=bs: (M.forward(p, t, q, cfg, _bs),)
        lowered = jax.jit(fn).lower(params, tokens_fwd, qv)
        emit(
            f"logits_bs{bs}",
            lowered,
            param_inputs
            + [_leaf_spec("tokens", tokens_fwd), _leaf_spec("qv", qv)],
            [{
                "shape": [EVAL_BATCH, cfg.seq_len, cfg.vocab],
                "dtype": "float32",
            }],
        )

    # -- train step -----------------------------------------------------
    tokens_tr = jnp.zeros((TRAIN_BATCH, cfg.seq_len + 1), jnp.int32)
    step = jnp.zeros((), jnp.float32)
    lr = jnp.zeros((), jnp.float32)
    wd = jnp.zeros((), jnp.float32)

    def train_fn(p, m, v, s, t, lr_, wd_):
        np_, nm, nv, loss = M.adamw_step(p, m, v, s, t, lr_, wd_, cfg)
        return (np_, nm, nv, loss)

    lowered = jax.jit(train_fn).lower(
        params, params, params, step, tokens_tr, lr, wd
    )
    order = _param_leaves(cfg)
    tr_inputs = (
        [_leaf_spec(f"p.{k}", params[k]) for k in order]
        + [_leaf_spec(f"m.{k}", params[k]) for k in order]
        + [_leaf_spec(f"v.{k}", params[k]) for k in order]
        + [
            _leaf_spec("step", step),
            _leaf_spec("tokens", tokens_tr),
            _leaf_spec("lr", lr),
            _leaf_spec("wd", wd),
        ]
    )
    tr_outputs = (
        [
            {"shape": list(params[k].shape), "dtype": "float32", "name": g + k}
            for g in ("p.", "m.", "v.")
            for k in order
        ]
        + [{"shape": [], "dtype": "float32", "name": "loss"}]
    )
    emit("train_step", lowered, tr_inputs, tr_outputs)

    # -- L1 Pallas kernel demos ------------------------------------------
    x_spec = jax.ShapeDtypeStruct(KERNEL_SHAPE, jnp.float32)
    cfg_fq = {
        k: v
        for k, v in ref.default_qcfg("fp4_e2m1", "ue4m3").items()
        if k not in ("per_tensor", "scale_fmt_max")
    }
    lowered = jax.jit(
        lambda x: (mk.fake_quant_pallas(x, 16, cfg_fq),)
    ).lower(x_spec)
    emit(
        "kernel_fq",
        lowered,
        [{"name": "x", "shape": list(KERNEL_SHAPE), "dtype": "float32"}],
        [{"shape": list(KERNEL_SHAPE), "dtype": "float32"}],
    )
    lowered = jax.jit(
        lambda x, w: (mk.quantized_matmul_pallas(x, w, 16, cfg_fq),)
    ).lower(x_spec, x_spec)
    emit(
        "kernel_qmm",
        lowered,
        [
            {"name": "x", "shape": list(KERNEL_SHAPE), "dtype": "float32"},
            {"name": "w", "shape": list(KERNEL_SHAPE), "dtype": "float32"},
        ],
        [{"shape": list(KERNEL_SHAPE), "dtype": "float32"}],
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def emit_golden(out_dir: str) -> None:
    """Golden vectors tying the Rust quantizer bit-exactly to ref.py."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(20260710)
    cases: List[dict] = []

    # minifloat casts across every scale format, log-uniform magnitudes
    mags = np.concatenate([
        np.float32(10.0) ** rng.uniform(-9, 6, 256).astype(np.float32),
        np.zeros(4, np.float32),
        np.float32([2**-9, 2**-10, 2**-17, 2**-18, 448.0, 449.0, 1e30]),
    ]).astype(np.float32)
    for name, f in ref.SCALE_FORMATS.items():
        out = np.asarray(
            ref.cast_minifloat(jnp.array(mags), f.m_bits, f.e_min, f.max_val)
        )
        cases.append({
            "kind": "cast",
            "fmt": name,
            "m_bits": f.m_bits,
            "e_min": f.e_min,
            "max_val": f.max_val,
            "x": mags.tolist(),
            "y": out.astype(float).tolist(),
        })

    # block fake-quant across element/scale/bs/per-tensor combinations
    combos = [
        ("fp4_e2m1", "ue4m3", False), ("fp4_e2m1", "ue4m3", True),
        ("fp4_e2m1", "ue5m3", False), ("fp4_e2m1", "ue4m4", False),
        ("fp4_e2m1", "ue5m1", False), ("fp4_e2m1", "ue4m2", False),
        ("fp4_e2m1", "bf16", False), ("fp4_e2m1", "e8m0", False),
        ("int4", "ue4m3", False), ("int4", "ue5m3", True),
        ("fp6_e2m3", "ue4m3", False), ("fp6_e3m2", "ue4m3", False),
        ("fp8_e4m3", "ue4m3", False), ("fp8_e4m3", "ue5m3", True),
        ("fp8_e4m3", "e8m0", False),
    ]
    for elem, scale, pt in combos:
        for bsz in (2, 8, 16, 32):
            for sigma in (1.0, 2e-2, 1e-4):
                x = rng.normal(0, sigma, 64).astype(np.float32)
                cfgq = ref.default_qcfg(elem, scale, pt)
                y = np.asarray(ref.fake_quant(jnp.array(x), bsz, **cfgq))
                cases.append({
                    "kind": "fake_quant",
                    "elem": elem,
                    "scale": scale,
                    "per_tensor": pt,
                    "block_size": bsz,
                    "x": x.astype(float).tolist(),
                    "y": y.astype(float).tolist(),
                })

    # UE5M3 scale-grid corner cases (subnormal scales, the s_min/2
    # collapse tie, overflow clamp, amax = 0 blocks): the proposed format
    # lives or dies on these edges, so the rust<->python contract pins
    # them explicitly. rust/tests/golden.rs additionally runs the packed
    # codec and the GEMM operand encoder over every tagged case.
    for bsz in (8, 32):
        for elem in ("fp4_e2m1", "fp8_e4m3"):
            # boundary motifs are dyadic multiples of the format's C, so
            # each element format gets its own calibrated vectors
            emax = ref.ELEM_FORMATS[elem].max_val
            edge = np.asarray(
                ref.ue5m3_edge_blocks(bsz, emax), dtype=np.float32
            )
            for pt in (False, True):
                cfgq = ref.default_qcfg(elem, "ue5m3", pt)
                y = np.asarray(ref.fake_quant(jnp.array(edge), bsz, **cfgq))
                cases.append({
                    "kind": "fake_quant",
                    "tag": "ue5m3_edge",
                    "elem": elem,
                    "scale": "ue5m3",
                    "per_tensor": pt,
                    "block_size": bsz,
                    "x": edge.astype(float).tolist(),
                    "y": y.astype(float).tolist(),
                })

    with open(os.path.join(gdir, "quant_golden.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  golden: {len(cases)} cases")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel path; artifacts land in its directory")
    ap.add_argument("--golden-only", action="store_true",
                    help="emit only golden/quant_golden.json (no HLO "
                         "lowering) — what CI uses to enforce the rust "
                         "bit-exactness contract without a PJRT build")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    if args.golden_only:
        print(f"emitting golden vectors to {out_dir}/golden")
        emit_golden(out_dir)
        print("done")
        return
    cfg = M.ModelConfig()
    print(f"lowering artifacts to {out_dir} (model={cfg})")
    lower_artifacts(out_dir, cfg)
    emit_golden(out_dir)
    # sentinel for the Makefile dependency
    with open(args.out, "w") as f:
        f.write("see manifest.json\n")
    print("done")


if __name__ == "__main__":
    main()
