"""L1 correctness: Pallas kernels vs the pure-jnp oracle, bit-for-bit.

Hypothesis sweeps shapes, block sizes, formats, and value distributions;
every case asserts exact equality (the kernel and the oracle share the
same grid math, so any drift is a bug, not tolerance noise).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import microscale as mk
from compile.kernels import ref

FORMATS = [
    ("fp4_e2m1", "ue4m3"),
    ("fp4_e2m1", "ue5m3"),
    ("fp4_e2m1", "ue4m4"),
    ("fp4_e2m1", "ue5m1"),
    ("fp4_e2m1", "ue4m2"),
    ("fp4_e2m1", "e8m0"),
    ("fp4_e2m1", "bf16"),
    ("int4", "ue4m3"),
    ("int4", "ue5m3"),
    ("fp6_e2m3", "ue4m3"),
    ("fp6_e3m2", "ue5m3"),
]


def _cfg(elem, scale):
    c = ref.default_qcfg(elem, scale)
    return {k: v for k, v in c.items() if k not in ("per_tensor", "scale_fmt_max")}


def _full_cfg(elem, scale):
    return ref.default_qcfg(elem, scale)


@pytest.mark.parametrize("elem,scale", FORMATS)
def test_fake_quant_kernel_matches_ref(elem, scale):
    rng = np.random.default_rng(42)
    x = rng.normal(0, 0.02, (128, 64)).astype(np.float32)
    got = mk.fake_quant_pallas(jnp.array(x), 16, _cfg(elem, scale))
    want = ref.fake_quant(jnp.array(x), 16, **_full_cfg(elem, scale))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("elem,scale", FORMATS[:4])
def test_qmatmul_kernel_matches_ref(elem, scale):
    rng = np.random.default_rng(7)
    x = rng.normal(0, 0.05, (64, 128)).astype(np.float32)
    w = rng.normal(0, 0.02, (128, 64)).astype(np.float32)
    got = mk.quantized_matmul_pallas(
        jnp.array(x), jnp.array(w), 16, _cfg(elem, scale)
    )
    want = ref.quantized_matmul(
        jnp.array(x), jnp.array(w), 16, _full_cfg(elem, scale)
    )
    # jnp.dot inside the kernel and the top-level @ use the same XLA CPU
    # dot; tiles are whole-K so partial sums associate identically.
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=0, atol=1e-6
    )


@settings(max_examples=40, deadline=None)
@given(
    rows=st.sampled_from([64, 128, 192]),
    kmul=st.integers(1, 3),
    bs=st.sampled_from([2, 4, 8, 16, 32]),
    sigma=st.sampled_from([1e-4, 1e-2, 1.0, 100.0]),
    fmt=st.sampled_from(FORMATS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_kernel_hypothesis(rows, kmul, bs, sigma, fmt, seed):
    k = bs * kmul * 2
    rng = np.random.default_rng(seed)
    x = rng.normal(0, sigma, (rows, k)).astype(np.float32)
    got = mk.fake_quant_pallas(jnp.array(x), bs, _cfg(*fmt), tile_m=64)
    want = ref.fake_quant(jnp.array(x), bs, **_full_cfg(*fmt))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    bs=st.sampled_from([4, 8, 16]),
    sigma=st.sampled_from([1e-3, 0.05]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_kernel_hypothesis(bs, sigma, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, sigma, (64, 64)).astype(np.float32)
    w = rng.normal(0, sigma, (64, 64)).astype(np.float32)
    got = mk.quantized_matmul_pallas(jnp.array(x), jnp.array(w), bs, _cfg(*FORMATS[0]))
    want = ref.quantized_matmul(jnp.array(x), jnp.array(w), bs, _full_cfg(*FORMATS[0]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_vmem_footprint_within_budget():
    """Perf contract: default tiles fit a 16 MiB VMEM budget with slack
    for double buffering (DESIGN.md §Perf)."""
    total, parts = mk.vmem_footprint_bytes(64, 64, 4096, 32)
    assert 2 * total < 16 * 2**20, (total, parts)
