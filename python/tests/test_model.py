"""L2 model tests: shapes, quant-config plumbing, training step sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(n_layers=2, seq_len=32)


def _params(seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in M.init_specs(CFG).items():
        if s["init"] == "normal":
            out[k] = jnp.array(
                rng.normal(0, s["std"], s["shape"]).astype(np.float32)
            )
        elif s["init"] == "ones":
            out[k] = jnp.ones(s["shape"], jnp.float32)
        else:
            out[k] = jnp.zeros(s["shape"], jnp.float32)
    return out


def _tokens(rng, batch, seqlen):
    return jnp.array(
        rng.integers(0, CFG.vocab, (batch, seqlen)).astype(np.int32)
    )


def test_forward_shapes():
    p = _params()
    rng = np.random.default_rng(0)
    t = _tokens(rng, 2, CFG.seq_len)
    qv = jnp.array(M.qvec(quant_on=False))
    logits = M.forward(p, t, qv, CFG, block_size=8)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_quant_off_equals_exact():
    """quant_on=0 must bypass fake-quant entirely (bit-exact baseline)."""
    p = _params()
    rng = np.random.default_rng(1)
    t = _tokens(rng, 2, CFG.seq_len)
    qv_off = jnp.array(M.qvec(quant_on=False))
    qv_off2 = jnp.array(M.qvec(scale="ue5m3", quant_on=False))
    a = M.forward(p, t, qv_off, CFG, 8)
    b = M.forward(p, t, qv_off2, CFG, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quant_configs_differ():
    p = _params()
    rng = np.random.default_rng(2)
    t = _tokens(rng, 2, CFG.seq_len)
    a = M.forward(p, t, jnp.array(M.qvec(scale="ue4m3")), CFG, 8)
    b = M.forward(p, t, jnp.array(M.qvec(scale="ue5m3")), CFG, 8)
    c = M.forward(p, t, jnp.array(M.qvec(quant_on=False)), CFG, 8)
    assert float(jnp.max(jnp.abs(a - c))) > 0
    assert float(jnp.max(jnp.abs(a - b))) > 0


def test_gain_sigma_transform_preserves_function():
    """DESIGN §1: w̃=w/γ with gain γ leaves the unquantized fwd invariant
    and (nearly) the quantized fwd too when scales are unquantized."""
    p = _params()
    rng = np.random.default_rng(3)
    t = _tokens(rng, 2, CFG.seq_len)
    p2 = dict(p)
    gamma = 0.125  # power of two => exact f32 rescale
    for k in ("wq", "wk", "wv", "wo", "w1", "w2"):
        p2[k] = p[k] / gamma
    p2["gains"] = p["gains"] * gamma
    qv_off = jnp.array(M.qvec(quant_on=False))
    a = M.forward(p, t, qv_off, CFG, 8)
    b = M.forward(p2, t, qv_off, CFG, 8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # with BF16 (quasi-continuous) scales, a power-of-two γ is also exact
    qv_bf = jnp.array(M.qvec(scale="bf16"))
    aq = M.forward(p, t, qv_bf, CFG, 8)
    bq = M.forward(p2, t, qv_bf, CFG, 8)
    np.testing.assert_allclose(np.asarray(aq), np.asarray(bq), atol=1e-5)


def test_nll_loss_reasonable_at_init():
    p = _params()
    rng = np.random.default_rng(4)
    t = _tokens(rng, 4, CFG.seq_len + 1)
    qv = jnp.array(M.qvec(quant_on=False))
    loss = float(M.nll_loss(p, t, qv, CFG, 8))
    # near-uniform logits at init: NLL ~ ln(vocab) = ln 256 ~ 5.55
    assert 4.5 < loss < 6.5, loss


def test_adamw_step_decreases_loss():
    p = _params()
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    rng = np.random.default_rng(5)
    t = _tokens(rng, 8, CFG.seq_len + 1)
    step_fn = jax.jit(
        lambda p, m, v, s, t: M.adamw_step(
            p, m, v, s, t, 1e-3, 0.01, CFG
        )
    )
    losses = []
    for i in range(8):
        p, m, v, loss = step_fn(p, m, v, jnp.float32(i + 1), t)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_qvec_layout_stable():
    """The Rust runtime hardcodes this layout — lock it."""
    v = M.qvec("fp4_e2m1", "ue4m3", per_tensor=True)
    assert v.shape == (11,)
    assert v[M.QV_QUANT_ON] == 1.0
    assert v[M.QV_ELEM_MAX] == 6.0
    assert v[M.QV_SCALE_M] == 3.0
    assert v[M.QV_SCALE_EMIN] == -6.0
    assert v[M.QV_SCALE_MAX] == 448.0
    assert v[M.QV_PER_TENSOR] == 1.0
    assert v[M.QV_ACT_QUANT] == 1.0
    v5 = M.qvec("fp4_e2m1", "ue5m3")
    assert v5[M.QV_SCALE_EMIN] == -14.0
    assert v5[M.QV_SCALE_MAX] == 122880.0
    vi = M.qvec("int4", "ue4m3")
    assert vi[M.QV_ELEM_IS_INT] == 1.0 and vi[M.QV_ELEM_MAX] == 7.0
