"""Properties of the reference quantizer (`ref.py`) itself.

These are the invariants the paper's formulation relies on (Sec. 2.1,
App. E/F) plus grid-exactness properties of the minifloat codec.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

ALL_SCALE_FMTS = list(ref.SCALE_FORMATS.values())


def _levels(fmt: ref.MiniFloat, count: int = 4096) -> np.ndarray:
    """Enumerate the first `count` non-negative representable values."""
    out = [0.0]
    q = 2.0 ** (fmt.e_min - fmt.m_bits)
    r = 1
    # subnormals (levels below f32 MIN_POSITIVE are excluded: the cast
    # contract flushes f32-subnormal inputs/outputs — see ref.py DAZ note)
    while r < 2**fmt.m_bits and len(out) < count:
        if r * q >= 2.0**-126:
            out.append(r * q)
        r += 1
    e = fmt.e_min
    while len(out) < count:
        for r in range(2**fmt.m_bits, 2 ** (fmt.m_bits + 1)):
            v = r * 2.0 ** (e - fmt.m_bits)
            if v > fmt.max_val or v > 3.0e38 or len(out) >= count:
                return np.array(out, np.float64)
            out.append(v)
        e += 1
    return np.array(out, np.float64)


@pytest.mark.parametrize("fmt", ALL_SCALE_FMTS, ids=lambda f: f.name)
def test_cast_is_idempotent_on_levels(fmt):
    lv = _levels(fmt, 600).astype(np.float32)
    got = np.asarray(ref.cast_minifloat(jnp.array(lv), *fmt.as_tuple()))
    np.testing.assert_array_equal(got, lv)


@pytest.mark.parametrize("fmt", ALL_SCALE_FMTS, ids=lambda f: f.name)
def test_cast_rounds_to_nearest(fmt):
    """Random points round to the nearest enumerated level (ties -> even)."""
    if fmt.name == "bf16":
        pytest.skip("bf16 level enumeration too large for a dense check")
    lv = _levels(fmt, 3000)
    rng = np.random.default_rng(3)
    hi = min(float(lv[-1]), fmt.max_val)
    x = (10.0 ** rng.uniform(np.log10(lv[1]) - 1, np.log10(hi), 500)).astype(
        np.float32
    )
    x = x[x <= hi]
    got = np.asarray(
        ref.cast_minifloat(jnp.array(x), *fmt.as_tuple())
    ).astype(np.float64)
    for xi, gi in zip(x.astype(np.float64), got):
        err = np.abs(lv - xi)
        best = err.min()
        assert abs(gi - xi) <= best + 1e-30, (fmt.name, xi, gi)


def test_paper_min_subnormals():
    """Sec. 2.1 / 5.2 / App. H/J: smallest non-zero representables."""
    expect = {
        "ue4m3": 2.0**-9,
        "ue5m3": 2.0**-17,
        "ue4m4": 2.0**-10,
        "ue5m1": 2.0**-15,
        "ue4m2": 2.0**-8,
    }
    for name, want in expect.items():
        f = ref.SCALE_FORMATS[name]
        # want is representable; want * 0.51 rounds up to want; 0.49 -> 0
        assert float(ref.cast_minifloat(jnp.float32(want), *f.as_tuple())) == want
        assert (
            float(ref.cast_minifloat(jnp.float32(want * 0.51), *f.as_tuple()))
            == want
        )
        assert (
            float(ref.cast_minifloat(jnp.float32(want * 0.49), *f.as_tuple()))
            == 0.0
        )


def test_fp4_level_set():
    xs = jnp.linspace(-8, 8, 4001)
    q = np.asarray(ref.cast_signed_minifloat(xs, 1, 0, 6.0))
    assert set(np.abs(np.unique(q)).tolist()) == {
        0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0
    }


def test_int4_level_set():
    xs = jnp.linspace(-9, 9, 1001)
    q = np.asarray(ref.cast_int_symmetric(xs, 7.0))
    assert set(np.unique(q).tolist()) == set(float(i) for i in range(-7, 8))


@settings(max_examples=60, deadline=None)
@given(
    bs=st.sampled_from([2, 4, 8, 16, 32]),
    sigma=st.floats(1e-5, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_bounded_error(bs, sigma, seed):
    """|xhat| is bounded by the block absmax plus one scale-rounding ulp.

    (Note: fake-quant is deliberately NOT asserted idempotent — requantizing
    the dequantized tensor changes the block absmax and hence the quantized
    scale, so a second pass can legitimately move values.)
    """
    rng = np.random.default_rng(seed)
    x = (rng.normal(0, sigma, 64)).astype(np.float32).reshape(1, 64)
    cfg = ref.default_qcfg("fp4_e2m1", "ue4m3")
    xq = ref.fake_quant(jnp.array(x), bs, **cfg)
    absmax = np.abs(x).max()
    # dequantized magnitudes can exceed absmax only via scale round-up
    # (s <= RNE-up one ulp): bound by (1 + 2^-m) slack plus saturation
    assert float(jnp.max(jnp.abs(xq))) <= absmax * (1 + 2.0**-3) + 1e-30


def test_zero_block_rounds_to_zero():
    """App. F.3: if absmax/6 < s_min/2, the whole block collapses to 0."""
    x = jnp.full((1, 8), 6.0 * 2.0**-10 * 0.99, jnp.float32)
    cfg = ref.default_qcfg("fp4_e2m1", "ue4m3")
    xq = ref.fake_quant(x, 8, **cfg)
    assert float(jnp.max(jnp.abs(xq))) == 0.0
    # ... but UE5M3's extended range still represents it (Sec. 5.2)
    cfg5 = ref.default_qcfg("fp4_e2m1", "ue5m3")
    xq5 = ref.fake_quant(x, 8, **cfg5)
    assert float(jnp.max(jnp.abs(xq5))) > 0.0


def test_per_tensor_scaling_rescues_narrow_tensor():
    """Eq. 11 / Table 1: UE4M3-S beats plain UE4M3 on narrow tensors."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1e-3, (8, 64)).astype(np.float32)
    base = ref.default_qcfg("fp4_e2m1", "ue4m3")
    scaled = ref.default_qcfg("fp4_e2m1", "ue4m3", per_tensor=True)
    mse = lambda c: float(
        jnp.mean((ref.fake_quant(jnp.array(x), 8, **c) - x) ** 2)
    )
    assert mse(scaled) < mse(base)


def test_ue5m3_matches_per_tensor_scaling_on_narrow():
    """Headline claim (Sec. 5.2): UE5M3 ~ UE4M3-S without the global scale."""
    rng = np.random.default_rng(1)
    x = rng.normal(0, 5e-3, (16, 64)).astype(np.float32)
    m = {}
    for name, cfg in [
        ("ue4m3", ref.default_qcfg("fp4_e2m1", "ue4m3")),
        ("ue4m3s", ref.default_qcfg("fp4_e2m1", "ue4m3", per_tensor=True)),
        ("ue5m3", ref.default_qcfg("fp4_e2m1", "ue5m3")),
    ]:
        m[name] = float(
            jnp.mean((ref.fake_quant(jnp.array(x), 8, **cfg) - x) ** 2)
        )
    assert m["ue5m3"] <= m["ue4m3s"] * 1.05
    assert m["ue5m3"] < m["ue4m3"]
