//! Hardware cost study (Fig. 4(a), Sec. 3.1, App. K): the PE-level cost
//! of every scale-format option, plus the storage/bandwidth model.
//!
//! ```bash
//! cargo run --release --example hw_cost
//! ```

use microscale::experiments::hwx;

fn main() {
    println!("{}", hwx::fig4a());
    println!("{}", hwx::appendix_k());
    println!("{}", hwx::sec31_costs());
}
