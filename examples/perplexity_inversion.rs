//! Perplexity inversion, live: reproduce the paper's Fig. 1(b) headline
//! phenomenon on the σ-calibrated model suite through the full
//! AOT-runtime stack (trains the base model on first run; cached after).
//!
//! ```bash
//! cargo run --release --example perplexity_inversion -- [--fast]
//! ```

use microscale::experiments::{self, Ctx};
use microscale::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut ctx = Ctx::default_dirs(args.has("fast") || !args.has("full"))?;
    println!("{}", experiments::figure(&mut ctx, "1a")?);
    println!("{}", experiments::figure(&mut ctx, "1b")?);
    println!(
        "Fig. 1(a) vs 1(b): with BF16 (non-quantized) scales the gap shrinks\n\
         monotonically as blocks shrink; quantizing the scales to UE4M3 makes\n\
         the narrow-σ models INVERT at small block sizes — the paper's anomaly."
    );
    Ok(())
}
