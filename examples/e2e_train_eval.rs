//! End-to-end driver (DESIGN.md deliverable (b)/e2e): exercises every
//! layer of the stack on a real small workload —
//!
//!   1. generates the synthetic corpus (L3 substrate),
//!   2. trains the decoder-only transformer for a few hundred steps via
//!      the AOT `train_step` HLO (L2 graph, executed through PJRT),
//!      logging the loss curve,
//!   3. builds the σ-calibrated model zoo,
//!   4. evaluates perplexity + downstream probes under the paper's
//!      quantization formats (L1-semantics in-graph quantization),
//!   5. writes results/e2e_report.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train_eval -- \
//!     [--steps 240] [--fast]
//! ```

use microscale::experiments::ppl::{ensure_models, ppl_point};
use microscale::experiments::Ctx;
use microscale::model::Corpus;
use microscale::report::Table;
use microscale::runtime::QConfig;
use microscale::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut ctx = Ctx::default_dirs(args.has("fast"))?;
    ctx.train_steps = args.get_usize("steps", 240)?;

    let t0 = std::time::Instant::now();
    let corpus = Corpus::default_language(256);
    println!(
        "corpus: synthetic Zipf–Markov language, entropy floor ≈ {:.2} nats \
         (uniform = {:.2})",
        corpus.entropy_estimate(300),
        (256f64).ln()
    );

    // train (or load) + zoo
    let models = ensure_models(&mut ctx)?;
    println!("model zoo ready ({} variants) in {:.0}s", models.len(),
        t0.elapsed().as_secs_f64());
    if let Ok(curve) = std::fs::read_to_string("results/train_loss_curve.csv")
    {
        println!("loss curve (results/train_loss_curve.csv):");
        for line in curve.lines().take(14) {
            println!("  {line}");
        }
    }

    // quantized evaluation across formats
    let mut t = Table::new(
        "End-to-end: perplexity by model and format (block size 8)",
        &["model", "BF16", "UE4M3", "UE4M3-S", "UE5M3 (ours)"],
    );
    let mut md = String::from("# e2e report\n\n");
    for m in &models {
        let base = ppl_point(&mut ctx, m, &QConfig::baseline(), 8)?;
        let q43 = ppl_point(&mut ctx, m, &QConfig::fp4("ue4m3")?, 8)?;
        let q43s = ppl_point(
            &mut ctx,
            m,
            &QConfig::fp4("ue4m3")?.with_per_tensor(true),
            8,
        )?;
        let q53 = ppl_point(&mut ctx, m, &QConfig::fp4("ue5m3")?, 8)?;
        t.row(vec![
            m.name.clone(),
            format!("{base:.3}"),
            format!("{q43:.3}"),
            format!("{q43s:.3}"),
            format!("{q53:.3}"),
        ]);
    }
    println!("{}", t.render());
    md.push_str(&t.markdown());
    ctx.sink()?.text("e2e_report.md", &md)?;
    println!(
        "total {:.0}s — report at results/e2e_report.md",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
