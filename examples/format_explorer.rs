//! Format explorer: use the theoretical framework (Sec. 4.3) to evaluate
//! *hypothetical* scale formats before building hardware — "in the
//! context of new data format exploration, this framework can play a
//! role in analyzing the impact of scaling down precision".
//!
//! Sweeps every (m_bits, e_min) scale format that fits in 8 bits with an
//! unused sign bit, reports the MSE at three representative σ, the bs8/16
//! crossover, and the hardware cost from the App. K model.
//!
//! ```bash
//! cargo run --release --example format_explorer
//! ```

use microscale::formats::{ElemFormat, MiniFloat};
use microscale::hw::pe::{lane_area, ScaleFmt};
use microscale::report::Table;
use microscale::stats::geomspace;
use microscale::theory;

fn main() {
    let mut t = Table::new(
        "Scale-format design space for FP4 elements (theory-driven, bs 8 vs 16)",
        &[
            "format", "min subnormal", "max",
            "MSE σ=2e-3", "MSE σ=2e-2", "MSE σ=0.5",
            "crossover σ", "lane ΔGE",
        ],
    );
    let base_ge = lane_area(ScaleFmt { name: "ue4m3", e_bits: 4, m_bits_incl: 4 })
        .mxfp4_scale_path;
    let elem = ElemFormat::FP4;
    let sigmas = geomspace(1e-4, 1.0, 33);
    for e_bits in 3..=6u32 {
        for m_bits in (8i32 - e_bits as i32 - 1).max(0)..(8 - e_bits as i32) {
            // unsigned: e_bits + m_bits <= 8 (sign bit repurposed)
            let m_bits = m_bits.max(0);
            let bias = (1 << (e_bits - 1)) - 1;
            let e_min = 1 - bias;
            let e_max = (1 << e_bits) - 1 - bias;
            let max_val =
                (2.0f64 - 2.0f64.powi(-m_bits)) as f32 * 2.0f32.powi(e_max);
            let fmt = MiniFloat { m_bits, e_min, max_val, name: "x" };
            let mse = |s: f64| {
                theory::mse_quantized_scales(&elem, &fmt, s, 8).total()
            };
            // crossover: largest σ where bs8 beats... bs8 worse than bs16
            let mut cross: Option<f64> = None;
            for &s in &sigmas {
                let b8 = theory::mse_quantized_scales(&elem, &fmt, s, 8);
                let b16 = theory::mse_quantized_scales(&elem, &fmt, s, 16);
                if b8.total() > b16.total() {
                    cross = Some(s);
                }
            }
            let hw = lane_area(ScaleFmt {
                name: "x",
                e_bits,
                m_bits_incl: (m_bits + 1) as u32,
            })
            .mxfp4_scale_path;
            t.row(vec![
                format!("UE{e_bits}M{m_bits}"),
                format!("2^{}", e_min - m_bits),
                format!("{max_val:.3e}"),
                format!("{:.2e}", mse(2e-3)),
                format!("{:.2e}", mse(2e-2)),
                format!("{:.2e}", mse(0.5)),
                cross
                    .map(|c| format!("{c:.1e}"))
                    .unwrap_or_else(|| "none".into()),
                format!("{:+.0}", hw - base_ge),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "Reading: UE5M3 eliminates the narrow-σ blow-up (no crossover above\n\
         the s=0 floor) at ~zero hardware cost — the paper's conclusion.\n\
         Wider-mantissa options (UE4M4) pay M² in the multiplier and still\n\
         keep a crossover; PoT-style UE6M1+ trades element precision."
    );
}
