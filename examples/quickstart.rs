//! Quickstart: quantize a tensor with every scale format of the paper,
//! see the anomaly, store it on real packed bytes, multiply it natively
//! in the packed code domain, serve a whole transformer on prepacked
//! weights, generate tokens through the KV-cached scheduler, run
//! memory-bounded generation with an MX-quantized KV cache, stream
//! tokens over a loopback HTTP server whose KV pool shares prompt
//! prefixes, and (when artifacts are present) run the L1 Pallas kernel
//! artifact through PJRT.
//!
//! ```bash
//! cargo run --release --example quickstart          # steps 1-8
//! make artifacts && cargo run --release --example quickstart  # + PJRT
//! ```

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, SCALE_FORMATS};
use microscale::quant::gemm::{GemmOperand, PackedGemm};
use microscale::quant::matmul::matmul_t;
use microscale::quant::{fake_quant, PackedMxTensor, QuantScheme};
use microscale::report::Table;
use microscale::runtime::{Manifest, Session};
use microscale::stats::mse_f32;

fn main() -> anyhow::Result<()> {
    // 1) A narrow weight tensor (σ = 5e-3, granite-territory) quantized
    //    to FP4 with each scale format, at block sizes 8 and 16.
    let mut rng = Pcg64::new(1);
    let x = rng.normal_vec_f32(1 << 16, 5e-3);
    let mut t = Table::new(
        "FP4 microscaling of a narrow tensor (σ = 5e-3): MSE by scale format",
        &["scale", "bs 8", "bs 16", "bs8 worse?"],
    );
    for scale in SCALE_FORMATS {
        let m8 = mse_f32(
            &x,
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, scale, 8), &x),
        );
        let m16 = mse_f32(
            &x,
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, scale, 16), &x),
        );
        t.row(vec![
            scale.name.to_string(),
            format!("{m8:.3e}"),
            format!("{m16:.3e}"),
            if m8 > m16 { "YES (anomaly)" } else { "no" }.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "The paper's discovery: under UE4M3 the *smaller* block is worse for\n\
         narrow tensors; the proposed UE5M3 restores the expected ordering.\n"
    );

    // 2) Per-tensor scaling (UE4M3-S, eq. 11) vs UE5M3.
    let s43 = QuantScheme::new(ElemFormat::FP4, microscale::formats::UE4M3, 8);
    let s43s = s43.with_per_tensor(true);
    let s53 = QuantScheme::new(ElemFormat::FP4, microscale::formats::UE5M3, 8);
    println!(
        "UE4M3: {:.3e} | UE4M3-S: {:.3e} | UE5M3: {:.3e}  (bs 8)\n",
        mse_f32(&x, &fake_quant(&s43, &x)),
        mse_f32(&x, &fake_quant(&s43s, &x)),
        mse_f32(&x, &fake_quant(&s53, &x)),
    );

    // 3) The same tensor on real packed bytes: PackedMxTensor stores
    //    bit-packed FP4 codes + one scale byte per block, and decodes
    //    bit-exactly to the fake-quant output.
    let packed = PackedMxTensor::encode(&s43, &x)?;
    assert!(packed
        .decode()
        .iter()
        .zip(&fake_quant(&s43, &x))
        .all(|(a, b)| a.to_bits() == b.to_bits()));
    println!(
        "PackedMxTensor: {} elements -> {} bytes ({:.3} bits/elem, \
         {:.2}x smaller than bf16), decode == fake_quant bit-for-bit ✓\n",
        packed.len(),
        packed.payload_bytes(),
        packed.bits_per_element(),
        packed.compression_vs_bf16(),
    );

    // 4) Multiply without ever dequantizing: the packed-native GEMM
    //    engine consumes the integer codes directly (decode LUTs +
    //    per-block scale fusion, mirroring the PE datapath) and is
    //    bit-identical to dequantize-then-f32-GEMM. The inner loops
    //    dispatch at runtime to AVX2 / NEON vector kernels where the
    //    host supports them (MICROSCALE_SIMD=scalar pins them off) —
    //    and stay bit-identical either way, because the vector lanes
    //    replay the scalar reduction order exactly (DESIGN.md §13).
    let (m, kd, nd) = (48usize, 256, 32);
    let a = rng.normal_vec_f32(m * kd, 5e-3);
    let b = rng.normal_vec_f32(kd * nd, 5e-3);
    let xo = GemmOperand::quantize(&s43, &a, m, kd)?;
    let wo = GemmOperand::quantize_transposed(&s43, &b, kd, nd)?; // prepacked ᵀ
    let y = PackedGemm::auto().matmul(&xo, &wo)?;
    let want = matmul_t(&xo.decode(), &wo.decode(), m, kd, nd);
    assert!(y.iter().zip(&want).all(|(u, v)| u.to_bits() == v.to_bits()));
    println!(
        "PackedGemm: {m}x{kd}x{nd} multiplied in the code domain \
         ({} + {} packed bytes, '{}' simd kernel) == dequant + f32 \
         GEMM, bit-for-bit ✓\n",
        xo.payload_bytes(),
        wo.payload_bytes(),
        microscale::util::simd::kernel_name(),
    );

    // 5) Serve a whole model on those packed codes: prepack a surrogate
    //    transformer's weights once (no XLA artifacts needed), then run
    //    micro-batched inference through the multi-worker engine.
    let dims = microscale::runtime::artifacts::ModelDims {
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 2,
        d_ff: 128,
        seq_len: 16,
    };
    let params = microscale::model::Params::init_surrogate(&dims, 2026);
    let qcfg = microscale::runtime::qconfig::PerLayerQConfig::uniform(
        microscale::runtime::QConfig::fp4("ue5m3")?,
    );
    let model = std::sync::Arc::new(microscale::serve::PackedModel::build(
        &dims,
        &params,
        &qcfg,
        16,
        microscale::serve::operand_cache(),
    )?);
    let engine = microscale::serve::ServeEngine::start(
        model,
        microscale::serve::EngineConfig::default(),
    )?;
    let mut handles = Vec::new();
    for _ in 0..8 {
        let toks: Vec<i32> = (0..dims.seq_len)
            .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
            .collect();
        handles.push(engine.submit(toks)?);
    }
    for h in handles {
        let logits = h.wait()?;
        assert_eq!(logits.len(), dims.seq_len * dims.vocab);
    }
    let stats = engine.shutdown();
    println!(
        "ServeEngine: {} requests served ({} batches, mean batch {:.1}), \
         p50 {:.2} ms, p99 {:.2} ms ✓\n",
        stats.requests, stats.batches, stats.mean_batch, stats.p50_ms,
        stats.p99_ms,
    );

    // 6) Generate: KV-cached continuous-batching decode over the same
    //    prepacked weights (operand-cache hit — nothing re-encodes).
    //    Every step's logits are bit-identical to re-running the full
    //    prefix; streams replay exactly from their seeds.
    let model = std::sync::Arc::new(microscale::serve::PackedModel::build(
        &dims,
        &params,
        &qcfg,
        16,
        microscale::serve::operand_cache(),
    )?);
    let mut sched = microscale::serve::Scheduler::new(
        microscale::serve::DecodeEngine::new(model)?,
        microscale::serve::SchedulerConfig::default(),
    );
    for id in 0..4u64 {
        let prompt: Vec<i32> = (0..4)
            .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
            .collect();
        sched.submit(microscale::serve::DecodeRequest {
            id,
            prompt,
            max_new_tokens: 8,
            eos: None,
            sampling: if id % 2 == 0 {
                microscale::serve::Sampling::Greedy
            } else {
                microscale::serve::Sampling::Temperature {
                    temp: 0.8,
                    seed: 40 + id,
                }
            },
            priority: microscale::serve::Priority::Interactive,
        })?;
    }
    for r in sched.run()? {
        println!(
            "  request {}: {:?} ({:?}, ttft {:.2} ms)",
            r.id,
            r.tokens,
            r.finish,
            r.ttft.as_secs_f64() * 1e3,
        );
    }
    println!("Scheduler: 4 seeded streams generated, KV-cached ✓\n");

    // 7) Memory-bounded generation: the same scheduler over a paged,
    //    byte-budgeted KV pool whose pages quantize the cache itself to
    //    MXFP8 (UE5M3 scales). The budget holds ~1.5 sequences, so
    //    requests queue / evict-and-requeue at capacity instead of
    //    growing memory without bound — and the KV cache costs a
    //    fraction of f32.
    let model = std::sync::Arc::new(microscale::serve::PackedModel::build(
        &dims,
        &params,
        &qcfg,
        16,
        microscale::serve::operand_cache(),
    )?);
    let kv_cfg = microscale::runtime::qconfig::PerLayerQConfig::uniform(
        microscale::runtime::QConfig::named("fp8_e4m3", "ue5m3", false)?,
    );
    let probe =
        microscale::serve::KvPool::build(&dims, &kv_cfg, 16, 4, usize::MAX)?;
    let exact = microscale::serve::KvPool::exact(&dims, 4, usize::MAX)?;
    println!(
        "KvPool codec {}: {} B/position vs {} B/position f32",
        probe.codec_id(0),
        probe.position_bytes(),
        exact.position_bytes(),
    );
    let budget = probe.bytes_for_positions(dims.seq_len) * 3 / 2;
    let pool =
        microscale::serve::KvPool::build(&dims, &kv_cfg, 16, 4, budget)?;
    let mut sched = microscale::serve::Scheduler::new(
        microscale::serve::DecodeEngine::with_pool(model, pool.clone())?,
        microscale::serve::SchedulerConfig::default(),
    );
    for id in 0..4u64 {
        let prompt: Vec<i32> = (0..6)
            .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
            .collect();
        sched.submit(microscale::serve::DecodeRequest {
            id,
            prompt,
            max_new_tokens: 8,
            eos: None,
            sampling: microscale::serve::Sampling::Greedy,
            priority: microscale::serve::Priority::Interactive,
        })?;
    }
    let results = sched.run()?;
    println!(
        "KvPool: {} requests under a {} B budget — peak resident {} B, \
         {} preemptions, accounting drained to {} B ✓\n",
        results.len(),
        pool.budget_bytes(),
        sched.peak_kv_resident_bytes(),
        sched.preemptions(),
        pool.used_bytes(),
    );

    // 8) The serving edge: the same scheduler over a KV pool that
    //    hash-conses shared prompt prefixes (one physical copy for N
    //    requests over one system prompt), then behind a dependency-free
    //    HTTP/1.1 front-end with SSE token streaming.
    let model = std::sync::Arc::new(microscale::serve::PackedModel::build(
        &dims,
        &params,
        &qcfg,
        16,
        microscale::serve::operand_cache(),
    )?);
    let pool = microscale::serve::KvPool::build_with(
        &dims, &kv_cfg, 16, 4, usize::MAX, true, // prefix sharing on
    )?;
    let mut sched = microscale::serve::Scheduler::new(
        microscale::serve::DecodeEngine::with_pool(model, pool.clone())?,
        microscale::serve::SchedulerConfig::default(),
    );
    // Three co-resident requests over one 8-token (2-page) system
    // prompt: the first interns the prefix pages, the other two attach.
    let system_prompt: Vec<i32> = (0..8)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect();
    for id in 0..3u64 {
        let mut prompt = system_prompt.clone();
        prompt.push(id as i32);
        sched.submit(microscale::serve::DecodeRequest {
            id,
            prompt,
            max_new_tokens: 4,
            eos: None,
            sampling: microscale::serve::Sampling::Greedy,
            priority: microscale::serve::Priority::Interactive,
        })?;
    }
    let shared_results = sched.run()?;
    let dedup_hits = pool.stats().dedup_hits;
    assert_eq!(shared_results.len(), 3);
    assert!(dedup_hits >= 4); // 2 prefix pages x 2 attaching requests
    println!(
        "Prefix sharing: 3 requests over one system prompt held one \
         physical copy of its pages ({dedup_hits} page dedup hits) ✓"
    );
    // Same scheduler, now serving over loopback HTTP with SSE.
    let server = microscale::serve::HttpServer::start(sched, "127.0.0.1:0")?;
    let addr = server.addr();
    let mut prompt = system_prompt.clone();
    prompt.push(99);
    let body = format!(
        "{{\"prompt\":{prompt:?},\"max_new_tokens\":6,\"stream\":true}}"
    );
    let stream = std::net::TcpStream::connect(addr)?;
    let mut w = &stream;
    microscale::serve::net::write_request(
        &mut w,
        "POST",
        "/v1/completions",
        body.as_bytes(),
    )?;
    let mut r = std::io::BufReader::new(stream.try_clone()?);
    let (status, _) = microscale::serve::net::read_response_head(&mut r)?;
    assert_eq!(status, 200);
    let mut events = 0;
    while microscale::serve::net::read_chunk(&mut r)?.is_some() {
        events += 1;
    }
    assert!(events >= 7); // 6 token events + the terminal done event
    server.shutdown();
    assert_eq!(pool.used_bytes(), 0);
    println!(
        "HttpServer: streamed a completion over {addr} as {events} SSE \
         events; pool drained to 0 B ✓\n"
    );

    // 9) The same quantizer as an AOT Pallas kernel through PJRT
    //    (optional: needs `make artifacts` and a native PJRT build).
    let manifest = match Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            println!(
                "Skipping the PJRT step (run `make artifacts` to enable): {e}"
            );
            return Ok(());
        }
    };
    let session = match Session::open(manifest) {
        Ok(s) => s,
        Err(e) => {
            println!("Skipping the PJRT step (no native runtime): {e}");
            return Ok(());
        }
    };
    let input = rng.normal_vec_f32(128 * 128, 0.02);
    let out = session.run(
        "kernel_fq",
        &[microscale::runtime::session::HostTensor::F32(
            vec![128, 128],
            input.clone(),
        )],
    )?;
    let y = out[0].to_vec::<f32>()?;
    let want = fake_quant(
        &QuantScheme::new(ElemFormat::FP4, microscale::formats::UE4M3, 16),
        &input,
    );
    assert!(y.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    println!("Pallas kernel artifact == Rust quantizer, bit-for-bit ✓");
    Ok(())
}
