//! Serving-edge acceptance suite (ISSUE-8): the HTTP front-end over
//! the real scheduler, driven through loopback sockets.
//!
//! Pins the load-bearing guarantees of the serving edge:
//!
//! 1. **Oracle exactness over the wire** — tokens served by
//!    `POST /v1/completions` (greedy and seeded sampling, both
//!    response modes) are bit-identical to the cache-free
//!    `generate_reforward` oracle; HTTP framing, concurrency, and
//!    priority classes cannot change a stream.
//! 2. **SSE streaming** — the chunked `text/event-stream` response
//!    delivers one event per token and the terminal `done` event
//!    repeats exactly the streamed tokens.
//! 3. **Disconnect cancellation** — a client that hangs up mid-stream
//!    leaves nothing behind: the pool drains to 0 bytes and other
//!    in-flight requests complete bit-identically.
//! 4. **Robustness** — malformed bodies get 400s, unknown routes 404s,
//!    and the stats/health endpoints answer while work is in flight.
//! 5. **Keep-alive** — one socket serves many requests in order; the
//!    `Connection` header is always truthful and a client-requested
//!    close actually closes.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use microscale::dist::Pcg64;
use microscale::model::Params;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::decode::generate_reforward;
use microscale::serve::net;
use microscale::serve::packed_model::PackedModel;
use microscale::serve::{
    DecodeEngine, HttpServer, KvPool, Sampling, Scheduler, SchedulerConfig,
};
use microscale::util::json::Json;

fn dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 48,
    }
}

fn model(seed: u64) -> Arc<PackedModel> {
    let d = dims();
    let params = Params::init_surrogate(&d, seed);
    let qcfg =
        PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    Arc::new(
        PackedModel::build(
            &d,
            &params,
            &qcfg,
            16,
            microscale::serve::operand_cache(),
        )
        .unwrap(),
    )
}

fn start(
    m: &Arc<PackedModel>,
    pool: Option<Arc<KvPool>>,
) -> HttpServer {
    let engine = match pool {
        Some(p) => DecodeEngine::with_pool(m.clone(), p).unwrap(),
        None => DecodeEngine::new(m.clone()).unwrap(),
    };
    let sched = Scheduler::new(engine, SchedulerConfig::default());
    HttpServer::start(sched, "127.0.0.1:0").unwrap()
}

/// One request/response exchange on a fresh connection.
fn exchange(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &[u8],
) -> net::Response {
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = &stream;
    net::write_request(&mut w, method, path, body, false).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    net::read_response(&mut r).unwrap()
}

fn body_json(resp: &net::Response) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

fn tokens_field(j: &Json, key: &str) -> Vec<i32> {
    j.get(key)
        .unwrap()
        .as_f64_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect()
}

fn prompt_json(prompt: &[i32]) -> String {
    let items: Vec<String> =
        prompt.iter().map(|t| t.to_string()).collect();
    format!("[{}]", items.join(","))
}

/// Parse one SSE chunk (`data: {..}\n\n`) into its JSON payload.
fn sse_payload(chunk: &[u8]) -> Json {
    let text = std::str::from_utf8(chunk).unwrap();
    let data = text
        .trim()
        .strip_prefix("data: ")
        .unwrap_or_else(|| panic!("not an SSE event: {text:?}"));
    Json::parse(data).unwrap()
}

#[test]
fn health_stats_and_error_routes_answer() {
    let m = model(70);
    let server = start(&m, None);
    let addr = server.addr();

    let resp = exchange(addr, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);
    assert!(body_json(&resp).get("ok").unwrap().as_bool().unwrap());

    let resp = exchange(addr, "GET", "/stats", b"");
    assert_eq!(resp.status, 200);
    let j = body_json(&resp);
    for key in ["pending", "active", "preempted", "kv_used_bytes"] {
        assert_eq!(j.get(key).unwrap().as_usize().unwrap(), 0, "{key}");
    }

    let resp = exchange(addr, "GET", "/nope", b"");
    assert_eq!(resp.status, 404);
    assert!(body_json(&resp).opt("error").is_some());

    // Malformed completion bodies are 400s with a reason, and leave
    // the server fully operational.
    for bad in [
        &b"not json"[..],
        br#"{"max_new_tokens": 4}"#,
        br#"{"prompt": [1], "priority": "urgent"}"#,
    ] {
        let resp = exchange(addr, "POST", "/v1/completions", bad);
        assert_eq!(resp.status, 400, "{bad:?}");
        assert!(body_json(&resp).opt("error").is_some());
    }
    let resp = exchange(addr, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);
    server.shutdown();
}

#[test]
fn keep_alive_serves_many_requests_on_one_socket() {
    let m = model(75);
    let server = start(&m, None);
    let addr = server.addr();

    // One TCP connection, several requests: the server must answer
    // each in order and keep the socket open until the client asks
    // for `Connection: close`.
    let stream = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());

    for i in 0..3 {
        let mut w = &stream;
        net::write_request(&mut w, "GET", "/healthz", b"", true).unwrap();
        let resp = net::read_response(&mut r).unwrap();
        assert_eq!(resp.status, 200, "request {i}");
        assert_eq!(resp.header("connection"), Some("keep-alive"));
        assert!(body_json(&resp).get("ok").unwrap().as_bool().unwrap());
    }

    // A completion works mid-connection too — keep-alive is not
    // limited to the trivial routes.
    let body = format!(
        "{{\"prompt\":{},\"max_new_tokens\":3}}",
        prompt_json(&[1, 2, 3])
    );
    let mut w = &stream;
    net::write_request(
        &mut w,
        "POST",
        "/v1/completions",
        body.as_bytes(),
        true,
    )
    .unwrap();
    let resp = net::read_response(&mut r).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("connection"), Some("keep-alive"));
    assert_eq!(tokens_field(&body_json(&resp), "tokens").len(), 3);

    // The final request opts out; the server advertises the close
    // and then actually closes (EOF on the next read).
    let mut w = &stream;
    net::write_request(&mut w, "GET", "/stats", b"", false).unwrap();
    let resp = net::read_response(&mut r).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("connection"), Some("close"));
    let mut rest = Vec::new();
    use std::io::Read;
    r.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server wrote past the closing response");
    server.shutdown();
}

#[test]
fn served_completions_match_the_reforward_oracle() {
    let m = model(71);
    let server = start(&m, None);
    let addr = server.addr();
    let mut rng = Pcg64::new(90);
    let d = dims();

    // greedy, then seeded sampling, then an explicit batch-class
    // request — every served stream must equal the cache-free oracle.
    let cases: Vec<(Vec<i32>, String, Sampling)> = vec![
        (
            (0..5)
                .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
                .collect(),
            String::new(),
            Sampling::Greedy,
        ),
        (
            (0..4)
                .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
                .collect(),
            ",\"temperature\":0.8,\"seed\":11".to_string(),
            Sampling::Temperature { temp: 0.8, seed: 11 },
        ),
        (
            (0..3)
                .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
                .collect(),
            ",\"priority\":\"batch\"".to_string(),
            Sampling::Greedy,
        ),
    ];
    for (i, (prompt, extra, sampling)) in cases.iter().enumerate() {
        let want =
            generate_reforward(&m, prompt, 6, None, sampling).unwrap();
        let body = format!(
            "{{\"prompt\":{},\"max_new_tokens\":6{extra}}}",
            prompt_json(prompt)
        );
        let resp =
            exchange(addr, "POST", "/v1/completions", body.as_bytes());
        assert_eq!(resp.status, 200, "case {i}");
        let j = body_json(&resp);
        assert_eq!(tokens_field(&j, "tokens"), want, "case {i}");
        assert_eq!(
            j.get("finish").unwrap().as_str().unwrap(),
            "max_tokens",
            "case {i}"
        );
        assert_eq!(
            j.get("prompt_len").unwrap().as_usize().unwrap(),
            prompt.len()
        );
        assert_eq!(
            j.get("itl_ms").unwrap().as_arr().unwrap().len(),
            want.len() - 1
        );
        assert!(j.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
        let want_class = if extra.contains("batch") {
            "batch"
        } else {
            "interactive"
        };
        assert_eq!(
            j.get("priority").unwrap().as_str().unwrap(),
            want_class,
            "case {i}"
        );
    }
    server.shutdown();
}

#[test]
fn sse_stream_is_incremental_and_matches_done() {
    let m = model(72);
    let server = start(&m, None);
    let addr = server.addr();
    let mut rng = Pcg64::new(91);
    let d = dims();
    let prompt: Vec<i32> = (0..4)
        .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
        .collect();
    let sampling = Sampling::Temperature { temp: 0.7, seed: 5 };
    let want = generate_reforward(&m, &prompt, 5, None, &sampling).unwrap();

    let body = format!(
        "{{\"prompt\":{},\"max_new_tokens\":5,\"temperature\":0.7,\
         \"seed\":5,\"stream\":true}}",
        prompt_json(&prompt)
    );
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = &stream;
    net::write_request(
        &mut w,
        "POST",
        "/v1/completions",
        body.as_bytes(),
        false,
    )
    .unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let (status, headers) = net::read_response_head(&mut r).unwrap();
    assert_eq!(status, 200);
    assert!(headers.iter().any(|(n, v)| n == "transfer-encoding"
        && v.eq_ignore_ascii_case("chunked")));
    assert!(headers.iter().any(|(n, v)| n == "content-type"
        && v == "text/event-stream"));

    let mut streamed = Vec::new();
    let mut done: Option<Json> = None;
    while let Some(chunk) = net::read_chunk(&mut r).unwrap() {
        let j = sse_payload(&chunk);
        if let Some(t) = j.opt("token") {
            assert!(done.is_none(), "token after done");
            streamed.push(t.as_i64().unwrap() as i32);
        } else {
            done = Some(j.get("done").unwrap().clone());
        }
    }
    let done = done.expect("stream ended without a done event");
    assert_eq!(streamed, want, "streamed tokens vs oracle");
    assert_eq!(tokens_field(&done, "tokens"), want, "done payload");
    assert_eq!(done.get("finish").unwrap().as_str().unwrap(), "max_tokens");
    server.shutdown();
}

#[test]
fn disconnect_mid_stream_drains_the_pool() {
    let d = dims();
    let m = model(73);
    // An Exact pool keeps the oracle comparison valid for the
    // surviving request; generous budget so only cancellation frees.
    let pool = KvPool::exact(&d, 4, usize::MAX).unwrap();
    let server = start(&m, Some(pool.clone()));
    let addr = server.addr();
    let mut rng = Pcg64::new(92);
    let prompt_a: Vec<i32> = (0..4)
        .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
        .collect();
    let prompt_b: Vec<i32> = (0..6)
        .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
        .collect();
    let want_b =
        generate_reforward(&m, &prompt_b, 8, None, &Sampling::Greedy)
            .unwrap();

    // Client A: a long stream (40 tokens to go), abandoned after two.
    let body_a = format!(
        "{{\"prompt\":{},\"max_new_tokens\":40,\"stream\":true}}",
        prompt_json(&prompt_a)
    );
    let stream_a = TcpStream::connect(addr).unwrap();
    {
        let mut w = &stream_a;
        net::write_request(
            &mut w,
            "POST",
            "/v1/completions",
            body_a.as_bytes(),
            false,
        )
        .unwrap();
    }
    let mut ra = BufReader::new(stream_a.try_clone().unwrap());
    let (status, _) = net::read_response_head(&mut ra).unwrap();
    assert_eq!(status, 200);
    for _ in 0..2 {
        let chunk = net::read_chunk(&mut ra).unwrap().unwrap();
        assert!(sse_payload(&chunk).opt("token").is_some());
    }
    // Client B submits while A is (still) streaming, then A hangs up.
    let body_b = format!(
        "{{\"prompt\":{},\"max_new_tokens\":8}}",
        prompt_json(&prompt_b)
    );
    let stream_b = TcpStream::connect(addr).unwrap();
    {
        let mut w = &stream_b;
        net::write_request(
            &mut w,
            "POST",
            "/v1/completions",
            body_b.as_bytes(),
            false,
        )
        .unwrap();
    }
    drop(ra);
    drop(stream_a); // the disconnect: no FIN-before-done handshake

    let mut rb = BufReader::new(stream_b.try_clone().unwrap());
    let resp = net::read_response(&mut rb).unwrap();
    assert_eq!(resp.status, 200);
    let j = body_json(&resp);
    assert_eq!(
        tokens_field(&j, "tokens"),
        want_b,
        "survivor stream must be untouched by the cancellation"
    );

    // The abandoned sequence's pages must drain — poll /stats until
    // the scheduler reports nothing pending, active, or resident.
    let mut drained = false;
    for _ in 0..250 {
        let resp = exchange(addr, "GET", "/stats", b"");
        let j = body_json(&resp);
        let busy = ["pending", "active", "preempted", "kv_used_bytes"]
            .iter()
            .map(|k| j.get(k).unwrap().as_usize().unwrap())
            .sum::<usize>();
        if busy == 0 {
            drained = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(drained, "pool never drained after client disconnect");
    // At most the one abandoned request can have been cancelled (it
    // may also have finished before the hang-up was observed).
    let resp = exchange(addr, "GET", "/stats", b"");
    let cancels =
        body_json(&resp).get("cancellations").unwrap().as_usize().unwrap();
    assert!(cancels <= 1, "cancellations {cancels}");
    server.shutdown();
    assert_eq!(pool.used_bytes(), 0);
    let s = pool.stats();
    assert_eq!(s.allocs, s.frees, "every allocated page was freed");
}

#[test]
fn concurrent_streams_are_all_bit_exact() {
    let d = dims();
    let m = model(74);
    let server = start(&m, None);
    let addr = server.addr();
    let mut rng = Pcg64::new(93);

    // Six clients race over real sockets; each served stream must
    // still equal its own single-request oracle.
    let cases: Vec<(Vec<i32>, Sampling)> = (0..6u64)
        .map(|i| {
            let len = 3 + (i as usize % 3);
            let prompt: Vec<i32> = (0..len)
                .map(|_| (rng.next_u64() % d.vocab as u64) as i32)
                .collect();
            let sampling = if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature { temp: 0.9, seed: 300 + i }
            };
            (prompt, sampling)
        })
        .collect();
    let want: Vec<Vec<i32>> = cases
        .iter()
        .map(|(p, s)| generate_reforward(&m, p, 6, None, s).unwrap())
        .collect();

    let handles: Vec<_> = cases
        .iter()
        .map(|(prompt, sampling)| {
            let extra = match sampling {
                Sampling::Greedy => String::new(),
                Sampling::Temperature { temp, seed } => {
                    format!(",\"temperature\":{temp},\"seed\":{seed}")
                }
            };
            let body = format!(
                "{{\"prompt\":{},\"max_new_tokens\":6{extra}}}",
                prompt_json(prompt)
            );
            std::thread::spawn(move || {
                let resp = exchange(
                    addr,
                    "POST",
                    "/v1/completions",
                    body.as_bytes(),
                );
                assert_eq!(resp.status, 200);
                tokens_field(&body_json(&resp), "tokens")
            })
        })
        .collect();
    for (h, want) in handles.into_iter().zip(&want) {
        assert_eq!(h.join().unwrap(), *want);
    }
    server.shutdown();
}
