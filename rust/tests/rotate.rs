//! Hadamard pre-rotation acceptance suite (DESIGN.md §16).
//!
//! Pins the rotation layer's load-bearing guarantees from outside the
//! crate:
//!
//! 1. **Transform algebra** — the normalized FWHT is self-inverse and
//!    orthonormal, at power-of-two and arbitrary lengths (via the
//!    block-diagonal largest-power-of-two cover), and the cover never
//!    mixes across chunk boundaries.
//! 2. **Exact-config elision** — a rotation flag on a
//!    quantization-off layer is algebraically the identity
//!    (`(xH)(HW) = xW`), so the implementation elides it; the logits
//!    must be BIT-identical to the unrotated exact model, packed and
//!    reference path alike.
//! 3. **Differential gate** — under a quantized config the rotated
//!    packed model stays bit-identical to the rotated scalar
//!    reference (the repo's packed==reference contract survives
//!    rotation), while genuinely changing the quantized logits.
//! 4. **Shard invariance** — rotated + tensor-parallel sharded logits
//!    are bit-identical to the unsharded rotated model.

use microscale::dist::Pcg64;
use microscale::model::weights::Params;
use microscale::quant::rotate::{fwht, fwht_cols, fwht_rows, pow2_chunks};
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::cache::OperandCache;
use microscale::serve::packed_model::{reference_forward, PackedModel};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 12,
    }
}

fn toks(dims: &ModelDims, batch: usize, seed: u64) -> Vec<i32> {
    let mut rng = Pcg64::new(seed);
    (0..batch * dims.seq_len)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect()
}

fn bits(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn fwht_self_inverse_and_orthonormal_any_length() {
    for d in [1usize, 2, 4, 16, 64, 48, 96, 100, 257, 384] {
        let mut rng = Pcg64::new(11 + d as u64);
        let x = rng.normal_vec_f32(d, 1.0);
        let mut y = x.clone();
        fwht(&mut y);
        // orthonormal: ‖Hx‖₂ = ‖x‖₂
        let n0: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        let n1: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!(
            (n1 - n0).abs() < 1e-3 * n0.max(1.0),
            "d={d}: ‖Hx‖²={n1} vs ‖x‖²={n0}"
        );
        // self-inverse: H(Hx) = x
        fwht(&mut y);
        for i in 0..d {
            assert!(
                (y[i] - x[i]).abs() <= 1e-4 * x[i].abs().max(1.0),
                "d={d} i={i}: {} vs {}",
                y[i],
                x[i]
            );
        }
    }
}

#[test]
fn non_power_of_two_cover_is_block_diagonal() {
    // the cover is the binary expansion of d...
    for d in [3usize, 12, 100, 257] {
        let chunks = pow2_chunks(d);
        assert_eq!(chunks.iter().map(|(_, l)| l).sum::<usize>(), d);
        let mut expect_off = 0;
        let mut prev = usize::MAX;
        for &(off, len) in &chunks {
            assert_eq!(off, expect_off, "d={d}");
            assert!(len.is_power_of_two() && len < prev, "d={d}");
            expect_off += len;
            prev = len;
        }
        // ...and a basis vector inside one chunk never leaks outside it
        for &(off, len) in &chunks {
            let mut e = vec![0.0f32; d];
            e[off] = 1.0;
            fwht(&mut e);
            for (i, v) in e.iter().enumerate() {
                let inside = i >= off && i < off + len;
                assert_eq!(
                    *v != 0.0,
                    inside,
                    "d={d}: chunk ({off},{len}) leaked to {i}"
                );
            }
        }
    }
}

#[test]
fn exact_config_elides_rotation_bit_identically() {
    // Rotation on a quantization-off layer is the algebraic identity,
    // so the implementation must elide it entirely: same bits, packed
    // and reference paths, with and without the flag.
    let dims = dims();
    let params = Params::init_surrogate(&dims, 21);
    let cache = OperandCache::new(32);
    let tokens = toks(&dims, 2, 5);
    let plain = PerLayerQConfig::uniform(QConfig::baseline());
    let rotated =
        PerLayerQConfig::uniform(QConfig::baseline().with_rotate(true));
    let m0 = PackedModel::build(&dims, &params, &plain, 16, &cache).unwrap();
    let m1 =
        PackedModel::build(&dims, &params, &rotated, 16, &cache).unwrap();
    let y0 = m0.forward(&tokens, 2, dims.seq_len).unwrap();
    let y1 = m1.forward(&tokens, 2, dims.seq_len).unwrap();
    assert_eq!(bits(&y0), bits(&y1), "packed path must elide rotation");
    let r0 = reference_forward(
        &params, &dims, &plain, 16, &tokens, 2, dims.seq_len,
    )
    .unwrap();
    let r1 = reference_forward(
        &params, &dims, &rotated, 16, &tokens, 2, dims.seq_len,
    )
    .unwrap();
    assert_eq!(bits(&r0), bits(&r1), "reference path must elide rotation");
    assert_eq!(bits(&y0), bits(&r0), "packed vs reference exact");
}

#[test]
fn rotated_packed_matches_rotated_reference_and_changes_logits() {
    let dims = dims();
    let params = Params::init_surrogate(&dims, 22);
    let cache = OperandCache::new(32);
    let tokens = toks(&dims, 2, 6);
    let base = QConfig::fp4("ue4m3").unwrap();
    for bs in [8usize, 16] {
        let plain = PerLayerQConfig::uniform(base);
        let rot = PerLayerQConfig::uniform(base.with_rotate(true));
        let packed =
            PackedModel::build(&dims, &params, &rot, bs, &cache).unwrap();
        let y = packed.forward(&tokens, 2, dims.seq_len).unwrap();
        let r = reference_forward(
            &params, &dims, &rot, bs, &tokens, 2, dims.seq_len,
        )
        .unwrap();
        assert_eq!(bits(&y), bits(&r), "bs={bs}: packed vs reference");
        // rotation must actually change the quantized computation
        let mp =
            PackedModel::build(&dims, &params, &plain, bs, &cache).unwrap();
        let yp = mp.forward(&tokens, 2, dims.seq_len).unwrap();
        assert_ne!(
            bits(&y),
            bits(&yp),
            "bs={bs}: rotated logits should differ under quantization"
        );
    }
}

#[test]
fn rotated_sharded_is_bit_identical_to_unsharded() {
    let dims = dims();
    let params = Params::init_surrogate(&dims, 23);
    let cache = OperandCache::new(64);
    let tokens = toks(&dims, 2, 7);
    let rot = PerLayerQConfig::uniform(
        QConfig::fp4("ue4m3").unwrap().with_rotate(true),
    );
    let whole =
        PackedModel::build_sharded(&dims, &params, &rot, 16, &cache, 1)
            .unwrap();
    let want = whole.forward(&tokens, 2, dims.seq_len).unwrap();
    for shards in [2usize, 4] {
        let m = PackedModel::build_sharded(
            &dims, &params, &rot, 16, &cache, shards,
        )
        .unwrap();
        let got = m.forward(&tokens, 2, dims.seq_len).unwrap();
        assert_eq!(bits(&want), bits(&got), "shards={shards}");
    }
}

#[test]
fn weight_rotation_commutes_with_column_slicing() {
    // the sharding contract: rotating then slicing columns equals
    // slicing then rotating (H acts on the contraction dim only)
    let (k, n) = (32usize, 12);
    let mut rng = Pcg64::new(31);
    let w = rng.normal_vec_f32(k * n, 1.0);
    let full = fwht_cols(&w, k, n);
    let (c0, c1) = (3usize, 9);
    let cols = c1 - c0;
    let mut sliced = vec![0.0f32; k * cols];
    for i in 0..k {
        sliced[i * cols..(i + 1) * cols]
            .copy_from_slice(&w[i * n + c0..i * n + c1]);
    }
    let sliced_rot = fwht_cols(&sliced, k, cols);
    for i in 0..k {
        for j in 0..cols {
            assert_eq!(
                sliced_rot[i * cols + j].to_bits(),
                full[i * n + c0 + j].to_bits(),
                "({i},{j})"
            );
        }
    }
}

#[test]
fn activation_rotation_is_per_row() {
    // fwht_rows on a 2-row matrix equals fwht on each row separately —
    // the decode path's guarantee that rotation cannot couple
    // positions (KV/decode invariance rides on this)
    let d = 48usize;
    let mut rng = Pcg64::new(33);
    let x = rng.normal_vec_f32(2 * d, 1.0);
    let mut both = x.clone();
    fwht_rows(&mut both, d);
    for r in 0..2 {
        let mut one = x[r * d..(r + 1) * d].to_vec();
        fwht(&mut one);
        for i in 0..d {
            assert_eq!(
                one[i].to_bits(),
                both[r * d + i].to_bits(),
                "row {r} elem {i}"
            );
        }
    }
}
