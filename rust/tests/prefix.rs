//! Prefix-sharing acceptance suite (ISSUE-8): hash-consed KV pages
//! under the real scheduler.
//!
//! Pins the tentpole's contracts at the integration level:
//!
//! 1. **Exactly one copy** — N requests over one page-aligned prompt
//!    hold one physical copy of its pages, verified on real pool
//!    counters (`used_bytes`, `shared_bytes`, `dedup_hits`), and the
//!    pool drains to 0 when the last reference drops.
//! 2. **Bit-identical streams** — a shared-prefix backlog driven
//!    through the scheduler produces exactly the token streams of an
//!    unshared pool, across the {FP8, FP4} × {UE4M3, UE5M3} KV codec
//!    grid, under eviction pressure (tight budget) and a mid-flight
//!    cancellation. Sharing changes admission dynamics (freed pages
//!    admit sooner), so matching streams is a real invariant, not a
//!    tautology.
//! 3. **Copy-on-write forks** — [`SeqKv::fork`] shares the resident
//!    prefix by refcount; divergence after the fork writes only
//!    private tail pages and never perturbs either stream's logits.

use std::sync::Arc;

use microscale::dist::Pcg64;
use microscale::model::Params;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::packed_model::PackedModel;
use microscale::serve::scheduler::{
    DecodeRequest, DecodeResult, Priority, Scheduler, SchedulerConfig,
};
use microscale::serve::{DecodeEngine, KvPool, Sampling};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 32,
    }
}

const PAGE_ROWS: usize = 4;

fn model(seed: u64) -> Arc<PackedModel> {
    let d = dims();
    let params = Params::init_surrogate(&d, seed);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    Arc::new(
        PackedModel::build(
            &d,
            &params,
            &qcfg,
            16,
            microscale::serve::operand_cache(),
        )
        .unwrap(),
    )
}

fn tokens(rng: &mut Pcg64, count: usize) -> Vec<i32> {
    let vocab = dims().vocab as u64;
    (0..count).map(|_| (rng.next_u64() % vocab) as i32).collect()
}

fn kv_grid() -> Vec<(String, PerLayerQConfig)> {
    let mut grid = Vec::new();
    for scale in ["ue4m3", "ue5m3"] {
        grid.push((
            format!("fp8/{scale}"),
            PerLayerQConfig::uniform(
                QConfig::named("fp8_e4m3", scale, false).unwrap(),
            ),
        ));
        grid.push((
            format!("fp4/{scale}"),
            PerLayerQConfig::uniform(QConfig::fp4(scale).unwrap()),
        ));
    }
    grid
}

/// Submit everything, then step to completion, cancelling `cancel_id`
/// after `cancel_at` steps. Returns results sorted by id.
fn drive(
    model: &Arc<PackedModel>,
    pool: &Arc<KvPool>,
    reqs: &[DecodeRequest],
    cfg: SchedulerConfig,
    cancel_id: u64,
    cancel_at: usize,
) -> Vec<DecodeResult> {
    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model.clone(), pool.clone()).unwrap(),
        cfg,
    );
    for r in reqs {
        sched.submit(r.clone()).unwrap();
    }
    let mut steps = 0usize;
    while !sched.is_idle() {
        if steps == cancel_at {
            sched.cancel(cancel_id);
            if sched.is_idle() {
                break;
            }
        }
        sched.step().unwrap();
        steps += 1;
        assert!(steps < 100_000, "backlog failed to converge");
    }
    sched.take_finished()
}

#[test]
fn n_prefills_of_one_prompt_hold_exactly_one_copy() {
    let d = dims();
    let m = model(80);
    let mut rng = Pcg64::new(100);
    let prompt = tokens(&mut rng, 2 * PAGE_ROWS); // page-aligned
    for (label, kv_cfg) in kv_grid() {
        let pool = KvPool::build_with(
            &d, &kv_cfg, 16, PAGE_ROWS, usize::MAX, true,
        )
        .unwrap();
        let engine =
            DecodeEngine::with_pool(m.clone(), pool.clone()).unwrap();
        let mut kvs = Vec::new();
        for _ in 0..4 {
            let mut kv = engine.new_kv();
            engine.prefill(&prompt, &mut kv).unwrap();
            kvs.push(kv);
        }
        let one_seq = pool.bytes_for_positions(prompt.len());
        let stats = pool.stats();
        assert_eq!(stats.used_bytes, one_seq, "{label}: physical bytes");
        assert_eq!(
            stats.shared_bytes,
            3 * one_seq,
            "{label}: 3 duplicate sequences' worth shared"
        );
        // 3 later sequences x 2 full pages x 2 layers (K and V rows
        // live in the same page here — count via hits being positive
        // and exact byte accounting above)
        assert!(stats.dedup_hits > 0, "{label}");
        drop(kvs);
        let stats = pool.stats();
        assert_eq!(stats.used_bytes, 0, "{label}: drain");
        assert_eq!(stats.allocs, stats.frees, "{label}: page ledger");
    }
}

#[test]
fn shared_streams_match_unshared_across_the_codec_grid() {
    let d = dims();
    let m = model(81);
    let mut rng = Pcg64::new(101);
    for (label, kv_cfg) in kv_grid() {
        let prefix = tokens(&mut rng, 2 * PAGE_ROWS);
        let reqs: Vec<DecodeRequest> = (0..6u64)
            .map(|id| {
                let mut prompt =
                    if id < 4 { prefix.clone() } else { Vec::new() };
                let tail = 1 + (rng.next_u64() % 3) as usize;
                prompt.extend(tokens(&mut rng, tail));
                DecodeRequest {
                    id,
                    prompt,
                    max_new_tokens: 5,
                    eos: None,
                    sampling: Sampling::Temperature {
                        temp: 0.9,
                        seed: 0xC0 ^ id,
                    },
                    priority: if id % 3 == 0 {
                        Priority::Batch
                    } else {
                        Priority::Interactive
                    },
                }
            })
            .collect();
        // tight budget: ~1.2 sequences forces queueing and eviction
        let probe = KvPool::build_with(
            &d, &kv_cfg, 16, PAGE_ROWS, usize::MAX, false,
        )
        .unwrap();
        let budget = (probe.bytes_for_positions(d.seq_len) as f64 * 1.2)
            .ceil() as usize;
        let cfg = SchedulerConfig {
            max_active: 3,
            max_prefill_per_step: 2,
            max_prefill_tokens: 2 * PAGE_ROWS, // chunked prefill too
        };
        let shared = KvPool::build_with(
            &d, &kv_cfg, 16, PAGE_ROWS, budget, true,
        )
        .unwrap();
        let unshared = KvPool::build_with(
            &d, &kv_cfg, 16, PAGE_ROWS, budget, false,
        )
        .unwrap();
        let got = drive(&m, &shared, &reqs, cfg, 1, 3);
        let want = drive(&m, &unshared, &reqs, cfg, 1, 3);
        assert_eq!(got.len(), want.len(), "{label}: finished count");
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.id, b.id, "{label}");
            assert_eq!(
                a.tokens, b.tokens,
                "{label}: request {} diverges under sharing",
                a.id
            );
            assert_eq!(a.finish, b.finish, "{label}: request {}", a.id);
        }
        let s = shared.stats();
        assert!(s.dedup_hits > 0, "{label}: no pages were ever shared");
        // Peak physical bytes are not compared across pools (sharing
        // admits more sequences, so its transient high-water mark can
        // sit a page-granule above the unshared pool's); the hard
        // invariant is the budget bound.
        assert!(s.peak_bytes <= budget, "{label}: shared budget bound");
        assert!(
            unshared.stats().peak_bytes <= budget,
            "{label}: unshared budget bound"
        );
        assert_eq!(shared.used_bytes(), 0, "{label}: shared drain");
        assert_eq!(unshared.used_bytes(), 0, "{label}: unshared drain");
    }
}

#[test]
fn forks_share_the_prefix_and_diverge_copy_on_write() {
    let d = dims();
    let m = model(82);
    let mut rng = Pcg64::new(102);
    // Exact pages so forked continuations can be checked bit-for-bit
    // against fresh unforked caches.
    let pool = {
        let kv_cfg = PerLayerQConfig::uniform(QConfig::baseline());
        KvPool::build_with(&d, &kv_cfg, 16, PAGE_ROWS, usize::MAX, true)
            .unwrap()
    };
    let engine = DecodeEngine::with_pool(m.clone(), pool.clone()).unwrap();
    let prompt = tokens(&mut rng, 2 * PAGE_ROWS);
    let (x, y) = (1i32, 2i32);

    let mut kv_a = engine.new_kv();
    engine.prefill(&prompt, &mut kv_a).unwrap();
    let mut kv_b = kv_a.fork().unwrap();
    let one_seq = pool.bytes_for_positions(prompt.len());
    let stats = pool.stats();
    assert_eq!(stats.used_bytes, one_seq, "fork copies nothing");
    assert_eq!(stats.shared_bytes, one_seq);

    // Diverge: each fork appends a different token into its own
    // private tail page; the shared prefix pages stay immutable.
    let la =
        engine.step(&[x], std::slice::from_mut(&mut kv_a)).unwrap();
    let lb =
        engine.step(&[y], std::slice::from_mut(&mut kv_b)).unwrap();
    assert_eq!((kv_a.len(), kv_b.len()), (prompt.len() + 1, prompt.len() + 1));
    let tail_page =
        pool.bytes_for_positions(prompt.len() + 1) - one_seq;
    let stats = pool.stats();
    assert_eq!(
        stats.used_bytes,
        one_seq + 2 * tail_page,
        "one shared prefix + two private tails"
    );
    assert_eq!(stats.shared_bytes, one_seq, "tails are never shared");

    // Neither continuation was perturbed by the other: both equal a
    // fresh, never-forked cache fed the same tokens.
    for (tok, got) in [(x, &la), (y, &lb)] {
        let mut fresh = engine.new_kv();
        engine.prefill(&prompt, &mut fresh).unwrap();
        let want = engine
            .step(&[tok], std::slice::from_mut(&mut fresh))
            .unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "fork divergence perturbed logit {i} after token {tok}"
            );
        }
    }
    drop(kv_a);
    drop(kv_b);
    let stats = pool.stats();
    assert_eq!(stats.used_bytes, 0, "drain");
    assert_eq!(stats.allocs, stats.frees, "page ledger");
}
