//! Cross-language bit-exactness: the Rust quantizer vs the python oracle
//! (`ref.py`), via golden vectors emitted by `python/compile/aot.py` into
//! `artifacts/golden/quant_golden.json`.
//!
//! Every minifloat cast and every block fake-quant case must match
//! BIT-FOR-BIT — the whole experiment stack relies on the two
//! implementations being interchangeable.

use microscale::formats::{scale_format, ElemFormat, MiniFloat};
use microscale::quant::gemm::GemmOperand;
use microscale::quant::{fake_quant, PackedMxTensor, QuantScheme};
use microscale::util::json::Json;

/// Golden vectors are produced by `make artifacts` (python build step)
/// and are not checked into the repo; absent vectors skip the test with a
/// note rather than failing a source-only checkout (see EXPERIMENTS.md).
fn load() -> Option<Json> {
    let path = "artifacts/golden/quant_golden.json";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping golden test: {path} not present (run `make artifacts`)");
        return None;
    }
    let text = std::fs::read_to_string(path).expect("golden file readable");
    Some(Json::parse(&text).expect("golden file parses"))
}

#[test]
fn golden_minifloat_casts_bit_exact() {
    let Some(g) = load() else { return };
    let mut checked = 0usize;
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        if case.get("kind").unwrap().as_str().unwrap() != "cast" {
            continue;
        }
        let fmt = MiniFloat {
            m_bits: case.get("m_bits").unwrap().as_i64().unwrap() as i32,
            e_min: case.get("e_min").unwrap().as_i64().unwrap() as i32,
            max_val: case.get("max_val").unwrap().as_f64().unwrap() as f32,
            name: "golden",
        };
        let xs = case.get("x").unwrap().as_f32_vec().unwrap();
        let ys = case.get("y").unwrap().as_f32_vec().unwrap();
        let reg = scale_format(case.get("fmt").unwrap().as_str().unwrap());
        for (x, y) in xs.iter().zip(&ys) {
            let got = fmt.cast(*x);
            assert_eq!(
                got.to_bits(),
                y.to_bits(),
                "fmt {:?} x={x}: got {got}, want {y}",
                case.get("fmt").unwrap()
            );
            // the registry entry (if present) must agree with the golden
            // file's parameters
            if let Some(r) = reg {
                assert_eq!(r.cast(*x).to_bits(), y.to_bits());
            }
        }
        checked += xs.len();
    }
    assert!(checked > 1000, "only {checked} cast points checked");
}

#[test]
fn golden_fake_quant_bit_exact() {
    let Some(g) = load() else { return };
    let mut checked = 0usize;
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        if case.get("kind").unwrap().as_str().unwrap() != "fake_quant" {
            continue;
        }
        let elem =
            ElemFormat::from_name(case.get("elem").unwrap().as_str().unwrap())
                .unwrap();
        let scale =
            scale_format(case.get("scale").unwrap().as_str().unwrap())
                .unwrap();
        let bs = case.get("block_size").unwrap().as_usize().unwrap();
        let pt = case.get("per_tensor").unwrap().as_bool().unwrap();
        let scheme =
            QuantScheme::new(elem, scale, bs).with_per_tensor(pt);
        let xs = case.get("x").unwrap().as_f32_vec().unwrap();
        let ys = case.get("y").unwrap().as_f32_vec().unwrap();
        let got = fake_quant(&scheme, &xs);
        for (i, (a, b)) in got.iter().zip(&ys).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{} elem {}: got {a}, want {b} (x={})",
                scheme.id(),
                i,
                xs[i]
            );
        }
        checked += 1;
    }
    assert!(checked > 100, "only {checked} fake-quant cases");
}

/// The `ue5m3_edge` vectors (subnormal scales, the s_min/2 collapse tie,
/// overflow clamp, amax = 0 blocks — see `ref.ue5m3_edge_blocks`) must be
/// reproduced bit-for-bit by every Rust encoding of the quantizer: the
/// scalar reference, the bit-packed tensor codec, and the GEMM operand
/// encoder the packed-native engine multiplies on.
#[test]
fn golden_ue5m3_edge_cases_pin_every_encoder() {
    let Some(g) = load() else { return };
    let mut checked = 0usize;
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        let tagged = case
            .opt("tag")
            .and_then(|t| t.as_str().ok())
            .is_some_and(|t| t == "ue5m3_edge");
        if !tagged {
            continue;
        }
        let elem =
            ElemFormat::from_name(case.get("elem").unwrap().as_str().unwrap())
                .unwrap();
        let scale =
            scale_format(case.get("scale").unwrap().as_str().unwrap()).unwrap();
        let bs = case.get("block_size").unwrap().as_usize().unwrap();
        let pt = case.get("per_tensor").unwrap().as_bool().unwrap();
        let scheme = QuantScheme::new(elem, scale, bs).with_per_tensor(pt);
        let xs = case.get("x").unwrap().as_f32_vec().unwrap();
        let ys = case.get("y").unwrap().as_f32_vec().unwrap();

        let check = |name: &str, got: &[f32]| {
            assert_eq!(got.len(), ys.len(), "{name} {}", scheme.id());
            for (i, (a, b)) in got.iter().zip(&ys).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{name} {} elem {i}: got {a}, want {b} (x={})",
                    scheme.id(),
                    xs[i]
                );
            }
        };
        check("fake_quant", &fake_quant(&scheme, &xs));
        let packed = PackedMxTensor::encode(&scheme, &xs)
            .expect("edge vectors must stay packable");
        check("packed roundtrip", &packed.decode());
        let op = GemmOperand::quantize(&scheme, &xs, 1, xs.len())
            .expect("edge vectors must stay GEMM-packable");
        check("gemm operand", &op.decode());
        checked += 1;
    }
    if checked == 0 {
        // artifacts predate the edge vectors: skip like every other
        // artifact-dependent test (CI always regenerates, so the
        // presence of all 8 cases is still enforced there)
        eprintln!(
            "skipping ue5m3_edge golden checks: artifacts predate these \
             vectors (regenerate with `make artifacts` / aot.py --golden-only)"
        );
        return;
    }
    assert!(
        checked >= 8,
        "only {checked} ue5m3_edge cases — partially regenerated artifacts?"
    );
}
