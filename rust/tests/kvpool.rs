//! Paged-KV subsystem acceptance suite (ISSUE-5).
//!
//! Pins the load-bearing guarantees of the byte-budgeted KV pool:
//!
//! 1. **Admission blocks at capacity** — with a pool sized for one
//!    in-flight sequence, requests queue (FIFO) instead of failing, and
//!    every stream still equals the cache-free full-prefix oracle.
//! 2. **Evict-and-requeue is invisible in the tokens** — forcing
//!    mid-generation evictions (budget < combined working set) changes
//!    no stream under the Exact codec, and preemptions really happen.
//! 3. **Byte accounting is exact** — the pool's `used_bytes` equals the
//!    scheduler's resident total at every step and returns to zero
//!    (allocs == frees) after every run.
//! 4. **Mx codec differential matrix** — over {FP8, FP4} × {UE4M3,
//!    UE5M3} × block sizes {8, 32}: token-by-token stepping is
//!    bit-identical to one whole-prefix call under the same codec, and
//!    the quantized-KV logits error against the Exact codec is nonzero
//!    but bounded (FP8 well under FP4).

use std::sync::Arc;

use microscale::dist::Pcg64;
use microscale::model::Params;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::cache::OperandCache;
use microscale::serve::decode::generate_reforward;
use microscale::serve::packed_model::PackedModel;
use microscale::serve::scheduler::{
    DecodeRequest, FinishReason, Priority, Scheduler, SchedulerConfig,
};
use microscale::serve::{DecodeEngine, KvPool, Sampling};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 16,
    }
}

fn model(seed: u64, qcfg: &PerLayerQConfig) -> Arc<PackedModel> {
    let d = dims();
    let params = Params::init_surrogate(&d, seed);
    let cache = OperandCache::new(256);
    Arc::new(PackedModel::build(&d, &params, qcfg, 8, &cache).unwrap())
}

fn tokens(rng: &mut Pcg64, count: usize) -> Vec<i32> {
    let v = dims().vocab as u64;
    (0..count).map(|_| (rng.next_u64() % v) as i32).collect()
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> DecodeRequest {
    DecodeRequest {
        id,
        prompt,
        max_new_tokens: max_new,
        eos: None,
        sampling: if id % 2 == 0 {
            Sampling::Greedy
        } else {
            Sampling::Temperature { temp: 0.8, seed: 900 + id }
        },
        priority: Priority::Interactive,
    }
}

#[test]
fn admission_blocks_at_capacity_and_streams_match_the_oracle() {
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model = model(61, &qcfg);
    // page math: d_model 32 → 128 B/row, 2 rows/page → 256 B/page;
    // one full 16-position sequence = 8 pages × 4 streams = 8192 B
    let pool = KvPool::exact(&dims(), 2, 8192).unwrap();
    assert_eq!(pool.bytes_for_positions(16), 8192);

    let mut rng = Pcg64::new(70);
    let reqs: Vec<DecodeRequest> =
        (0..3).map(|id| req(id, tokens(&mut rng, 10), 4)).collect();
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            generate_reforward(
                &model,
                &r.prompt,
                r.max_new_tokens,
                r.eos,
                &r.sampling,
            )
            .unwrap()
        })
        .collect();

    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model, pool.clone()).unwrap(),
        SchedulerConfig {
            max_active: 8,
            max_prefill_per_step: 8,
            ..SchedulerConfig::default()
        },
    );
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    // a 10-token prefill takes 5120 B, so a second one (another 5120 B)
    // cannot fit: admission must block, not error
    sched.step().unwrap();
    assert_eq!(sched.active(), 1, "only one sequence fits the budget");
    assert_eq!(sched.pending(), 2, "the rest queue FIFO");
    assert!(pool.used_bytes() <= pool.budget_bytes());
    assert_eq!(sched.kv_resident_bytes(), pool.used_bytes());

    let results = sched.run().unwrap();
    assert_eq!(results.len(), 3);
    for (r, w) in results.iter().zip(&want) {
        assert_eq!(r.tokens, *w, "request {} stream", r.id);
        assert_eq!(r.finish, FinishReason::MaxTokens);
    }
    assert!(sched.peak_kv_resident_bytes() <= pool.budget_bytes());
    assert_eq!(pool.used_bytes(), 0, "all pages returned");
    let s = pool.stats();
    assert_eq!(s.allocs, s.frees);
}

#[test]
fn evict_and_requeue_preserves_streams_bit_exactly() {
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let model = model(62, &qcfg);
    // budget = one full sequence (8192 B), but two requests that each
    // grow to 11 positions (6144 B apiece): both admit while small,
    // then decode growth forces evict-and-requeue
    let pool = KvPool::exact(&dims(), 2, 8192).unwrap();
    let mut rng = Pcg64::new(71);
    let reqs: Vec<DecodeRequest> =
        (0..2).map(|id| req(id, tokens(&mut rng, 2), 10)).collect();
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            generate_reforward(
                &model,
                &r.prompt,
                r.max_new_tokens,
                r.eos,
                &r.sampling,
            )
            .unwrap()
        })
        .collect();

    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model, pool.clone()).unwrap(),
        SchedulerConfig {
            max_active: 4,
            max_prefill_per_step: 4,
            ..SchedulerConfig::default()
        },
    );
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let mut saw_preempted = false;
    while !sched.is_idle() {
        sched.step().unwrap();
        saw_preempted |= sched.preempted() > 0;
        assert_eq!(
            sched.kv_resident_bytes(),
            pool.used_bytes(),
            "scheduler residency == pool accounting at every step"
        );
        assert!(pool.used_bytes() <= pool.budget_bytes());
    }
    let results = sched.take_finished();
    assert_eq!(results.len(), 2);
    for (r, w) in results.iter().zip(&want) {
        assert_eq!(
            r.tokens, *w,
            "request {}: eviction must not change the stream",
            r.id
        );
        assert_eq!(r.itl.len(), r.tokens.len() - 1);
    }
    assert!(
        sched.preemptions() > 0 && saw_preempted,
        "the budget must actually have forced evictions \
         ({} preemptions)",
        sched.preemptions()
    );
    assert_eq!(pool.used_bytes(), 0);
    let s = pool.stats();
    assert_eq!(s.allocs, s.frees);
    assert!(s.peak_bytes <= pool.budget_bytes());
}

#[test]
fn paged_exact_decode_is_bit_identical_to_inline() {
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model = model(63, &qcfg);
    let pool = KvPool::exact(&dims(), 4, 1 << 20).unwrap();
    let inline = DecodeEngine::new(model.clone()).unwrap();
    let paged = DecodeEngine::with_pool(model, pool).unwrap();
    let mut rng = Pcg64::new(72);
    let toks = tokens(&mut rng, 12);

    let mut kv_i = inline.new_kv();
    let mut kv_p = paged.new_kv();
    assert!(!kv_i.is_paged() && kv_p.is_paged());
    let mut a = inline.prefill(&toks[..4], &mut kv_i).unwrap();
    let mut b = paged.prefill(&toks[..4], &mut kv_p).unwrap();
    for t in 4..toks.len() {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "prefix {t}");
        }
        a = inline.step(&[toks[t]], std::slice::from_mut(&mut kv_i)).unwrap();
        b = paged.step(&[toks[t]], std::slice::from_mut(&mut kv_p)).unwrap();
    }
    // the exact pages hold the identical rows
    for layer in 0..dims().n_layers {
        let (ki, vi) = kv_i.layer_rows_f32(layer);
        let (kp, vp) = kv_p.layer_rows_f32(layer);
        assert!(ki.iter().zip(&kp).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(vi.iter().zip(&vp).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}

/// Stepped decode vs one whole-prefix ragged call under the same Mx
/// codec: identical bits (the codec-relative exactness contract), and
/// the error vs the Exact codec is nonzero but bounded.
#[test]
fn mx_codec_differential_matrix() {
    let weights = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model = model(64, &weights);
    let mut rng = Pcg64::new(73);
    let toks = tokens(&mut rng, 12);

    // exact-codec reference logits for the same prefix
    let exact_engine = DecodeEngine::new(model.clone()).unwrap();
    let mut kv_e = exact_engine.new_kv();
    let exact_logits = exact_engine.prefill(&toks, &mut kv_e).unwrap();
    let exact_rms = rms(&exact_logits);

    for elem in ["fp8_e4m3", "fp4_e2m1"] {
        for scale in ["ue4m3", "ue5m3"] {
            for bs in [8usize, 32] {
                let kv_cfg = PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).unwrap(),
                );
                let mk_pool = || {
                    KvPool::build(&dims(), &kv_cfg, bs, 4, 1 << 22).unwrap()
                };
                let label = format!("{elem}/{scale}/bs{bs}");

                let engine =
                    DecodeEngine::with_pool(model.clone(), mk_pool()).unwrap();
                let mut kv = engine.new_kv();
                let mut stepped =
                    engine.prefill(&toks[..4], &mut kv).unwrap();
                for t in 4..toks.len() {
                    stepped = engine
                        .step(&[toks[t]], std::slice::from_mut(&mut kv))
                        .unwrap();
                }
                let engine2 =
                    DecodeEngine::with_pool(model.clone(), mk_pool()).unwrap();
                let mut kv2 = engine2.new_kv();
                let whole = engine2.prefill(&toks, &mut kv2).unwrap();
                assert_eq!(stepped.len(), whole.len(), "{label}");
                for (i, (x, y)) in stepped.iter().zip(&whole).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{label}: stepped vs whole-prefix logit {i}"
                    );
                }

                // error model sanity vs the Exact codec: quantization
                // really happened, and stays within generous bounds
                let err = rms_diff(&whole, &exact_logits) / exact_rms;
                assert!(err > 0.0, "{label}: Mx KV changed nothing?");
                let bound = if elem == "fp8_e4m3" { 1.0 } else { 3.0 };
                assert!(
                    err.is_finite() && err < bound,
                    "{label}: rel logits error {err} out of bounds"
                );
            }
        }
    }
}

/// Per-tensor KV codecs and mismatched pools are refused up front.
#[test]
fn invalid_pool_configurations_are_refused() {
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model = model(65, &qcfg);
    // per-tensor KV scaling
    let per_tensor = PerLayerQConfig::uniform(
        QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
    );
    assert!(KvPool::build(&dims(), &per_tensor, 8, 4, 1 << 20).is_err());
    // pool too small for one full-context sequence → deadlock risk,
    // refused by the engine
    let tiny = KvPool::exact(&dims(), 2, 4096).unwrap();
    assert!(DecodeEngine::with_pool(model.clone(), tiny).is_err());
    // shape mismatch
    let other = ModelDims { d_model: 64, ..dims() };
    let wrong = KvPool::exact(&other, 2, 1 << 20).unwrap();
    assert!(DecodeEngine::with_pool(model, wrong).is_err());
}

fn rms(x: &[f32]) -> f64 {
    (x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / x.len() as f64)
        .sqrt()
}

fn rms_diff(a: &[f32], b: &[f32]) -> f64 {
    (a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64)
        .sqrt()
}
