//! Packed-native GEMM acceptance suite (ISSUE 2).
//!
//! 1. **Bit-exactness**: the code-domain engine equals decode +
//!    [`matmul_t`] bit for bit across every element × scale ×
//!    block-size × shape combination of the acceptance matrix, plus a
//!    randomized property sweep on seeded [`Pcg64`] inputs.
//! 2. **Determinism**: thread count and tile size never change a byte,
//!    for the tiled GEMM and for [`ChunkedKernel`] alike.
//! 3. **Dispatch**: `quantized_matmul`'s packed path is bit-identical
//!    to the golden-pinned fake-quant reference on aligned shapes.
//! 4. **Integer psum path**: deterministic, near-exact (i32 block
//!    psums), and bit-stable across engine configurations.

use microscale::dist::Pcg64;
use microscale::formats::{
    ElemFormat, MiniFloat, BF16_SCALE, E8M0, FP6_E2M3, FP6_E3M2, UE4M3, UE5M3,
};
use microscale::quant::gemm::{packed_matmul, GemmOperand, PackedGemm};
use microscale::quant::matmul::{matmul_t, quantized_matmul_with};
use microscale::quant::{QuantKernel, QuantScheme, ScalarKernel};
use microscale::util::simd::SimdLevel;

/// The ISSUE acceptance matrix.
const ELEMS: [ElemFormat; 4] = [
    ElemFormat::FP4,
    ElemFormat::Fp(FP6_E2M3),
    ElemFormat::Fp(FP6_E3M2),
    ElemFormat::FP8,
];
const SCALES: [MiniFloat; 3] = [UE4M3, UE5M3, BF16_SCALE];
const BLOCK_SIZES: [usize; 4] = [4, 8, 16, 32];
/// Odd / non-multiple shapes on purpose: trailing partial blocks per
/// row, quad-kernel remainders in every dimension.
const SHAPES: [(usize, usize, usize); 5] =
    [(1, 1, 1), (3, 5, 2), (8, 40, 7), (5, 33, 9), (16, 64, 13)];

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what} out {i}: packed {a} vs reference {b}"
        );
    }
}

#[test]
fn packed_gemm_bit_exact_across_acceptance_matrix() {
    let mut rng = Pcg64::new(0x6E44);
    for elem in ELEMS {
        for scale in SCALES {
            for bs in BLOCK_SIZES {
                let scheme = QuantScheme::new(elem, scale, bs);
                for &(m, k, n) in &SHAPES {
                    // σ sweeps the regimes the paper cares about: wide,
                    // granite-narrow (subnormal scales), collapsing
                    for sigma in [1.0, 5e-3, 2e-5] {
                        let x = rng.normal_vec_f32(m * k, sigma);
                        let w = rng.normal_vec_f32(k * n, sigma);
                        let xo =
                            GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                        let wo =
                            GemmOperand::quantize_transposed(&scheme, &w, k, n)
                                .unwrap();
                        let want =
                            matmul_t(&xo.decode(), &wo.decode(), m, k, n);
                        let got =
                            PackedGemm::serial().matmul(&xo, &wo).unwrap();
                        assert_bits_eq(
                            &got,
                            &want,
                            &format!("{} {m}x{k}x{n} σ={sigma}", scheme.id()),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn packed_gemm_bit_exact_property() {
    // randomized shapes/configs beyond the fixed matrix, threaded engine
    microscale::util::check::property("packed gemm == decode+matmul_t", 40, |g| {
        let scheme = QuantScheme::new(
            *g.pick(&ELEMS),
            *g.pick(&SCALES),
            *g.pick(&BLOCK_SIZES),
        );
        let (m, k, n) = (g.usize_in(1, 12), g.usize_in(1, 70), g.usize_in(1, 12));
        let sigma = g.log_uniform(1e-5, 2.0);
        let x = g.normal_vec_f32(m * k, sigma);
        let w = g.normal_vec_f32(k * n, sigma);
        let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
        let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
        let want = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
        let engine = PackedGemm {
            tile_n: g.usize_in(1, 9),
            threads: g.usize_in(1, 4),
            par_threshold: 0,
            // unsupported levels clamp to scalar, so picking freely
            // also exercises the clamp
            simd: *g.pick(&[
                SimdLevel::Scalar,
                SimdLevel::Avx2,
                SimdLevel::Neon,
            ]),
        };
        let got = engine.matmul(&xo, &wo).unwrap();
        assert_bits_eq(&got, &want, &scheme.id());
    });
}

#[test]
fn gemm_determinism_across_threads_and_tiles() {
    // byte-identical output for every (thread count, tile size) pairing
    let mut rng = Pcg64::new(0xDE7);
    let (m, k, n) = (33, 96, 29);
    let x = rng.normal_vec_f32(m * k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 5e-3);
    for scheme in [
        QuantScheme::new(ElemFormat::FP4, UE5M3, 8),
        QuantScheme::new(ElemFormat::FP8, UE4M3, 16),
        QuantScheme::new(ElemFormat::INT4, UE4M3, 8),
    ] {
        let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
        let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
        let baseline = PackedGemm {
            tile_n: 64,
            threads: 1,
            par_threshold: 0,
            simd: SimdLevel::Scalar,
        }
        .matmul(&xo, &wo)
        .unwrap();
        for tile_n in [1, 3, 8, 256] {
            for threads in [1, 2, 4, 8] {
                for simd in [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Neon]
                {
                    let engine =
                        PackedGemm { tile_n, threads, par_threshold: 0, simd };
                    let got = engine.matmul(&xo, &wo).unwrap();
                    assert_bits_eq(
                        &got,
                        &baseline,
                        &format!(
                            "{} tile {tile_n} threads {threads} {}",
                            scheme.id(),
                            simd.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn single_row_fast_path_is_bit_identical_to_tiled_threaded() {
    // m = 1 (the KV-cached decode step shape) takes the serial
    // short-circuit inside PackedGemm::matmul — no plan_threads, no
    // par_chunks_mut. It must be bit-identical both to the explicitly
    // tiled/threaded engine on the same operands and to the decode
    // reference, for FP and INT elements alike.
    let mut rng = Pcg64::new(0x1A07);
    let (k, n) = (96, 29);
    let x = rng.normal_vec_f32(k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 5e-3);
    for scheme in [
        QuantScheme::new(ElemFormat::FP4, UE5M3, 8),
        QuantScheme::new(ElemFormat::Fp(FP6_E2M3), UE4M3, 16),
        QuantScheme::new(ElemFormat::FP8, UE4M3, 16),
        QuantScheme::new(ElemFormat::INT4, UE4M3, 8),
    ] {
        let xo = GemmOperand::quantize(&scheme, &x, 1, k).unwrap();
        let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
        let fast = PackedGemm::auto().matmul(&xo, &wo).unwrap();
        // an engine that would thread if it could (par_threshold 0):
        // m = 1 must still take the serial path and match bytes
        for tile_n in [1, 8, 256] {
            let forced = PackedGemm {
                tile_n,
                threads: 8,
                par_threshold: 0,
                ..PackedGemm::auto()
            }
            .matmul(&xo, &wo)
            .unwrap();
            assert_bits_eq(
                &forced,
                &fast,
                &format!("{} m=1 tile {tile_n}", scheme.id()),
            );
        }
        if matches!(scheme.elem, ElemFormat::Fp(_)) {
            let want = matmul_t(&xo.decode(), &wo.decode(), 1, k, n);
            assert_bits_eq(
                &fast,
                &want,
                &format!("{} m=1 vs decode reference", scheme.id()),
            );
        }
        // the single row of a taller multiply matches the m=1 result:
        // the short-circuit changes setup, never accumulation order
        let x3 = {
            let mut v = x.clone();
            v.extend(rng.normal_vec_f32(2 * k, 5e-3));
            v
        };
        let xo3 = GemmOperand::quantize(&scheme, &x3, 3, k).unwrap();
        let tall = PackedGemm::auto().matmul(&xo3, &wo).unwrap();
        assert_bits_eq(
            &tall[..n],
            &fast,
            &format!("{} row 0 of m=3", scheme.id()),
        );
    }
}

#[test]
fn chunked_kernel_determinism_across_threads_and_tiles() {
    use microscale::quant::ChunkedKernel;
    let mut rng = Pcg64::new(0xC4A);
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 16).with_per_tensor(true);
    let x = rng.normal_vec_f32(16 * 700, 4e-3);
    let mut baseline = x.clone();
    let base_scales = ChunkedKernel { tile: 16 * 1024, threads: 1, par_threshold: 0 }
        .fake_quant_into(&scheme, &mut baseline);
    for tile in [16, 64, 1024] {
        for threads in [1, 2, 4, 8] {
            let kernel = ChunkedKernel { tile, threads, par_threshold: 0 };
            let mut y = x.clone();
            let scales = kernel.fake_quant_into(&scheme, &mut y);
            assert_bits_eq(
                &y,
                &baseline,
                &format!("chunked tile {tile} threads {threads}"),
            );
            assert_bits_eq(&scales, &base_scales, "chunked scales");
        }
    }
}

#[test]
fn packed_dispatch_matches_fake_quant_reference() {
    // end-to-end: quantize straight to codes, multiply natively ==
    // fake-quantize to f32, transpose, naive GEMM — bit for bit
    let mut rng = Pcg64::new(0xD15);
    let (m, k, n) = (9, 64, 11);
    let x = rng.normal_vec_f32(m * k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 1e-2);
    for elem in ELEMS {
        for scale in SCALES {
            let scheme = QuantScheme::new(elem, scale, 16);
            let got = packed_matmul(&scheme, &x, &w, m, k, n).unwrap();
            let want =
                quantized_matmul_with(&ScalarKernel, &scheme, &x, &w, m, k, n);
            assert_bits_eq(&got, &want, &scheme.id());
        }
    }
}

#[test]
fn per_tensor_operands_fall_back_bit_exact() {
    let mut rng = Pcg64::new(0x5CA);
    let (m, k, n) = (4, 32, 6);
    let x = rng.normal_vec_f32(m * k, 1e-3);
    let w = rng.normal_vec_f32(k * n, 1e-3);
    let scheme =
        QuantScheme::new(ElemFormat::FP4, UE4M3, 8).with_per_tensor(true);
    let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
    let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
    assert!(xo.per_tensor_factor() != 1.0);
    let got = PackedGemm::auto().matmul(&xo, &wo).unwrap();
    let want = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
    assert_bits_eq(&got, &want, "per-tensor fallback");
}

#[test]
fn int_psum_path_is_block_fused_and_accurate() {
    let mut rng = Pcg64::new(0x177);
    let (m, k, n) = (7, 40, 5);
    let x = rng.normal_vec_f32(m * k, 0.5);
    let w = rng.normal_vec_f32(k * n, 0.5);
    let cases = [(ElemFormat::INT4, 8usize), (ElemFormat::Int(127.0), 16)];
    for (elem, bs) in cases {
        let scheme = QuantScheme::new(elem, UE4M3, bs);
        let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
        let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
        let got = PackedGemm::serial().matmul(&xo, &wo).unwrap();

        let dx = xo.decode();
        let dw = wo.decode();
        let bpr = k.div_ceil(bs);

        // (a) near-exact vs f64 on the decoded operands: the i32 block
        // psums are exact, so the only roundings are one f32 product and
        // one f32 add per block

        for i in 0..m {
            for j in 0..n {
                let mut exact = 0.0f64;
                let mut mag = 0.0f64;
                for t in 0..k {
                    let p = dx[i * k + t] as f64 * dw[j * k + t] as f64;
                    exact += p;
                    mag += p.abs();
                }
                let gotv = got[i * n + j] as f64;
                // 2 roundings per block at f32 eps, vs the magnitude sum
                // (the exact value may cancel toward zero)
                let tol = 1e-6 * (2 * bpr) as f64 * mag.max(1e-30);
                assert!(
                    (gotv - exact).abs() <= tol,
                    "{} out ({i},{j}): {gotv} vs exact {exact} (mag {mag})",
                    scheme.id()
                );
            }
        }

        // (b) byte-stable across engine configurations (the int psum
        // path always runs the scalar kernel, whatever simd asks for)
        for tile_n in [1, 4, 64] {
            for threads in [1, 2, 5] {
                let engine = PackedGemm {
                    tile_n,
                    threads,
                    par_threshold: 0,
                    ..PackedGemm::auto()
                };
                let again = engine.matmul(&xo, &wo).unwrap();
                assert_bits_eq(&again, &got, "int determinism");
            }
        }
    }
}

#[test]
fn extreme_magnitudes_stay_bit_exact_on_unbounded_scale_grids() {
    // On bf16/e8m0 scale grids an extreme tensor can push the fused
    // scale product out of the normal f32 range, where the significand
    // exactness argument no longer applies; the engine must detect the
    // regime (fusion_safe) and still match decode + matmul_t bit for
    // bit. Covers overflow (1e20: s_x·s_w -> inf territory) and
    // underflow (1e-25: subnormal terms).
    let mut rng = Pcg64::new(0xFFF);
    let (m, k, n) = (3, 16, 4);
    for scale in [E8M0, BF16_SCALE] {
        for mag in [1e20f32, 1e-25] {
            let x: Vec<f32> = rng
                .normal_vec_f32(m * k, 1.0)
                .iter()
                .map(|v| v * mag)
                .collect();
            let w: Vec<f32> = rng
                .normal_vec_f32(k * n, 1.0)
                .iter()
                .map(|v| v * mag)
                .collect();
            let scheme = QuantScheme::new(ElemFormat::FP4, scale, 8);
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let wo =
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
            let want = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
            let got = PackedGemm::auto().matmul(&xo, &wo).unwrap();
            assert_bits_eq(
                &got,
                &want,
                &format!("{} mag {mag:e}", scheme.id()),
            );
        }
    }
}

#[test]
fn small_m_wide_n_column_split_is_bit_identical_to_serial() {
    // ISSUE 7 bugfix pin: m ∈ {2,3} with n far past the worker count.
    // The old row-only split could use at most m workers here; the
    // engine now fans out over the column axis — and that split must
    // never change a byte vs the serial engine, on the vector kernels
    // and the scalar ones alike.
    let mut rng = Pcg64::new(0xC015);
    let (k, n) = (64, 1536);
    for scheme in [
        QuantScheme::new(ElemFormat::FP4, UE5M3, 16),
        QuantScheme::new(ElemFormat::Fp(FP6_E3M2), UE4M3, 16),
        QuantScheme::new(ElemFormat::FP8, UE4M3, 16),
        QuantScheme::new(ElemFormat::INT4, UE4M3, 8),
    ] {
        for m in [2usize, 3] {
            let x = rng.normal_vec_f32(m * k, 5e-3);
            let w = rng.normal_vec_f32(k * n, 5e-3);
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let wo =
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
            let serial = PackedGemm::serial().matmul(&xo, &wo).unwrap();
            for threads in [4, 8, 16] {
                for simd in [SimdLevel::Scalar, SimdLevel::Avx2] {
                    let engine = PackedGemm {
                        threads,
                        par_threshold: 0,
                        simd,
                        ..PackedGemm::auto()
                    };
                    let got = engine.matmul(&xo, &wo).unwrap();
                    assert_bits_eq(
                        &got,
                        &serial,
                        &format!(
                            "{} m={m} threads={threads} {}",
                            scheme.id(),
                            simd.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn zero_length_contraction_returns_all_zero_output() {
    // ISSUE 7 bugfix pin: k == 0 with m·n > 0 is the empty sum — an
    // all-zero m×n result on every engine path, serial and threaded,
    // not an accident of loop bounds.
    for scheme in [
        QuantScheme::new(ElemFormat::FP4, UE5M3, 8),
        QuantScheme::new(ElemFormat::Fp(FP6_E3M2), UE4M3, 8),
        QuantScheme::new(ElemFormat::FP8, UE4M3, 8),
        QuantScheme::new(ElemFormat::INT4, UE4M3, 8),
    ] {
        let (m, n) = (3usize, 5usize);
        let xo = GemmOperand::quantize(&scheme, &[], m, 0).unwrap();
        let wo = GemmOperand::quantize_transposed(&scheme, &[], 0, n).unwrap();
        for engine in [
            PackedGemm::serial(),
            PackedGemm { threads: 8, par_threshold: 0, ..PackedGemm::auto() },
        ] {
            let got = engine.matmul(&xo, &wo).unwrap();
            assert_eq!(got.len(), m * n, "{}", scheme.id());
            for (i, v) in got.iter().enumerate() {
                assert_eq!(
                    v.to_bits(),
                    0.0f32.to_bits(),
                    "{} out {i} nonzero for k=0",
                    scheme.id()
                );
            }
        }
    }
}

#[test]
fn operand_shape_validation() {
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
    assert!(GemmOperand::quantize(&scheme, &[0.0; 10], 2, 4).is_err());
    let xo = GemmOperand::quantize(&scheme, &[0.0; 8], 2, 4).unwrap();
    let wo = GemmOperand::quantize(&scheme, &[0.0; 15], 3, 5).unwrap();
    // contraction mismatch (4 vs 5) must error, not panic
    assert!(PackedGemm::serial().matmul(&xo, &wo).is_err());
    // scheme mismatch
    let other = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
    let wo2 = GemmOperand::quantize(&other, &[0.0; 8], 2, 4).unwrap();
    assert!(PackedGemm::serial().matmul(&xo, &wo2).is_err());
}
