//! Decode subsystem acceptance suite (ISSUE-4).
//!
//! Pins the load-bearing guarantees of KV-cached autoregressive
//! generation:
//!
//! 1. **Step-wise differential exactness** — at every generated token,
//!    the KV-cached step's logits are bit-identical to re-running the
//!    scalar fake-quant `reference_forward` on the **full prefix**,
//!    across {FP4, FP8} × {UE4M3, UE5M3} × block sizes {8, 32} and a
//!    mixed per-layer config (packed + reference-path INT4 +
//!    bf16-exact layers in one model).
//! 2. **Chunked prefill exactness** — splitting a prompt across
//!    prefill calls changes nothing.
//! 3. **Scheduler stream invariance** — same seeds ⇒ same token
//!    streams, regardless of admission order, concurrency limits, or
//!    GEMM threading; streams equal the cache-free re-forward oracle.
//! 4. **Stop conditions** — eos, max-tokens, and context-full retire
//!    sequences correctly, with populated TTFT/ITL metrics.

use std::sync::Arc;

use microscale::dist::Pcg64;
use microscale::model::Params;
use microscale::quant::gemm::PackedGemm;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::cache::OperandCache;
use microscale::serve::decode::generate_reforward;
use microscale::serve::packed_model::{reference_forward, PackedModel};
use microscale::serve::scheduler::{
    DecodeRequest, FinishReason, Priority, Scheduler, SchedulerConfig,
};
use microscale::serve::{DecodeEngine, Sampling};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 10,
    }
}

fn tokens(rng: &mut Pcg64, d: &ModelDims, count: usize) -> Vec<i32> {
    (0..count).map(|_| (rng.next_u64() % d.vocab as u64) as i32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} {x} vs {y}");
    }
}

/// Feed `toks[prompt_len..]` one token at a time through the cached
/// engine and assert every step's logits equal the full-prefix scalar
/// reference bit for bit.
fn assert_stepwise_differential(
    model: &Arc<PackedModel>,
    params: &Params,
    qcfg: &PerLayerQConfig,
    block_size: usize,
    toks: &[i32],
    prompt_len: usize,
    what: &str,
) {
    let d = *model.dims();
    let engine = DecodeEngine::new(model.clone()).unwrap();
    let mut kv = engine.new_kv();
    let mut got = engine.prefill(&toks[..prompt_len], &mut kv).unwrap();
    for t in prompt_len..=toks.len() {
        // `got` holds the cached-step logits for the t-token prefix;
        // the oracle recomputes that prefix from scratch
        let want = reference_forward(
            params,
            &d,
            qcfg,
            block_size,
            &toks[..t],
            1,
            t,
        )
        .unwrap();
        assert_bits_eq(
            &got,
            &want[(t - 1) * d.vocab..t * d.vocab],
            &format!("{what}: step logits at prefix {t}"),
        );
        if t == toks.len() {
            break;
        }
        got = engine.step(&[toks[t]], std::slice::from_mut(&mut kv)).unwrap();
        assert_eq!(kv.len(), t + 1, "{what}: cache length");
    }
}

#[test]
fn cached_decode_matches_full_prefix_reference_across_grid() {
    let d = dims();
    let params = Params::init_surrogate(&d, 17);
    assert_eq!(params.max_positions().unwrap(), d.seq_len);
    let cache = OperandCache::new(256);
    let mut rng = Pcg64::new(50);
    for elem in ["fp4_e2m1", "fp8_e4m3"] {
        for scale in ["ue4m3", "ue5m3"] {
            for bs in [8usize, 32] {
                let qcfg = PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).unwrap(),
                );
                let model = Arc::new(
                    PackedModel::build(&d, &params, &qcfg, bs, &cache)
                        .unwrap(),
                );
                // the grid must exercise the packed engine, not a
                // fallback
                assert_eq!(
                    model.path_summary().packed,
                    d.n_layers * 6,
                    "{elem}/{scale}/bs{bs}"
                );
                let toks = tokens(&mut rng, &d, d.seq_len);
                assert_stepwise_differential(
                    &model,
                    &params,
                    &qcfg,
                    bs,
                    &toks,
                    3,
                    &format!("{elem}/{scale}/bs{bs}"),
                );
            }
        }
    }
}

#[test]
fn mixed_per_layer_config_decodes_exactly() {
    let d = ModelDims { n_layers: 3, ..dims() };
    let params = Params::init_surrogate(&d, 18);
    let cache = OperandCache::new(256);
    let mut rng = Pcg64::new(51);
    // one model spanning all three linear paths: packed FP4 bulk,
    // reference-path INT4, and an exact bf16 layer
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
        .with_override(0, QConfig::named("int4", "ue4m3", false).unwrap())
        .with_override(2, QConfig::baseline());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let s = model.path_summary();
    assert_eq!((s.exact, s.packed, s.reference), (6, 6, 6));
    let toks = tokens(&mut rng, &d, d.seq_len);
    assert_stepwise_differential(
        &model, &params, &qcfg, 8, &toks, 2, "mixed",
    );
}

#[test]
fn chunked_prefill_is_bit_identical_to_one_shot() {
    let d = dims();
    let params = Params::init_surrogate(&d, 19);
    let cache = OperandCache::new(64);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let engine = DecodeEngine::new(model).unwrap();
    let mut rng = Pcg64::new(52);
    let toks = tokens(&mut rng, &d, 7);

    let mut kv_once = engine.new_kv();
    let once = engine.prefill(&toks, &mut kv_once).unwrap();
    let mut kv_split = engine.new_kv();
    engine.prefill(&toks[..3], &mut kv_split).unwrap();
    let split = engine.prefill(&toks[3..], &mut kv_split).unwrap();
    assert_eq!((kv_once.len(), kv_split.len()), (7, 7));
    assert_bits_eq(&once, &split, "chunked prefill last-token logits");

    // and the caches are interchangeable for the next step
    let a = engine.step(&[5], std::slice::from_mut(&mut kv_once)).unwrap();
    let b = engine.step(&[5], std::slice::from_mut(&mut kv_split)).unwrap();
    assert_bits_eq(&a, &b, "step after chunked prefill");
}

#[test]
fn scheduler_streams_are_invariant_to_order_concurrency_and_threads() {
    let d = dims();
    let params = Params::init_surrogate(&d, 20);
    let cache = OperandCache::new(256);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let mut rng = Pcg64::new(53);
    let reqs: Vec<DecodeRequest> = (0..6)
        .map(|id| {
            let prompt_len = 2 + (id as usize % 3);
            DecodeRequest {
                id,
                prompt: tokens(&mut rng, &d, prompt_len),
                max_new_tokens: 3 + (id as usize % 4),
                eos: None,
                sampling: if id % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::Temperature { temp: 0.7, seed: 1000 + id }
                },
                priority: Priority::Interactive,
            }
        })
        .collect();

    // oracle: each request generated alone, cache-free, full re-forward
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            generate_reforward(
                &model,
                &r.prompt,
                r.max_new_tokens,
                r.eos,
                &r.sampling,
            )
            .unwrap()
        })
        .collect();

    // serial-GEMM twin of the same model: "worker count" knob
    let serial = Arc::new(
        PackedModel::build(&d, &params, &qcfg, 8, &cache)
            .unwrap()
            .with_gemm(PackedGemm::serial()),
    );
    let runs: Vec<(Arc<PackedModel>, SchedulerConfig, bool)> = vec![
        (
            model.clone(),
            SchedulerConfig {
                max_active: 2,
                max_prefill_per_step: 1,
                ..SchedulerConfig::default()
            },
            false,
        ),
        (
            model.clone(),
            SchedulerConfig {
                max_active: 6,
                max_prefill_per_step: 6,
                ..SchedulerConfig::default()
            },
            true, // reversed admission order
        ),
        (
            serial,
            SchedulerConfig {
                max_active: 3,
                max_prefill_per_step: 2,
                ..SchedulerConfig::default()
            },
            true,
        ),
    ];
    for (m, cfg, reversed) in runs {
        let mut sched = Scheduler::new(DecodeEngine::new(m).unwrap(), cfg);
        let order: Vec<usize> = if reversed {
            (0..reqs.len()).rev().collect()
        } else {
            (0..reqs.len()).collect()
        };
        for &i in &order {
            sched.submit(reqs[i].clone()).unwrap();
        }
        let results = sched.run().unwrap();
        assert_eq!(results.len(), reqs.len());
        for (r, w) in results.iter().zip(&want) {
            assert_eq!(
                r.tokens, *w,
                "request {} stream (max_active {}, reversed {reversed})",
                r.id, cfg.max_active
            );
            assert_eq!(r.finish, FinishReason::MaxTokens, "request {}", r.id);
            assert_eq!(r.itl.len(), r.tokens.len() - 1, "request {}", r.id);
        }
    }
}

#[test]
fn take_finished_returns_id_sorted_batches() {
    let d = dims();
    let params = Params::init_surrogate(&d, 23);
    let cache = OperandCache::new(64);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let mut rng = Pcg64::new(55);
    let mut sched = Scheduler::new(
        DecodeEngine::new(model).unwrap(),
        SchedulerConfig {
            max_active: 2,
            max_prefill_per_step: 1,
            ..SchedulerConfig::default()
        },
    );
    // submission order is scrambled and ids are sparse; lengths vary
    // so completion order differs from id order too
    for (id, max_new) in [(9u64, 2usize), (2, 5), (31, 3), (0, 4)] {
        sched
            .submit(DecodeRequest {
                id,
                prompt: tokens(&mut rng, &d, 3),
                max_new_tokens: max_new,
                eos: None,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
            })
            .unwrap();
    }
    let mut seen = Vec::new();
    let mut steps = 0;
    while !sched.is_idle() {
        sched.step().unwrap();
        steps += 1;
        assert!(steps < 1000);
        let batch = sched.take_finished();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted, "each drained batch is id-sorted");
        seen.extend(ids);
    }
    seen.sort_unstable();
    assert_eq!(seen, vec![0, 2, 9, 31], "every request retired once");
    assert!(sched.take_finished().is_empty(), "drained means drained");
}

#[test]
fn prefill_token_limit_never_changes_streams() {
    let d = dims();
    let params = Params::init_surrogate(&d, 24);
    let cache = OperandCache::new(64);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let mut rng = Pcg64::new(56);
    let reqs: Vec<DecodeRequest> = (0..4u64)
        .map(|id| DecodeRequest {
            id,
            prompt: tokens(&mut rng, &d, 4 + (id as usize % 3)),
            max_new_tokens: 3,
            eos: None,
            sampling: Sampling::Temperature { temp: 0.8, seed: 70 + id },
            priority: Priority::Interactive,
        })
        .collect();
    let mut baseline: Option<Vec<Vec<i32>>> = None;
    for limit in [1usize, 2, 3, usize::MAX] {
        let mut sched = Scheduler::new(
            DecodeEngine::new(model.clone()).unwrap(),
            SchedulerConfig {
                max_active: 3,
                max_prefill_per_step: 2,
                max_prefill_tokens: limit,
            },
        );
        for r in &reqs {
            sched.submit(r.clone()).unwrap();
        }
        let results = sched.run().unwrap();
        for r in &results {
            // queueing happens before the first token, never after
            assert!(
                r.queue_wait <= r.ttft,
                "request {}: queue_wait {:?} > ttft {:?} (limit {limit})",
                r.id,
                r.queue_wait,
                r.ttft
            );
        }
        let streams: Vec<Vec<i32>> =
            results.iter().map(|r| r.tokens.clone()).collect();
        match &baseline {
            None => baseline = Some(streams),
            Some(want) => assert_eq!(
                &streams, want,
                "prefill chunk limit {limit} changed a stream"
            ),
        }
    }
}

#[test]
fn cancellation_mid_flight_drains_pool_accounting() {
    let d = dims();
    let params = Params::init_surrogate(&d, 25);
    let cache = OperandCache::new(64);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let kv_cfg = PerLayerQConfig::uniform(
        QConfig::named("fp8_e4m3", "ue5m3", false).unwrap(),
    );
    let pool =
        microscale::serve::KvPool::build_with(&d, &kv_cfg, 8, 2, usize::MAX, true)
            .unwrap();
    let mut rng = Pcg64::new(57);
    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model, pool.clone()).unwrap(),
        SchedulerConfig::default(),
    );
    let prompt = tokens(&mut rng, &d, 4);
    for id in 0..2u64 {
        sched
            .submit(DecodeRequest {
                id,
                prompt: prompt.clone(), // shared prefix across both
                max_new_tokens: 5,
                eos: None,
                sampling: Sampling::Greedy,
                priority: Priority::Interactive,
            })
            .unwrap();
    }
    // two steps in, both sequences hold pages; cancel one mid-flight
    sched.step().unwrap();
    sched.step().unwrap();
    assert!(pool.used_bytes() > 0);
    assert_eq!(sched.cancel(0), 1, "request 0 was live");
    let results = sched.run().unwrap();
    assert_eq!(results.len(), 1, "only the survivor retires");
    assert_eq!(results[0].id, 1);
    assert_eq!(results[0].tokens.len(), 5);
    assert_eq!(sched.cancellations(), 1);
    assert_eq!(pool.used_bytes(), 0, "cancelled pages were reclaimed");
    let s = pool.stats();
    assert_eq!(s.allocs, s.frees, "page ledger balances");
}

#[test]
fn eos_and_context_full_retire_sequences() {
    let d = dims();
    let params = Params::init_surrogate(&d, 22);
    let cache = OperandCache::new(64);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let mut rng = Pcg64::new(54);
    let prompt = tokens(&mut rng, &d, 3);

    // learn the free-running greedy stream, then stop on its 3rd token
    let free =
        generate_reforward(&model, &prompt, 5, None, &Sampling::Greedy)
            .unwrap();
    assert_eq!(free.len(), 5);
    let eos = free[2];
    let cut = free.iter().position(|&t| t == eos).unwrap();
    let mut sched =
        Scheduler::new(DecodeEngine::new(model.clone()).unwrap(), SchedulerConfig::default());
    sched
        .submit(DecodeRequest {
            id: 0,
            prompt: prompt.clone(),
            max_new_tokens: 5,
            eos: Some(eos),
            sampling: Sampling::Greedy,
            priority: Priority::Interactive,
        })
        .unwrap();
    let r = &sched.run().unwrap()[0];
    assert_eq!(r.tokens, free[..=cut].to_vec());
    assert_eq!(r.finish, FinishReason::Eos);
    assert_eq!(r.prompt_len, prompt.len());

    // a window-filling request retires as ContextFull with metrics
    sched
        .submit(DecodeRequest {
            id: 1,
            prompt: tokens(&mut rng, &d, d.seq_len - 1),
            max_new_tokens: 100,
            eos: None,
            sampling: Sampling::Greedy,
            priority: Priority::Interactive,
        })
        .unwrap();
    let r = &sched.run().unwrap()[0];
    assert_eq!(r.finish, FinishReason::ContextFull);
    assert_eq!(r.tokens.len(), 2);
    assert_eq!(r.itl.len(), 1);
}
