//! End-to-end integration over the PJRT runtime: artifacts load, the
//! training step optimizes, perplexity evaluation responds to
//! quantization configs the way the paper says it must, and the CPU-side
//! quantizer agrees with the in-graph quantization.

use std::path::Path;

use microscale::model::{weights::Params, Corpus};
use microscale::runtime::eval::{self, DeviceParams};
use microscale::runtime::train::{train, TrainConfig};
use microscale::runtime::{Manifest, QConfig, Session};

/// AOT artifacts are produced by `make artifacts` (python build step) and
/// are not checked into the repo; a source-only checkout (or a build with
/// the stub `xla` vendor crate) skips the runtime tests with a note
/// instead of failing — see DESIGN.md §7.
fn session() -> Option<Session> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!(
            "skipping runtime test: artifacts/ not present (run `make artifacts`)"
        );
        return None;
    }
    let m = Manifest::load(Path::new("artifacts")).expect("manifest parses");
    match Session::open(m) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime test: PJRT session unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn end_to_end_train_and_quantized_eval() {
    let Some(s) = session() else { return };
    let m = s.manifest().clone();
    let corpus = Corpus::default_language(m.model.vocab);

    // -- a few training steps must reduce loss -------------------------
    let init = Params::init(&m, 7);
    let cfg = TrainConfig {
        steps: 20,
        lr: 2e-3,
        warmup: 2,
        weight_decay: 0.01,
        seed: 3,
        log_every: 4,
    };
    let (trained, curve) = train(&s, &corpus, &init, &cfg).unwrap();
    assert!(curve.len() >= 2);
    let first = curve.first().unwrap().loss;
    let last = curve.last().unwrap().loss;
    assert!(
        last < first - 0.1,
        "loss did not decrease: {first} -> {last}"
    );

    // -- eval: quantization configs order as the paper requires --------
    let dev = DeviceParams::upload(&s, &trained).unwrap();
    let batches = corpus.batches(999, 2, m.eval_batch, m.model.seq_len + 1);
    let ppl = |q: &QConfig, bs: usize| -> f64 {
        eval::perplexity(&s, &dev, q, bs, &batches).unwrap()
    };
    let base = ppl(&QConfig::baseline(), 8);
    let ue4m3 = ppl(&QConfig::fp4("ue4m3").unwrap(), 8);
    let ue5m3 = ppl(&QConfig::fp4("ue5m3").unwrap(), 8);
    let bf16s = ppl(&QConfig::fp4("bf16").unwrap(), 8);
    assert!(base > 1.0 && base < 300.0, "baseline ppl {base}");
    assert!(ue4m3 >= base * 0.999, "quantized can't beat baseline much");
    // after only 20 steps the model is weakly trained and format
    // orderings carry ~0.3% noise; the strict orderings are asserted on
    // the fully-trained models by the experiment suite (EXPERIMENTS.md)
    assert!(bf16s <= ue4m3 * 1.005, "bf16 scales {bf16s} vs ue4m3 {ue4m3}");
    assert!(ue5m3 <= ue4m3 * 1.005, "ue5m3 {ue5m3} vs ue4m3 {ue4m3}");

    // baseline is block-size invariant (quant bypassed)
    let base16 = ppl(&QConfig::baseline(), 16);
    assert!((base - base16).abs() < 1e-6 * base.max(1.0));

    // -- logits + probes pipeline --------------------------------------
    let probes = eval::probes_for_config(
        &s,
        &dev,
        &corpus,
        &QConfig::baseline(),
        8,
        1,
        555,
    )
    .unwrap();
    assert!(probes.top1 > 0.0 && probes.top1 <= 100.0);
    assert!(probes.kl_to_baseline.abs() < 1e-9, "baseline KL to itself");
}

#[test]
fn kernel_artifacts_match_rust_quantizer() {
    // The standalone Pallas kernel artifact (L1) must agree with the
    // Rust CPU quantizer bit-for-bit on the same inputs.
    use microscale::formats::{ElemFormat, UE4M3};
    use microscale::quant::{fake_quant, QuantScheme};
    use microscale::runtime::session::HostTensor;

    let Some(s) = session() else { return };
    let mut rng = microscale::dist::Pcg64::new(42);
    let x = rng.normal_vec_f32(128 * 128, 0.02);
    let out = s
        .run(
            "kernel_fq",
            &[HostTensor::F32(vec![128, 128], x.clone())],
        )
        .unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
    let want = fake_quant(&scheme, &x);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: {a} vs {b}");
    }
}

#[test]
fn fused_gemm_artifact_matches_rust() {
    use microscale::formats::{ElemFormat, UE4M3};
    use microscale::quant::matmul::quantized_matmul;
    use microscale::quant::QuantScheme;
    use microscale::runtime::session::HostTensor;

    let Some(s) = session() else { return };
    let mut rng = microscale::dist::Pcg64::new(43);
    let x = rng.normal_vec_f32(128 * 128, 0.05);
    let w = rng.normal_vec_f32(128 * 128, 0.02);
    let out = s
        .run(
            "kernel_qmm",
            &[
                HostTensor::F32(vec![128, 128], x.clone()),
                HostTensor::F32(vec![128, 128], w.clone()),
            ],
        )
        .unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
    let want = quantized_matmul(&scheme, &x, &w, 128, 128, 128);
    let mut max_rel = 0.0f64;
    for (a, b) in got.iter().zip(&want) {
        let d = (*a as f64 - *b as f64).abs()
            / (b.abs() as f64).max(1e-3);
        max_rel = max_rel.max(d);
    }
    // accumulation order differs (XLA dot vs naive loop): tiny fp drift
    assert!(max_rel < 1e-4, "max rel diff {max_rel}");
}

#[test]
fn sigma_transform_preserves_baseline_ppl() {
    // the zoo transform must not change the unquantized model function
    use microscale::model::zoo;

    let Some(s) = session() else { return };
    let m = s.manifest().clone();
    let corpus = Corpus::default_language(m.model.vocab);
    let params = Params::init(&m, 11);
    let batches = corpus.batches(1000, 1, m.eval_batch, m.model.seq_len + 1);

    let dev = DeviceParams::upload(&s, &params).unwrap();
    let base =
        eval::perplexity(&s, &dev, &QConfig::baseline(), 8, &batches).unwrap();

    let mut zp = params.clone();
    let prof = zoo::profile("granite-like").unwrap();
    zoo::apply_sigma_profile(&mut zp, m.model.n_layers, &prof, 5);
    let devz = DeviceParams::upload(&s, &zp).unwrap();
    let basez =
        eval::perplexity(&s, &devz, &QConfig::baseline(), 8, &batches)
            .unwrap();
    let rel = (base - basez).abs() / base;
    assert!(rel < 1e-3, "σ-transform changed the function: {base} vs {basez}");

    // ... but it must increase the *effective* quantization error of the
    // stored weights: sum of gamma^2 * ||w_stored - FQ(w_stored)||^2
    // relative to the effective weight norm. (The perplexity-level effect
    // needs a trained model and is covered by the Fig. 1 reproduction.)
    use microscale::formats::{ElemFormat, UE4M3};
    use microscale::quant::{fake_quant, QuantScheme};
    let rel_err = |p: &Params| -> f64 {
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let (_, gains) = p.get("gains").unwrap();
        let n_layers = m.model.n_layers;
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (col, name) in Params::QUANTIZED.iter().enumerate() {
            let (_, data) = p.get(name).unwrap();
            let per_layer = data.len() / n_layers;
            for l in 0..n_layers {
                let t = &data[l * per_layer..(l + 1) * per_layer];
                let g = gains[l * Params::QUANTIZED.len() + col] as f64;
                let tq = fake_quant(&scheme, t);
                for (a, b) in t.iter().zip(&tq) {
                    num += g * g * ((a - b) as f64).powi(2);
                    den += g * g * (*a as f64).powi(2);
                }
            }
        }
        num / den
    };
    let e_orig = rel_err(&params);
    let e_zoo = rel_err(&zp);
    assert!(
        e_zoo > 1.5 * e_orig,
        "granite-like transform should raise relative UE4M3 error: \
         {e_zoo:.3e} vs {e_orig:.3e}"
    );
}
