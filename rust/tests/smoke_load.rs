// Smoke: AOT artifacts load + execute on the PJRT CPU client.
use anyhow::Result;

#[test]
fn kernel_fq_artifact_runs() -> Result<()> {
    // Artifacts come from `make artifacts` and are not in the repo; a
    // source-only checkout skips rather than fails (DESIGN.md §7).
    if !std::path::Path::new("artifacts/kernel_fq.hlo.txt").exists() {
        eprintln!("skipping smoke test: artifacts/kernel_fq.hlo.txt not present");
        return Ok(());
    }
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("skipping smoke test: PJRT unavailable: {e}");
            return Ok(());
        }
    };
    let proto = xla::HloModuleProto::from_text_file("artifacts/kernel_fq.hlo.txt")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x: Vec<f32> = (0..128 * 128).map(|i| (i as f32 * 0.001) - 8.0).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[128, 128])?;
    let out = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    let out = out.to_tuple1()?;
    let v = out.to_vec::<f32>()?;
    assert_eq!(v.len(), 128 * 128);
    // fake-quant output must be finite and within |x|max * small slack
    assert!(v.iter().all(|a| a.is_finite() && a.abs() <= 9.0));
    Ok(())
}
