// Smoke: AOT artifacts load + execute on the PJRT CPU client.
use anyhow::Result;

#[test]
fn kernel_fq_artifact_runs() -> Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("artifacts/kernel_fq.hlo.txt")?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp)?;
    let x: Vec<f32> = (0..128 * 128).map(|i| (i as f32 * 0.001) - 8.0).collect();
    let lit = xla::Literal::vec1(&x).reshape(&[128, 128])?;
    let out = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
    let out = out.to_tuple1()?;
    let v = out.to_vec::<f32>()?;
    assert_eq!(v.len(), 128 * 128);
    // fake-quant output must be finite and within |x|max * small slack
    assert!(v.iter().all(|a| a.is_finite() && a.abs() <= 9.0));
    Ok(())
}
