//! Tensor-parallel shard-invariance acceptance suite (ISSUE 6).
//!
//! Sharding is a pure execution-layout change: for every configuration
//! the repo serves, `shards = N` must produce the same *bits* as
//! `shards = 1`. This suite pins that differentially:
//!
//! 1. **Split properties** (fuzz) — shard column ranges are
//!    block-aligned and tile `0..n`; shards reassemble to the parent
//!    operand byte-for-byte (`bits_digest`); `resident_bytes` sums
//!    exactly; a shard's bytes equal an independent re-quantize of its
//!    column slice.
//! 2. **Matmul invariance** — sharded `x · wᵀ` is bit-identical to the
//!    unsharded packed GEMM for random shapes (odd column counts
//!    included), with and without a [`ShardPool`], pools larger than
//!    the shard count included.
//! 3. **Forward/decode invariance** — logits and full decode token
//!    streams for shards ∈ {1,2,3,4,7} equal the 1-shard baseline
//!    across {FP4,FP8} × {UE4M3,UE5M3} × block sizes {8,32}, the mixed
//!    per-layer config, and the fusion-fallback path (extreme scale
//!    magnitudes driving decode fallback in some shards but not
//!    others).
//! 4. **Cache keying** — sharded and unsharded encodes of the same
//!    weight bytes occupy distinct [`OperandCache`] entries; repeat
//!    lookups return `Arc`-identical operands per shard slot.
//! 5. **Scheduler under memory pressure** — sharded decode through the
//!    paged [`KvPool`] (evict-and-requeue) keeps stream equality vs
//!    the cache-free oracle, with pool workers exceeding the shard
//!    count, and every shard slot runs marked (no oversubscription).

use std::sync::Arc;

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, MiniFloat, BF16_SCALE, E8M0, UE4M3, UE5M3};
use microscale::model::Params;
use microscale::quant::gemm::{GemmOperand, PackedGemm};
use microscale::quant::shard::{shard_ranges, ShardedOperand};
use microscale::quant::QuantScheme;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::cache::OperandCache;
use microscale::serve::decode::generate_reforward;
use microscale::serve::packed_model::PackedModel;
use microscale::serve::scheduler::{
    DecodeRequest, Priority, Scheduler, SchedulerConfig,
};
use microscale::serve::{DecodeEngine, KvPool, Sampling};
use microscale::util::par::{on_worker_thread, ShardPool};

const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 4, 7];

fn dims() -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 16,
    }
}

fn tokens(rng: &mut Pcg64, count: usize) -> Vec<i32> {
    let v = dims().vocab as u64;
    (0..count).map(|_| (rng.next_u64() % v) as i32).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: element {i} {a} vs {b}");
    }
}

#[test]
fn shard_ranges_fuzz_block_aligned_and_near_even() {
    let mut rng = Pcg64::new(0x5A01);
    for _ in 0..300 {
        let n = 1 + (rng.next_u64() % 300) as usize;
        let bs = [1usize, 3, 8, 16, 32][(rng.next_u64() % 5) as usize];
        let shards = 1 + (rng.next_u64() % 9) as usize;
        let ranges = shard_ranges(n, bs, shards);
        let units = n.div_ceil(bs);
        assert_eq!(ranges.len(), shards.min(units), "n={n} bs={bs}");
        let mut at = 0usize;
        let mut unit_counts = Vec::new();
        for (i, &(c0, c1)) in ranges.iter().enumerate() {
            assert_eq!(c0, at, "contiguous cover (n={n} bs={bs} s={shards})");
            assert!(c1 > c0, "no empty shard (n={n} bs={bs} s={shards})");
            assert_eq!(c0 % bs, 0, "block-aligned start");
            if i + 1 < ranges.len() {
                assert_eq!(c1 % bs, 0, "block-aligned interior boundary");
            }
            unit_counts.push((c1 - c0).div_ceil(bs));
            at = c1;
        }
        assert_eq!(at, n, "full cover (n={n} bs={bs} s={shards})");
        let (mn, mx) = (
            unit_counts.iter().min().unwrap(),
            unit_counts.iter().max().unwrap(),
        );
        assert!(mx - mn <= 1, "near-even in blocks (n={n} bs={bs} s={shards})");
    }
}

#[test]
fn split_reassembles_byte_for_byte_and_bytes_sum_exactly() {
    let schemes: [(ElemFormat, MiniFloat, usize); 3] = [
        (ElemFormat::FP4, UE4M3, 8),
        (ElemFormat::FP8, UE5M3, 16),
        (ElemFormat::FP4, BF16_SCALE, 8),
    ];
    let mut rng = Pcg64::new(0x5A02);
    for _ in 0..40 {
        let k = 1 + (rng.next_u64() % 48) as usize;
        let n = 1 + (rng.next_u64() % 90) as usize;
        let (elem, scale, bs) = schemes[(rng.next_u64() % 3) as usize];
        let scheme = QuantScheme { elem, scale, block_size: bs, per_tensor: false };
        let w = rng.normal_vec_f32(k * n, 0.5);
        let parent =
            Arc::new(GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap());
        for shards in [1usize, 2, 3, 5, 9] {
            let sh = ShardedOperand::split(&parent, shards).unwrap();
            let label = format!("k={k} n={n} bs={bs} shards={shards}");
            // byte accounting: slicing copies rows, never pads
            assert_eq!(sh.resident_bytes(), parent.resident_bytes(), "{label}");
            // reassembly is the identity, digest included
            assert_eq!(
                sh.reassemble().unwrap().bits_digest(),
                parent.bits_digest(),
                "{label}"
            );
            // each shard equals an independent re-quantize of its own
            // column slice (per-row encode commutes with slicing)
            for (op, &(c0, c1)) in sh.parts().iter().zip(sh.ranges()) {
                let width = c1 - c0;
                let mut sub = vec![0.0f32; k * width];
                for r in 0..k {
                    sub[r * width..(r + 1) * width]
                        .copy_from_slice(&w[r * n + c0..r * n + c1]);
                }
                let fresh =
                    GemmOperand::quantize_transposed(&scheme, &sub, k, width)
                        .unwrap();
                assert_eq!(
                    op.bits_digest(),
                    fresh.bits_digest(),
                    "{label} shard {c0}..{c1}"
                );
            }
        }
    }
}

#[test]
fn sharded_matmul_is_bit_identical_with_and_without_pool() {
    let mut rng = Pcg64::new(0x5A03);
    let gemm = PackedGemm::auto();
    // pool deliberately larger than any shard count below
    let pool = ShardPool::new(8);
    for &(elem, scale, bs) in &[
        (ElemFormat::FP4, UE4M3, 8usize),
        (ElemFormat::FP8, UE5M3, 16),
    ] {
        let scheme = QuantScheme { elem, scale, block_size: bs, per_tensor: false };
        // odd / non-divisible output widths included
        for &(m, k, n) in &[(1usize, 32usize, 13usize), (5, 16, 50), (8, 48, 64)]
        {
            let x = rng.normal_vec_f32(m * k, 1.0);
            let w = rng.normal_vec_f32(k * n, 0.5);
            let parent = Arc::new(
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap(),
            );
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let want = gemm.matmul(&xo, &parent).unwrap();
            for shards in SHARD_COUNTS {
                let sh = ShardedOperand::split(&parent, shards).unwrap();
                let label = format!("m={m} k={k} n={n} shards={shards}");
                let xo2 = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                let got = sh.matmul(xo2, &gemm, Some(&pool)).unwrap();
                assert_bits_eq(&got, &want, &format!("{label} (pooled)"));
                let xo3 = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                let got = sh.matmul(xo3, &gemm, None).unwrap();
                assert_bits_eq(&got, &want, &format!("{label} (serial)"));
            }
        }
    }
}

/// Extreme scale magnitudes force the packed GEMM's `fusion_safe`
/// fallback. With the extremes confined to some columns, the unsharded
/// operand falls back to decode while individual shards stay packed —
/// the sharded result must still match bit for bit (both paths are
/// exact per output column).
#[test]
fn fusion_fallback_path_is_shard_invariant() {
    let mut rng = Pcg64::new(0x5A04);
    let gemm = PackedGemm::auto();
    let pool = ShardPool::new(3);
    for &scale in &[E8M0, BF16_SCALE] {
        for &mag in &[1e20f64, 1e-25] {
            let scheme = QuantScheme {
                elem: ElemFormat::FP4,
                scale,
                block_size: 8,
                per_tensor: false,
            };
            let (m, k, n) = (3usize, 16usize, 24usize);
            let x: Vec<f32> =
                rng.normal_vec_f32(m * k, 1.0).iter().map(|v| v * mag as f32).collect();
            // extremes only in the first 8 output columns: shard 0 of 3
            // inherits them, shards 1..2 see normal-range scales
            let mut w = rng.normal_vec_f32(k * n, 0.5);
            for r in 0..k {
                for c in 0..8 {
                    w[r * n + c] *= mag as f32;
                }
            }
            let parent = Arc::new(
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap(),
            );
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let want = gemm.matmul(&xo, &parent).unwrap();
            for shards in [2usize, 3] {
                let sh = ShardedOperand::split(&parent, shards).unwrap();
                let xo2 = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                let got = sh.matmul(xo2, &gemm, Some(&pool)).unwrap();
                assert_bits_eq(
                    &got,
                    &want,
                    &format!("{}/mag={mag:e}/shards={shards}", scale.name),
                );
            }
        }
    }
}

#[test]
fn forward_logits_shard_invariant_across_format_matrix() {
    let d = dims();
    let params = Params::init_surrogate(&d, 81);
    let mut rng = Pcg64::new(0x5A05);
    for elem in ["fp4_e2m1", "fp8_e4m3"] {
        for scale in ["ue4m3", "ue5m3"] {
            for block_size in [8usize, 32] {
                let qcfg = PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).unwrap(),
                );
                let cache = OperandCache::new(256);
                let base = PackedModel::build(&d, &params, &qcfg, block_size, &cache)
                    .unwrap();
                for batch in [1usize, 4] {
                    let toks = tokens(&mut rng, batch * d.seq_len);
                    let want = base.forward(&toks, batch, d.seq_len).unwrap();
                    for shards in SHARD_COUNTS {
                        let model = PackedModel::build_sharded(
                            &d, &params, &qcfg, block_size, &cache, shards,
                        )
                        .unwrap();
                        let got = model.forward(&toks, batch, d.seq_len).unwrap();
                        assert_bits_eq(
                            &got,
                            &want,
                            &format!(
                                "{elem}/{scale}/bs{block_size}/batch{batch}\
                                 /shards={shards}"
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn decode_token_streams_shard_invariant() {
    let d = dims();
    let params = Params::init_surrogate(&d, 82);
    let mut rng = Pcg64::new(0x5A06);
    for (elem, scale) in [("fp4_e2m1", "ue4m3"), ("fp8_e4m3", "ue5m3")] {
        let qcfg =
            PerLayerQConfig::uniform(QConfig::named(elem, scale, false).unwrap());
        let cache = OperandCache::new(256);
        let base = Arc::new(
            PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap(),
        );
        let reqs: Vec<DecodeRequest> = (0..3)
            .map(|id| DecodeRequest {
                id,
                prompt: tokens(&mut rng, 4 + id as usize),
                max_new_tokens: 6,
                eos: None,
                sampling: if id % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::Temperature { temp: 0.8, seed: 700 + id }
                },
                priority: Priority::Interactive,
            })
            .collect();
        let want: Vec<Vec<i32>> = reqs
            .iter()
            .map(|r| {
                generate_reforward(&base, &r.prompt, r.max_new_tokens, r.eos, &r.sampling)
                    .unwrap()
            })
            .collect();
        for shards in SHARD_COUNTS {
            let model = Arc::new(
                PackedModel::build_sharded(&d, &params, &qcfg, 8, &cache, shards)
                    .unwrap(),
            );
            let mut sched = Scheduler::new(
                DecodeEngine::new(model).unwrap(),
                SchedulerConfig {
                    max_active: 4,
                    max_prefill_per_step: 4,
                    ..SchedulerConfig::default()
                },
            );
            for r in &reqs {
                sched.submit(r.clone()).unwrap();
            }
            let results = sched.run().unwrap();
            assert_eq!(results.len(), reqs.len());
            for (r, w) in results.iter().zip(&want) {
                assert_eq!(
                    r.tokens, *w,
                    "{elem}/{scale} shards={shards} request {} stream",
                    r.id
                );
            }
        }
    }
}

/// Mixed per-layer precision: layer 0 packed FP4, layer 1 INT4 on the
/// reference path — the reference path never shards, the packed layer
/// does, and the composition must stay bit-invariant end to end.
#[test]
fn mixed_per_layer_config_shard_invariant() {
    let d = dims();
    let params = Params::init_surrogate(&d, 83);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
        .with_override(1, QConfig::named("int4", "ue4m3", false).unwrap());
    let cache = OperandCache::new(256);
    let base =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let s = base.path_summary();
    assert_eq!((s.packed, s.reference), (6, 6), "mixed paths as intended");
    let mut rng = Pcg64::new(0x5A07);
    let toks = tokens(&mut rng, 2 * d.seq_len);
    let want = base.forward(&toks, 2, d.seq_len).unwrap();
    let prompt = tokens(&mut rng, 5);
    let want_stream =
        generate_reforward(&base, &prompt, 6, None, &Sampling::Greedy).unwrap();
    for shards in SHARD_COUNTS {
        let model = Arc::new(
            PackedModel::build_sharded(&d, &params, &qcfg, 8, &cache, shards)
                .unwrap(),
        );
        let got = model.forward(&toks, 2, d.seq_len).unwrap();
        assert_bits_eq(&got, &want, &format!("mixed/shards={shards}"));
        let got_stream =
            generate_reforward(&model, &prompt, 6, None, &Sampling::Greedy)
                .unwrap();
        assert_eq!(got_stream, want_stream, "mixed/shards={shards} stream");
    }
}

/// Regression (ISSUE 6 satellite): cache keys must include the shard
/// slot. The content digests cover the full weight for both the
/// unsharded operand and each shard, so without the slot in the key a
/// shard lookup would alias the unsharded entry.
#[test]
fn opcache_shard_entries_are_distinct_and_arc_shared() {
    let cache = OperandCache::new(64);
    let mut rng = Pcg64::new(0x5A08);
    let (k, n) = (16usize, 24usize);
    let w = rng.normal_vec_f32(k * n, 0.5);
    let scheme = QuantScheme {
        elem: ElemFormat::FP4,
        scale: UE4M3,
        block_size: 8,
        per_tensor: false,
    };
    let full = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
    let baseline = cache.stats().entries;

    let ranges = shard_ranges(n, scheme.block_size, 3);
    assert_eq!(ranges, vec![(0, 8), (8, 16), (16, 24)]);
    let mut shards = Vec::new();
    for (i, &(c0, c1)) in ranges.iter().enumerate() {
        shards.push(
            cache
                .get_or_pack_transposed_shard(&scheme, &w, k, n, i, 3, c0, c1)
                .unwrap(),
        );
    }
    // three NEW entries: no shard aliased the unsharded operand
    assert_eq!(cache.stats().entries, baseline + 3);
    for (s, &(c0, c1)) in shards.iter().zip(&ranges) {
        assert!(!Arc::ptr_eq(s, &full), "shard {c0}..{c1} aliased full");
        assert_eq!(
            s.bits_digest(),
            full.slice_rows(c0, c1).unwrap().bits_digest(),
            "shard {c0}..{c1} bytes"
        );
    }
    // shard slots of different counts are distinct entries too
    let half = cache
        .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 2, 0, 16)
        .unwrap();
    assert!(!Arc::ptr_eq(&half, &shards[0]));
    assert_eq!(cache.stats().entries, baseline + 4);
    // repeat lookups are hits returning the identical Arc
    let again = cache
        .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 3, 0, 8)
        .unwrap();
    assert!(Arc::ptr_eq(&again, &shards[0]));
    assert_eq!(cache.stats().entries, baseline + 4);
    // the ShardedOperand a sharded model assembles from those entries
    // reassembles to the unsharded bytes
    let sh = ShardedOperand::from_parts(shards, ranges).unwrap();
    assert_eq!(sh.reassemble().unwrap().bits_digest(), full.bits_digest());
    assert_eq!(sh.resident_bytes(), full.resident_bytes());
}

/// Satellite: sharded decode under the paged KvPool with
/// evict-and-requeue, pool workers > shard count, streams equal the
/// cache-free oracle, and no thread-pool oversubscription (every shard
/// slot is a marked worker).
#[test]
fn sharded_paged_decode_survives_eviction_and_never_oversubscribes() {
    // the no-oversubscription pin: every ShardPool slot (inline job 0
    // and workers alike) reports as a marked pool worker, which is
    // what keeps the inner GEMM serial per shard
    let probe = ShardPool::new(6);
    let marks =
        probe.run((0..7).map(|_| on_worker_thread as fn() -> bool).collect());
    assert!(marks.iter().all(|&m| m), "unmarked shard slot: {marks:?}");
    assert!(!on_worker_thread(), "guard must not leak past run()");

    let d = dims();
    let params = Params::init_surrogate(&d, 84);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let cache = OperandCache::new(256);
    let base =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    // 6 pool workers for 3 shards: worker count > shard count
    let model = Arc::new(
        PackedModel::build_sharded(&d, &params, &qcfg, 8, &cache, 3)
            .unwrap()
            .with_shard_pool(Arc::new(ShardPool::new(6))),
    );
    assert_eq!(model.shards(), 3);

    // budget = one full sequence; two requests growing to 12 positions
    // apiece force evict-and-requeue mid-generation (kvpool.rs idiom)
    let pool = KvPool::exact(&d, 2, 8192).unwrap();
    let mut rng = Pcg64::new(0x5A09);
    let reqs: Vec<DecodeRequest> = (0..2)
        .map(|id| DecodeRequest {
            id,
            prompt: tokens(&mut rng, 2),
            max_new_tokens: 10,
            eos: None,
            sampling: if id % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::Temperature { temp: 0.8, seed: 900 + id }
            },
            priority: Priority::Interactive,
        })
        .collect();
    // the oracle is cache-free AND unsharded: one run checks both the
    // paged-KV and the sharding layer at once
    let want: Vec<Vec<i32>> = reqs
        .iter()
        .map(|r| {
            generate_reforward(&base, &r.prompt, r.max_new_tokens, r.eos, &r.sampling)
                .unwrap()
        })
        .collect();
    let mut sched = Scheduler::new(
        DecodeEngine::with_pool(model, pool.clone()).unwrap(),
        SchedulerConfig {
            max_active: 4,
            max_prefill_per_step: 4,
            ..SchedulerConfig::default()
        },
    );
    for r in &reqs {
        sched.submit(r.clone()).unwrap();
    }
    let results = sched.run().unwrap();
    assert_eq!(results.len(), 2);
    for (r, w) in results.iter().zip(&want) {
        assert_eq!(
            r.tokens, *w,
            "request {}: sharded paged stream vs cache-free unsharded oracle",
            r.id
        );
    }
    assert!(
        sched.preemptions() > 0,
        "the budget must actually have forced evictions"
    );
    assert_eq!(pool.used_bytes(), 0, "all pages returned");
}
