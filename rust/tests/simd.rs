//! SIMD differential acceptance suite (ISSUE 7).
//!
//! Every vector kernel in the crate must be **bit-identical** to its
//! scalar reference — no fast-mode kernels shipped, so there are no
//! error-bound carve-outs anywhere in this suite:
//!
//! 1. **GEMM kernels** — `PackedGemm` pinned to each host-supported
//!    [`SimdLevel`] equals the scalar engine bit for bit over the
//!    acceptance grid {FP4, FP6, FP8, INT4} × {UE4M3, UE5M3, E8M0,
//!    BF16} × block sizes {4, 8, 17, 32} × odd shapes, serial and
//!    threaded (row split and small-m column split both).
//! 2. **Sharded GEMM** — the same grid holds through
//!    [`ShardedOperand`] at shards ∈ {1, 3}.
//! 3. **m == 1 decode path** — the KV-cached decode step shape takes
//!    the serial short-circuit whatever the level; bytes must match.
//! 4. **KV page codec** — [`KvPool::codec_roundtrip`] equals the
//!    scalar [`fake_quant`] of every row, bit for bit, across the
//!    format × scale × block-size grid (the codec's decode runs the
//!    dispatched `scale_lut*` primitives; its contract is the scalar
//!    pipeline's output exactly).
//! 5. **Primitives** — `absmax_scaled` / `scale_lut16` / `scale_lut`
//!    at every supported level equal the scalar bodies, NaN and
//!    signed-zero inputs included.
//!
//! Levels the host cannot execute clamp to scalar, so this suite is
//! meaningful on any runner; CI additionally runs the whole test
//! binary twice (`MICROSCALE_SIMD=scalar` and default auto-dispatch)
//! to pin the latched global dispatch on both sides.

use std::sync::Arc;

use microscale::dist::Pcg64;
use microscale::formats::{
    ElemFormat, MiniFloat, BF16_SCALE, E8M0, FP6_E3M2, UE4M3, UE5M3,
};
use microscale::quant::gemm::{GemmOperand, PackedGemm};
use microscale::quant::matmul::matmul_t;
use microscale::quant::{fake_quant, QuantScheme, ShardedOperand};
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::KvPool;
use microscale::util::simd::{self, SimdLevel};

const ELEMS: [ElemFormat; 4] = [
    ElemFormat::FP4,
    ElemFormat::Fp(FP6_E3M2),
    ElemFormat::FP8,
    ElemFormat::INT4,
];
const SCALES: [MiniFloat; 4] = [UE4M3, UE5M3, E8M0, BF16_SCALE];
/// 17 on purpose: a block size that never divides the shapes below, so
/// every row carries a partial trailing block.
const BLOCK_SIZES: [usize; 4] = [4, 8, 17, 32];
const SHAPES: [(usize, usize, usize); 3] =
    [(1, 16, 9), (3, 37, 19), (5, 24, 40)];

/// Scalar always, plus every level this host can actually execute.
fn levels() -> Vec<SimdLevel> {
    let mut v = vec![SimdLevel::Scalar];
    for l in [SimdLevel::Avx2, SimdLevel::Neon] {
        if l.supported() {
            v.push(l);
        }
    }
    v
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: out {i} {a} vs {b}");
    }
}

#[test]
fn active_dispatch_is_executable_and_named() {
    let level = simd::active();
    assert!(level.supported(), "active() returned an unsupported level");
    assert!(["scalar", "avx2", "neon"].contains(&simd::kernel_name()));
}

#[test]
fn gemm_vector_kernels_match_scalar_bitwise_across_grid() {
    let mut rng = Pcg64::new(0x51D0);
    let lv = levels();
    for elem in ELEMS {
        for scale in SCALES {
            for bs in BLOCK_SIZES {
                let scheme = QuantScheme::new(elem, scale, bs);
                for &(m, k, n) in &SHAPES {
                    for sigma in [1.0, 5e-3] {
                        let x = rng.normal_vec_f32(m * k, sigma);
                        let w = rng.normal_vec_f32(k * n, sigma);
                        let xo =
                            GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                        let wo = GemmOperand::quantize_transposed(
                            &scheme, &w, k, n,
                        )
                        .unwrap();
                        let scalar = PackedGemm::serial()
                            .with_simd(SimdLevel::Scalar)
                            .matmul(&xo, &wo)
                            .unwrap();
                        // the scalar engine is itself pinned to the
                        // decode reference on the FP paths
                        if matches!(elem, ElemFormat::Fp(_)) {
                            let want =
                                matmul_t(&xo.decode(), &wo.decode(), m, k, n);
                            assert_bits_eq(
                                &scalar,
                                &want,
                                &format!("{} scalar vs decode", scheme.id()),
                            );
                        }
                        for &level in &lv {
                            for threads in [1usize, 7] {
                                let engine = PackedGemm {
                                    threads,
                                    par_threshold: 0,
                                    ..PackedGemm::serial()
                                }
                                .with_simd(level);
                                let got = engine.matmul(&xo, &wo).unwrap();
                                assert_bits_eq(
                                    &got,
                                    &scalar,
                                    &format!(
                                        "{} {m}x{k}x{n} σ={sigma} {} t={threads}",
                                        scheme.id(),
                                        level.name()
                                    ),
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn gemm_vector_kernels_match_scalar_under_sharding() {
    let mut rng = Pcg64::new(0x51D1);
    let lv = levels();
    for elem in ELEMS {
        for scale in [UE4M3, BF16_SCALE] {
            let scheme = QuantScheme::new(elem, scale, 8);
            let (m, k, n) = (3usize, 32usize, 29usize);
            let x = rng.normal_vec_f32(m * k, 5e-3);
            let w = rng.normal_vec_f32(k * n, 5e-3);
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let parent = Arc::new(
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap(),
            );
            let scalar = PackedGemm::serial()
                .with_simd(SimdLevel::Scalar)
                .matmul(&xo, &parent)
                .unwrap();
            for shards in [1usize, 3] {
                let sh = ShardedOperand::split(&parent, shards).unwrap();
                for &level in &lv {
                    let engine = PackedGemm::serial().with_simd(level);
                    let xo2 = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
                    let got = sh.matmul(xo2, &engine, None).unwrap();
                    assert_bits_eq(
                        &got,
                        &scalar,
                        &format!(
                            "{} shards={shards} {}",
                            scheme.id(),
                            level.name()
                        ),
                    );
                }
            }
        }
    }
}

#[test]
fn single_row_decode_path_matches_scalar_at_every_level() {
    // m == 1 is the KV-cached decode step shape: serial short-circuit,
    // one row, wide n. Every level must produce the scalar bytes.
    let mut rng = Pcg64::new(0x51D2);
    let lv = levels();
    let (k, n) = (64usize, 200usize);
    for elem in ELEMS {
        for scale in [UE5M3, E8M0] {
            let scheme = QuantScheme::new(elem, scale, 16);
            let x = rng.normal_vec_f32(k, 5e-3);
            let w = rng.normal_vec_f32(k * n, 5e-3);
            let xo = GemmOperand::quantize(&scheme, &x, 1, k).unwrap();
            let wo =
                GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
            let scalar = PackedGemm::auto()
                .with_simd(SimdLevel::Scalar)
                .matmul(&xo, &wo)
                .unwrap();
            for &level in &lv {
                let got = PackedGemm::auto()
                    .with_simd(level)
                    .matmul(&xo, &wo)
                    .unwrap();
                assert_bits_eq(
                    &got,
                    &scalar,
                    &format!("{} m=1 {}", scheme.id(), level.name()),
                );
            }
        }
    }
}

#[test]
fn kv_codec_roundtrip_is_fake_quant_bitwise() {
    // The KV page codec's contract: a cached row reads back as
    // fake_quant(scheme, row) of what was written, bit for bit —
    // whatever level the dispatched decode primitives run at.
    let dims = ModelDims {
        vocab: 32,
        d_model: 32,
        n_heads: 2,
        n_layers: 1,
        d_ff: 64,
        seq_len: 16,
    };
    let mut rng = Pcg64::new(0x51D3);
    for elem in ["fp4_e2m1", "fp6_e3m2", "fp8_e4m3", "int4"] {
        for scale in ["ue4m3", "ue5m3", "e8m0", "bf16"] {
            for bs in [4usize, 8, 16, 32] {
                let cfg = PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).unwrap(),
                );
                let pool =
                    KvPool::build(&dims, &cfg, bs, 4, 1 << 22).unwrap();
                let scheme =
                    QConfig::named(elem, scale, false).unwrap().scheme(bs);
                for sigma in [1.0f32, 4e-3] {
                    let mut rows = rng.normal_vec_f32(4 * dims.d_model, sigma);
                    // one all-zero row: every block collapses (s = 0)
                    rows[..dims.d_model].fill(0.0);
                    let got = pool.codec_roundtrip(0, &rows).unwrap();
                    let want = fake_quant(&scheme, &rows);
                    assert_bits_eq(
                        &got,
                        &want,
                        &format!("kv {elem}/{scale} bs={bs} σ={sigma}"),
                    );
                }
            }
        }
    }
}

#[test]
fn primitives_match_scalar_at_every_level() {
    let mut rng = Pcg64::new(0x51D4);
    let lv = levels();
    // absmax: NaN candidates never beat the running max; signed zeros
    // and subnormals flow through the same rounded |v·s_t|
    for len in [0usize, 1, 3, 8, 9, 31, 64] {
        let mut block = rng.normal_vec_f32(len, 1.0);
        if len > 2 {
            block[1] = f32::NAN;
            block[2] = -0.0;
        }
        for s_t in [1.0f32, 0.25, 1e-30] {
            let want = simd::absmax_scaled_with(SimdLevel::Scalar, &block, s_t);
            for &level in &lv {
                let got = simd::absmax_scaled_with(level, &block, s_t);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "absmax len={len} s_t={s_t} {}",
                    level.name()
                );
            }
        }
    }
    // block decodes: one rounded multiply per element
    let lut16: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.375).collect();
    let lut256: Vec<f32> =
        (0..256).map(|i| (i as f32 - 128.0) * 3e-2).collect();
    for len in [0usize, 1, 7, 8, 20, 64] {
        let codes: Vec<u8> =
            (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let codes16: Vec<u8> = codes.iter().map(|c| c & 15).collect();
        for s in [0.5f32, 3.0] {
            let mut want = vec![0.0f32; len];
            simd::scale_lut16_with(
                SimdLevel::Scalar,
                s,
                &codes16,
                &lut16,
                &mut want,
            );
            for &level in &lv {
                let mut got = vec![0.0f32; len];
                simd::scale_lut16_with(level, s, &codes16, &lut16, &mut got);
                assert_bits_eq(
                    &got,
                    &want,
                    &format!("scale_lut16 len={len} {}", level.name()),
                );
            }
            let mut want = vec![0.0f32; len];
            simd::scale_lut_with(
                SimdLevel::Scalar,
                s,
                &codes,
                &lut256,
                &mut want,
            );
            for &level in &lv {
                let mut got = vec![0.0f32; len];
                simd::scale_lut_with(level, s, &codes, &lut256, &mut got);
                assert_bits_eq(
                    &got,
                    &want,
                    &format!("scale_lut len={len} {}", level.name()),
                );
            }
        }
    }
}
