//! Serve subsystem acceptance suite (ISSUE-3).
//!
//! Pins the four load-bearing guarantees of the packed-domain serving
//! path:
//!
//! 1. **Forward equivalence** — `PackedModel::forward` is bit-identical
//!    to the scalar fake-quant reference forward over
//!    {FP4, FP8} × {UE4M3, UE5M3} × block sizes {8, 32}, plus mixed
//!    per-layer and reference-path (INT4 / per-tensor / weight-only)
//!    configs.
//! 2. **Batching invariance** — a request's logits do not depend on its
//!    co-batched neighbors, including under per-tensor "-S" activation
//!    scaling (the one batch-global statistic, applied per sequence).
//! 3. **Engine determinism** — the same request set produces identical
//!    logits for any worker count and batch policy.
//! 4. **Operand-cache correctness** — cache hits return the operand the
//!    first encode produced (bit-identical, same allocation), and
//!    `quantized_matmul` reuses cached weight operands across calls.
//! 5. **Batcher state machine** (ISSUE-4) — the release rules match a
//!    naive declarative reference over fuzzed arrival/length streams,
//!    pinning the PR-3 size-trigger fix (a full non-head group releases
//!    ahead of an idle incompatible head) so it cannot regress.
//! 6. **Cache eviction boundaries** (ISSUE-4) — byte-budget exact-fit,
//!    oversized single operands, and FIFO-under-hits entry-cap cases,
//!    with `resident_bytes` accounting exact after every eviction.

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, UE5M3};
use microscale::model::Params;
use microscale::quant::gemm::{GemmOperand, PackedGemm};
use microscale::quant::matmul::{quantized_matmul, quantized_matmul_with};
use microscale::quant::{QuantScheme, ScalarKernel};
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::batcher::{Batcher, BatcherConfig, Request};
use microscale::serve::cache::{operand_cache, OperandCache};
use microscale::serve::engine::{EngineConfig, ServeEngine};
use microscale::serve::packed_model::{reference_forward, PackedModel};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 32,
        d_model: 32,
        n_heads: 2,
        n_layers: 3,
        d_ff: 64,
        seq_len: 8,
    }
}

fn tokens(rng: &mut Pcg64, d: &ModelDims, count: usize) -> Vec<i32> {
    (0..count).map(|_| (rng.next_u64() % d.vocab as u64) as i32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} {x} vs {y}");
    }
}

#[test]
fn packed_forward_equals_reference_across_format_grid() {
    let d = dims();
    let params = Params::init_surrogate(&d, 7);
    let cache = OperandCache::new(256);
    let mut rng = Pcg64::new(40);
    for elem in ["fp4_e2m1", "fp8_e4m3"] {
        for scale in ["ue4m3", "ue5m3"] {
            for bs in [8usize, 32] {
                let qcfg = PerLayerQConfig::uniform(
                    QConfig::named(elem, scale, false).unwrap(),
                );
                let model =
                    PackedModel::build(&d, &params, &qcfg, bs, &cache)
                        .unwrap();
                // every linear must actually be on the packed path
                assert_eq!(
                    model.path_summary().packed,
                    d.n_layers * 6,
                    "{elem}/{scale}/bs{bs}"
                );
                for batch in [1usize, 3] {
                    let toks = tokens(&mut rng, &d, batch * d.seq_len);
                    let got = model.forward(&toks, batch, d.seq_len).unwrap();
                    let want = reference_forward(
                        &params, &d, &qcfg, bs, &toks, batch, d.seq_len,
                    )
                    .unwrap();
                    assert_bits_eq(
                        &got,
                        &want,
                        &format!("{elem}/{scale}/bs{bs}/b{batch}"),
                    );
                }
            }
        }
    }
}

#[test]
fn mixed_and_fallback_configs_stay_pinned_to_reference() {
    let d = dims();
    let params = Params::init_surrogate(&d, 8);
    let cache = OperandCache::new(256);
    let mut rng = Pcg64::new(41);
    let mut wonly = QConfig::fp4("ue4m3").unwrap();
    wonly.act_quant = false;
    let configs = [
        // mixed per-layer: FP8 head/tail layers, exact middle
        PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
            .with_override(0, QConfig::named("fp8_e4m3", "ue5m3", false).unwrap())
            .with_override(1, QConfig::baseline()),
        // INT4 elements: reference path
        PerLayerQConfig::uniform(QConfig::named("int4", "ue4m3", false).unwrap()),
        // per-tensor eq. 11: reference path
        PerLayerQConfig::uniform(
            QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
        ),
        // weight-only quantization: reference path
        PerLayerQConfig::uniform(wonly),
    ];
    for qcfg in configs {
        let model = PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap();
        let toks = tokens(&mut rng, &d, 2 * d.seq_len);
        let got = model.forward(&toks, 2, d.seq_len).unwrap();
        let want =
            reference_forward(&params, &d, &qcfg, 8, &toks, 2, d.seq_len)
                .unwrap();
        assert_bits_eq(&got, &want, &qcfg.id());
    }
}

#[test]
fn logits_do_not_depend_on_co_batched_neighbors() {
    let d = dims();
    let params = Params::init_surrogate(&d, 9);
    let cache = OperandCache::new(256);
    let mut rng = Pcg64::new(42);
    let sv = d.seq_len * d.vocab;
    // the per-tensor "-S" config is the adversarial case: its eq. 11
    // absmax is the one batch-global statistic in the forward pass
    let configs = [
        PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap()),
        PerLayerQConfig::uniform(
            QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
        ),
    ];
    for qcfg in configs {
        let model = PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap();
        let r0 = tokens(&mut rng, &d, d.seq_len);
        let r1 = tokens(&mut rng, &d, d.seq_len);
        let r2 = tokens(&mut rng, &d, d.seq_len);
        let solo = model.forward(&r0, 1, d.seq_len).unwrap();

        let mut pair = r0.clone();
        pair.extend_from_slice(&r1);
        let out = model.forward(&pair, 2, d.seq_len).unwrap();
        assert_bits_eq(&out[..sv], &solo, &format!("{} head-of-2", qcfg.id()));

        let mut trio = r2.clone();
        trio.extend_from_slice(&r0);
        trio.extend_from_slice(&r1);
        let out = model.forward(&trio, 3, d.seq_len).unwrap();
        assert_bits_eq(
            &out[sv..2 * sv],
            &solo,
            &format!("{} middle-of-3", qcfg.id()),
        );
    }
}

#[test]
fn engine_is_deterministic_across_worker_counts() {
    let d = dims();
    let params = Params::init_surrogate(&d, 10);
    let cache = OperandCache::new(256);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let mut rng = Pcg64::new(43);
    let reqs: Vec<Vec<i32>> =
        (0..9).map(|_| tokens(&mut rng, &d, d.seq_len)).collect();
    let run = |workers: usize, max_batch: usize| -> Vec<Vec<f32>> {
        let engine = ServeEngine::start(
            model.clone(),
            EngineConfig {
                workers,
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                },
            },
        )
        .unwrap();
        let handles: Vec<_> = reqs
            .iter()
            .map(|r| engine.submit(r.clone()).unwrap())
            .collect();
        let out: Vec<Vec<f32>> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        let stats = engine.shutdown();
        assert_eq!(stats.requests, reqs.len() as u64);
        assert_eq!(stats.errors, 0);
        assert!(stats.mean_batch >= 1.0);
        assert!(stats.p50_ms <= stats.p99_ms);
        out
    };
    let base = run(1, 4);
    for (workers, max_batch) in [(2usize, 4usize), (3, 2), (2, 9)] {
        let got = run(workers, max_batch);
        for (i, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_bits_eq(
                a,
                b,
                &format!("request {i} (workers {workers}, bs {max_batch})"),
            );
        }
    }
}

#[test]
fn engine_serves_mixed_length_requests() {
    let d = dims();
    let params = Params::init_surrogate(&d, 11);
    let cache = OperandCache::new(256);
    let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
    let model =
        Arc::new(PackedModel::build(&d, &params, &qcfg, 8, &cache).unwrap());
    let engine = ServeEngine::start(
        model.clone(),
        EngineConfig {
            workers: 2,
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
        },
    )
    .unwrap();
    let mut rng = Pcg64::new(44);
    let mut handles = Vec::new();
    for seq in [8usize, 4, 8, 4, 8] {
        handles.push(engine.submit(tokens(&mut rng, &d, seq)).unwrap());
    }
    for h in handles {
        let seq = h.seq;
        let logits = h.wait().unwrap();
        assert_eq!(logits.len(), seq * d.vocab);
    }
    // over-long and empty sequences are refused at submit
    assert!(engine.submit(vec![0; d.seq_len + 1]).is_err());
    assert!(engine.submit(Vec::new()).is_err());
    engine.shutdown();
}

#[test]
fn operand_cache_hits_are_bit_identical_to_fresh_encodes() {
    let cache = OperandCache::new(16);
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
    let mut rng = Pcg64::new(45);
    let (m, k, n) = (5usize, 48, 12);
    let w = rng.normal_vec_f32(k * n, 5e-3);
    let x = rng.normal_vec_f32(m * k, 5e-3);

    let first = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
    let second = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
    let stats = cache.stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
    // the hit IS the first encode — one allocation, zero re-encodes
    assert!(Arc::ptr_eq(&first, &second));

    // and it is bit-identical to an uncached encode, through both the
    // payload digest and an actual multiply
    let fresh = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
    assert_eq!(first.bits_digest(), fresh.bits_digest());
    assert_bits_eq(&first.decode(), &fresh.decode(), "decode");
    let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
    let via_cache = PackedGemm::serial().matmul(&xo, &first).unwrap();
    let via_fresh = PackedGemm::serial().matmul(&xo, &fresh).unwrap();
    assert_bits_eq(&via_cache, &via_fresh, "matmul");
}

/// A declarative model of the batcher's release state machine
/// (DESIGN.md §9): the head's equal-seq group releases on
/// size/deadline/drain; otherwise the first-seen *full* non-head group
/// releases on size alone (the PR-3 fix this suite pins). Operates on
/// plain `(id, seq)` pairs so divergence from the real collector is a
/// bug in exactly one of them.
struct RefBatcher {
    queue: Vec<(u64, usize)>,
    max_batch: usize,
}

impl RefBatcher {
    fn collect(&mut self, deadline_hit: bool, closed: bool) -> Option<Vec<u64>> {
        let head_seq = self.queue.first()?.1;
        let head_group: Vec<usize> = (0..self.queue.len())
            .filter(|&i| self.queue[i].1 == head_seq)
            .take(self.max_batch)
            .collect();
        let take = if head_group.len() == self.max_batch
            || deadline_hit
            || closed
        {
            head_group
        } else {
            // distinct non-head lengths in first-appearance order; the
            // first one with a full group releases
            let mut seen = Vec::new();
            let mut full: Option<Vec<usize>> = None;
            for &(_, seq) in &self.queue {
                if seq == head_seq || seen.contains(&seq) {
                    continue;
                }
                seen.push(seq);
                let group: Vec<usize> = (0..self.queue.len())
                    .filter(|&i| self.queue[i].1 == seq)
                    .take(self.max_batch)
                    .collect();
                if group.len() == self.max_batch {
                    full = Some(group);
                    break;
                }
            }
            full?
        };
        let ids = take.iter().map(|&i| self.queue[i].0).collect();
        for &i in take.iter().rev() {
            self.queue.remove(i);
        }
        Some(ids)
    }
}

fn raw_request(id: u64, seq: usize) -> Request {
    let (tx, _rx) = mpsc::channel();
    Request { id, tokens: vec![0; seq], seq, enqueued: Instant::now(), done: tx }
}

/// `next_batch()` bounded to 10 s: the fuzz suites only call it when
/// the reference model says a release is due, so a regression in the
/// release rules must fail fast instead of sleeping out the huge
/// `max_wait` the size-trigger tests pin the deadline arm shut with.
fn next_ids_bounded(b: &Arc<Batcher>) -> Option<Vec<u64>> {
    let (tx, rx) = mpsc::channel();
    let bb = Arc::clone(b);
    std::thread::spawn(move || {
        let ids = bb
            .next_batch()
            .map(|v| v.iter().map(|r| r.id).collect::<Vec<u64>>());
        let _ = tx.send(ids);
    });
    rx.recv_timeout(Duration::from_secs(10))
        .expect("batcher blocked although the reference says a release is due")
}

#[test]
fn batcher_fuzz_matches_naive_reference_size_and_drain_triggers() {
    // max_wait is huge, so pre-close releases come from the size
    // trigger alone and post-close from the drain trigger — both
    // deterministic, both checked batch-for-batch against RefBatcher
    // over random arrival/length streams.
    for seed in 0..25u64 {
        let mut rng = Pcg64::new(0xBA7C + seed);
        let max_batch = 1 + (rng.next_u64() % 4) as usize;
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_secs(3600),
        }));
        let mut naive = RefBatcher { queue: Vec::new(), max_batch };
        for id in 0..40u64 {
            let seq = [4usize, 8, 12][(rng.next_u64() % 3) as usize];
            assert!(b.submit(raw_request(id, seq)));
            naive.queue.push((id, seq));
            while let Some(want) = naive.collect(false, false) {
                let got = next_ids_bounded(&b).unwrap();
                assert_eq!(got, want, "seed {seed} bs{max_batch} size trigger");
                assert_eq!(b.pending(), naive.queue.len(), "seed {seed}");
            }
        }
        b.close();
        loop {
            let want = naive.collect(true, true);
            let got = next_ids_bounded(&b);
            assert_eq!(got, want, "seed {seed} bs{max_batch} drain");
            if want.is_none() {
                break;
            }
        }
        assert_eq!(b.pending(), 0);
    }
}

#[test]
fn batcher_fuzz_matches_naive_reference_deadline_trigger() {
    // max_wait zero: the head's deadline has always passed, so every
    // collection releases the head group (possibly partial) — the
    // deadline arm of the state machine, again batch-for-batch.
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(0xDEAD + seed);
        let max_batch = 1 + (rng.next_u64() % 4) as usize;
        let b = Arc::new(Batcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::ZERO,
        }));
        let mut naive = RefBatcher { queue: Vec::new(), max_batch };
        for id in 0..24u64 {
            let seq = [4usize, 8][(rng.next_u64() % 2) as usize];
            assert!(b.submit(raw_request(id, seq)));
            naive.queue.push((id, seq));
        }
        while let Some(want) = naive.collect(true, false) {
            let got = next_ids_bounded(&b).unwrap();
            assert_eq!(got, want, "seed {seed} bs{max_batch} deadline");
        }
        assert_eq!(b.pending(), 0);
    }
}

#[test]
fn operand_cache_byte_budget_boundaries() {
    // each transposed 8x3 FP4/bs8 operand resides at exactly 36 bytes
    // (3x8 code bytes + 3 f32 scales); budgets are chosen around that
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
    let mut rng = Pcg64::new(0xCAFE);
    let mut tensor = || rng.normal_vec_f32(8 * 3, 0.02);

    // exact fit: two operands == the budget, byte for byte — the third
    // insert evicts exactly one entry, and accounting stays exact
    let cache = OperandCache::with_byte_cap(64, 72);
    let a = cache.get_or_pack_transposed(&scheme, &tensor(), 8, 3).unwrap();
    let b = cache.get_or_pack_transposed(&scheme, &tensor(), 8, 3).unwrap();
    assert_eq!(a.resident_bytes() + b.resident_bytes(), 72);
    assert_eq!(cache.stats().resident_bytes, 72);
    assert_eq!(cache.stats().evictions, 0);
    let c = cache.get_or_pack_transposed(&scheme, &tensor(), 8, 3).unwrap();
    let s = cache.stats();
    assert_eq!((s.entries, s.evictions), (2, 1));
    assert_eq!(s.resident_bytes, b.resident_bytes() + c.resident_bytes());

    // a single operand over the whole budget is served but cannot stay
    // resident: the cache evicts down to empty and accounts to zero
    let cache = OperandCache::with_byte_cap(64, 35);
    let w = tensor();
    let big = cache.get_or_pack_transposed(&scheme, &w, 8, 3).unwrap();
    assert_eq!(big.resident_bytes(), 36);
    let s = cache.stats();
    assert_eq!((s.entries, s.resident_bytes, s.evictions), (0, 0, 1));
    // the returned operand is fully usable despite eviction
    assert_eq!(big.decode().len(), 3 * 8);
    // and re-requesting it is a fresh miss, not a corrupt hit
    let again = cache.get_or_pack_transposed(&scheme, &w, 8, 3).unwrap();
    assert_eq!(cache.stats().misses, 2);
    assert_eq!(big.bits_digest(), again.bits_digest());
}

#[test]
fn operand_cache_entry_cap_is_fifo_under_mixed_hits() {
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
    let mut rng = Pcg64::new(0xF1F0);
    let mut tensor = || rng.normal_vec_f32(8 * 3, 0.02);
    let cache = OperandCache::new(3);
    let (wa, wb, wc, wd) = (tensor(), tensor(), tensor(), tensor());
    let a = cache.get_or_pack_transposed(&scheme, &wa, 8, 3).unwrap();
    let b = cache.get_or_pack_transposed(&scheme, &wb, 8, 3).unwrap();
    let c = cache.get_or_pack_transposed(&scheme, &wc, 8, 3).unwrap();
    // a hit on the oldest entry does NOT refresh its position (FIFO,
    // not LRU — insertion order is the only order)
    let a_hit = cache.get_or_pack_transposed(&scheme, &wa, 8, 3).unwrap();
    assert!(Arc::ptr_eq(&a, &a_hit));
    let d = cache.get_or_pack_transposed(&scheme, &wd, 8, 3).unwrap();
    let s = cache.stats();
    assert_eq!((s.entries, s.evictions, s.hits, s.misses), (3, 1, 1, 4));
    assert_eq!(
        s.resident_bytes,
        b.resident_bytes() + c.resident_bytes() + d.resident_bytes()
    );
    // B, C, D are still resident hits (and hits never reorder FIFO)
    let hits_before = cache.stats().hits;
    for w in [&wb, &wc, &wd] {
        cache.get_or_pack_transposed(&scheme, w, 8, 3).unwrap();
    }
    assert_eq!(cache.stats().hits, hits_before + 3);
    // A was evicted despite its recent hit: this get re-encodes (a
    // fresh allocation with identical bits), evicting B next in line
    let misses_before = cache.stats().misses;
    let a2 = cache.get_or_pack_transposed(&scheme, &wa, 8, 3).unwrap();
    let s = cache.stats();
    assert_eq!((s.misses, s.evictions), (misses_before + 1, 2));
    assert!(!Arc::ptr_eq(&a, &a2));
    assert_eq!(a.bits_digest(), a2.bits_digest());
    assert_eq!(
        s.resident_bytes,
        c.resident_bytes() + d.resident_bytes() + a2.resident_bytes()
    );
}

#[test]
fn quantized_matmul_reuses_cached_weight_operands() {
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
    let mut rng = Pcg64::new(46);
    let (m, k, n) = (4usize, 32, 6);
    let x = rng.normal_vec_f32(m * k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 5e-3);

    let before = operand_cache().stats();
    let a = quantized_matmul(&scheme, &x, &w, m, k, n);
    let b = quantized_matmul(&scheme, &x, &w, m, k, n);
    let after = operand_cache().stats();
    // second call hit the shared cache (counters are global and
    // monotonic, so compare deltas)
    assert!(
        after.hits >= before.hits + 1,
        "hits {} -> {}",
        before.hits,
        after.hits
    );
    assert_bits_eq(&a, &b, "repeat call");
    // cached dispatch stays bit-identical to the scalar reference path
    let want =
        quantized_matmul_with(&ScalarKernel, &scheme, &x, &w, m, k, n);
    assert_bits_eq(&a, &want, "vs reference");
}
