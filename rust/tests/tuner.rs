//! Auto-tuner acceptance suite (DESIGN.md §16): property tests pinning
//! the `microscale tune` search layer.
//!
//! 1. **Budget fit, exactly** — the chosen assignment's byte total
//!    equals the sum of real packed-operand `payload_bytes` over every
//!    quantized weight, and never exceeds the budget; an infeasible
//!    budget errors instead of overshooting.
//! 2. **Determinism** — same seed, same tables, same choice, bit for
//!    bit on the emitted config id.
//! 3. **Budget monotonicity** — more bytes never buys more error (the
//!    λ-sweep's exchange-argument guarantee, checked on real tables).
//! 4. **Config round-trip** — the emitted per-layer id (with `@bsN`
//!    and `-rot` suffixes) survives `PerLayerQConfig::parse`.
//! 5. **The pinned rotation flip** — on the FP4 × UE4M3 axis (where
//!    the paper's block-size anomaly lives), making Hadamard rotation
//!    available moves the anomaly-regime layers' chosen block size
//!    strictly DOWN: unrotated narrow channels collapse under fine
//!    blocks (s_zero), rotated ones ride the tensor RMS and prefer
//!    fine blocks again.
//! 6. **Beats uniform at equal bytes** — at a budget just under the
//!    uniform-fine cost, the mixed per-layer assignment achieves lower
//!    end-to-end mean logits error than every uniform candidate that
//!    fits the same budget.

use microscale::coordinator::tuner::{
    calibration, candidate_space, demo_model, e2e_logits_mse,
    measure_tables, search, LayerTables,
};
use microscale::dist::Pcg64;
use microscale::model::weights::Params;
use microscale::quant::gemm::GemmOperand;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::cache::OperandCache;
use microscale::serve::packed_model::PackedModel;

const BLOCK_SIZE: usize = 16;

fn dims() -> ModelDims {
    ModelDims {
        vocab: 64,
        d_model: 64,
        n_heads: 2,
        n_layers: 4,
        d_ff: 128,
        seq_len: 16,
    }
}

fn linear_dims(dims: &ModelDims, which: usize) -> (usize, usize) {
    let (d, f) = (dims.d_model, dims.d_ff);
    match which {
        4 => (d, f),
        5 => (f, d),
        _ => (d, d),
    }
}

/// Demo model + calibration + measured tables over the given axis.
fn tables(
    dims: &ModelDims,
    params: &Params,
    elems: &[&str],
    scales: &[&str],
    block_sizes: &[usize],
    rotate: bool,
) -> LayerTables {
    let calib = calibration(params, dims, 7, 2).unwrap();
    let cands = candidate_space(
        dims,
        &elems.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &scales.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        block_sizes,
        rotate,
    )
    .unwrap();
    measure_tables(params, dims, &calib, &cands, BLOCK_SIZE, 64).unwrap()
}

/// Independent byte accounting: sum of real packed-operand payloads
/// for every quantized weight under the per-layer config.
fn real_payload_bytes(
    dims: &ModelDims,
    params: &Params,
    qcfg: &PerLayerQConfig,
) -> usize {
    let mut total = 0;
    for layer in 0..dims.n_layers {
        let scheme = qcfg.layer(layer).scheme(BLOCK_SIZE);
        for (which, name) in Params::QUANTIZED.iter().enumerate() {
            let (k, n) = linear_dims(dims, which);
            let w = &params.get(name).unwrap().1[layer * k * n..][..k * n];
            total += GemmOperand::quantize_transposed(&scheme, w, k, n)
                .unwrap()
                .payload_bytes();
        }
    }
    total
}

#[test]
fn search_fits_budget_with_exact_byte_accounting() {
    let dims = dims();
    let params = demo_model(&dims, 7).unwrap();
    let t = tables(&dims, &params, &["fp4_e2m1"], &["ue4m3"], &[8, 32], true);
    let (min_u, max_u) = t.uniform_bytes_range();
    assert!(min_u < max_u, "degenerate byte axis");
    for budget in [min_u, (min_u + max_u) / 2, max_u, max_u * 2] {
        let c = search(&t, budget).unwrap();
        assert!(
            c.total_bytes <= budget,
            "budget {budget}: chose {} bytes",
            c.total_bytes
        );
        // the search's accounting is the real packed wire cost
        assert_eq!(
            c.total_bytes,
            real_payload_bytes(&dims, &params, &c.qcfg),
            "budget {budget}: table bytes disagree with packed operands"
        );
    }
    // an infeasible budget must refuse, not overshoot
    assert!(search(&t, min_u - 1).is_err());
}

#[test]
fn search_is_deterministic_for_a_fixed_seed() {
    let dims = dims();
    let run = || {
        let params = demo_model(&dims, 7).unwrap();
        let t = tables(
            &dims,
            &params,
            &["fp4_e2m1", "fp8_e4m3"],
            &["ue4m3", "ue5m3"],
            &[8, 16, 32],
            true,
        );
        let (min_u, max_u) = t.uniform_bytes_range();
        search(&t, (min_u + max_u) / 2).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.qcfg.id(), b.qcfg.id());
    assert_eq!(a.picks, b.picks);
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.total_err.to_bits(), b.total_err.to_bits());
    // and a different seed actually changes the tables it ran on
    let params2 = demo_model(&dims, 8).unwrap();
    let t2 = tables(
        &dims,
        &params2,
        &["fp4_e2m1", "fp8_e4m3"],
        &["ue4m3", "ue5m3"],
        &[8, 16, 32],
        true,
    );
    assert_ne!(
        t2.err[0][0].to_bits(),
        tables(
            &dims,
            &demo_model(&dims, 7).unwrap(),
            &["fp4_e2m1", "fp8_e4m3"],
            &["ue4m3", "ue5m3"],
            &[8, 16, 32],
            true,
        )
        .err[0][0]
            .to_bits()
    );
}

#[test]
fn search_error_is_monotone_in_budget() {
    let dims = dims();
    let params = demo_model(&dims, 7).unwrap();
    let t = tables(
        &dims,
        &params,
        &["fp4_e2m1", "fp8_e4m3"],
        &["ue4m3", "ue5m3", "e8m0"],
        &[8, 16, 32],
        true,
    );
    let (min_u, max_u) = t.uniform_bytes_range();
    let mut last = f64::INFINITY;
    let steps = 8;
    for i in 0..=steps {
        let budget = min_u + (max_u - min_u) * i / steps;
        let c = search(&t, budget).unwrap();
        assert!(
            c.total_err <= last * (1.0 + 1e-12),
            "budget {budget}: err {} after {last}",
            c.total_err
        );
        last = c.total_err;
    }
}

#[test]
fn chosen_config_round_trips_through_parse() {
    let dims = dims();
    let params = demo_model(&dims, 7).unwrap();
    let t = tables(
        &dims,
        &params,
        &["fp4_e2m1", "fp8_e4m3"],
        &["ue4m3", "ue5m3"],
        &[8, 16, 32],
        true,
    );
    let (min_u, max_u) = t.uniform_bytes_range();
    for budget in [min_u, (min_u + 3 * max_u) / 4] {
        let c = search(&t, budget).unwrap();
        let id = c.qcfg.id();
        let back = PerLayerQConfig::parse(&id).unwrap();
        assert_eq!(back, c.qcfg, "round trip of {id:?}");
        assert_eq!(back.id(), id);
        for l in 0..dims.n_layers {
            assert_eq!(back.layer(l), c.qcfg.layer(l), "layer {l} of {id:?}");
        }
    }
}

#[test]
fn rotation_flips_block_size_downward_on_the_anomaly_axis() {
    // The pinned case. FP4 × UE4M3 only: UE5M3/E8M0 scales would
    // rescue the narrow channels without any rotation (the paper's
    // Sec. 5.2 result) and mask the flip. Open budget: the choice is
    // the pure per-layer error argmin.
    let dims = dims();
    let params = demo_model(&dims, 7).unwrap();
    let with_rot =
        tables(&dims, &params, &["fp4_e2m1"], &["ue4m3"], &[8, 16, 32], true);
    let no_rot = tables(
        &dims,
        &params,
        &["fp4_e2m1"],
        &["ue4m3"],
        &[8, 16, 32],
        false,
    );
    let open = usize::MAX / 2;
    let c_rot = search(&with_rot, open).unwrap();
    let c_no = search(&no_rot, open).unwrap();
    let mut flipped = Vec::new();
    for l in 0..dims.n_layers {
        let b_rot = c_rot.qcfg.layer(l).effective_block_size(BLOCK_SIZE);
        let b_no = c_no.qcfg.layer(l).effective_block_size(BLOCK_SIZE);
        if b_rot < b_no {
            // the downward move must come from an actually-rotated pick
            assert!(
                c_rot.qcfg.layer(l).rotate,
                "layer {l}: block size fell {b_no} -> {b_rot} without \
                 rotation"
            );
            flipped.push(l);
        }
    }
    // the even (anomaly-regime) layers must flip: without rotation
    // their narrow channels collapse under fine blocks, so the tuner
    // holds a coarse block size; rotation lifts them to the tensor RMS
    // and the fine block size wins again
    for l in (0..dims.n_layers).step_by(2) {
        assert!(
            flipped.contains(&l),
            "anomaly layer {l} did not flip: rot {} vs norot {}",
            c_rot.qcfg.layer(l).id(),
            c_no.qcfg.layer(l).id()
        );
    }
    // and rotation must strictly reduce the achievable error
    assert!(
        c_rot.total_err < c_no.total_err,
        "rotation should lower the open-budget error: {} vs {}",
        c_rot.total_err,
        c_no.total_err
    );
}

#[test]
fn tuned_beats_every_uniform_at_equal_bytes() {
    let dims = dims();
    let params = demo_model(&dims, 7).unwrap();
    let t = tables(&dims, &params, &["fp4_e2m1"], &["ue4m3"], &[8, 32], true);
    // budget one byte under the uniform-fine cost: no bs-8 uniform
    // fits, but the tuner can still spend fine blocks where they pay
    let (_, max_u) = t.uniform_bytes_range();
    let budget = max_u - 1;
    let tuned = search(&t, budget).unwrap();
    // the winning assignment must actually be mixed (this is the
    // heterogeneous-layer demo model working as designed)
    let distinct: std::collections::BTreeSet<String> = (0..dims.n_layers)
        .map(|l| tuned.qcfg.layer(l).id())
        .collect();
    assert!(distinct.len() > 1, "tuned config degenerated to uniform");

    let cache = OperandCache::new(64);
    let mut rng = Pcg64::new(99);
    let tokens: Vec<i32> = (0..2 * dims.seq_len)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect();
    let exact = PerLayerQConfig::uniform(QConfig::baseline());
    let exact_model =
        PackedModel::build(&dims, &params, &exact, BLOCK_SIZE, &cache)
            .unwrap();
    let exact_logits = exact_model.forward(&tokens, 2, dims.seq_len).unwrap();
    let tuned_mse = e2e_logits_mse(
        &params,
        &dims,
        &tuned.qcfg,
        BLOCK_SIZE,
        &exact_logits,
        &tokens,
        2,
        &cache,
    )
    .unwrap();
    let mut compared = 0;
    for (c, cand) in t.cands.iter().enumerate() {
        if t.uniform_bytes(c) > budget {
            continue;
        }
        let mse = e2e_logits_mse(
            &params,
            &dims,
            &PerLayerQConfig::uniform(*cand),
            BLOCK_SIZE,
            &exact_logits,
            &tokens,
            2,
            &cache,
        )
        .unwrap();
        assert!(
            tuned_mse < mse,
            "uniform {} ({} bytes) at {mse:.4e} not beaten by tuned {} \
             ({} bytes) at {tuned_mse:.4e}",
            cand.id(),
            t.uniform_bytes(c),
            tuned.qcfg.id(),
            tuned.total_bytes
        );
        compared += 1;
    }
    assert!(compared > 0, "no uniform candidate fit the {budget}-byte budget");
}
