//! Speculative decoding acceptance suite (ISSUE-9): the
//! cross-precision draft/verify engine and the scheduler's speculation
//! mode, pinned to the one contract that makes speculation safe to
//! ship — **the emitted stream is bit-identical to non-speculative
//! decode**, for every depth, draft format, sampling policy, GEMM
//! dispatch, and shard count.
//!
//! 1. **Oracle equality over the format grid** — spec streams equal
//!    the cache-free `generate_reforward` stream for k ∈ {1,2,4,8}
//!    over {FP4, FP8} × {UE4M3, UE5M3} drafts, greedy and seeded
//!    temperature.
//! 2. **Stop conditions** — eos and a full context window truncate the
//!    spec stream exactly where they truncate the oracle.
//! 3. **Bit determinism** — seeded rejection sampling produces the
//!    same stream on repeated runs, under serial vs threaded GEMM
//!    dispatch, and on a sharded target.
//! 4. **Degenerate acceptance** — draft == target accepts every greedy
//!    proposal (acceptance 1.0).
//! 5. **Scheduler speculation mode** — pooled draft + target banks
//!    ([`KvPool::build_spec`]) serve streams identical to the base
//!    scheduler and drain the pool to zero bytes afterwards.

use std::sync::Arc;

use microscale::model::Params;
use microscale::quant::gemm::PackedGemm;
use microscale::runtime::artifacts::ModelDims;
use microscale::runtime::qconfig::{PerLayerQConfig, QConfig};
use microscale::serve::decode::generate_reforward;
use microscale::serve::{
    operand_cache, DecodeEngine, DecodeRequest, KvPool, PackedModel,
    Priority, Sampling, Scheduler, SchedulerConfig, SpecDecodeEngine,
};

fn dims() -> ModelDims {
    ModelDims {
        vocab: 48,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        seq_len: 48,
    }
}

fn params() -> Params {
    Params::init_surrogate(&dims(), 2026)
}

fn model(cfg: QConfig, block: usize) -> Arc<PackedModel> {
    Arc::new(
        PackedModel::build(
            &dims(),
            &params(),
            &PerLayerQConfig::uniform(cfg),
            block,
            operand_cache(),
        )
        .unwrap(),
    )
}

#[test]
fn spec_streams_equal_the_oracle_across_the_format_grid() {
    let target = model(QConfig::baseline(), 16);
    let prompt = [7, 1, 40, 3, 22];
    for elem in ["fp4_e2m1", "fp8_e4m3"] {
        for scale in ["ue4m3", "ue5m3"] {
            let cfg = QConfig::named(elem, scale, false).unwrap();
            let draft = model(cfg, 8);
            for k in [1usize, 2, 4, 8] {
                let engine = SpecDecodeEngine::new(
                    target.clone(),
                    draft.clone(),
                    k,
                )
                .unwrap();
                for sampling in [
                    Sampling::Greedy,
                    Sampling::Temperature { temp: 0.85, seed: 0xFEED },
                ] {
                    let want = generate_reforward(
                        &target, &prompt, 14, None, &sampling,
                    )
                    .unwrap();
                    let got = engine
                        .generate(&prompt, 14, None, &sampling)
                        .unwrap();
                    assert_eq!(
                        got.tokens, want,
                        "{elem}/{scale} k={k} {sampling:?}"
                    );
                    assert!(got.accepted <= got.proposed);
                    assert!(got.rounds >= 1);
                }
            }
        }
    }
}

#[test]
fn eos_and_context_stops_match_the_oracle() {
    let d = dims();
    let target = model(QConfig::baseline(), 16);
    let draft = model(QConfig::fp4("ue5m3").unwrap(), 8);
    let engine =
        SpecDecodeEngine::new(target.clone(), draft, 3).unwrap();

    // eos: pick a token the greedy stream actually emits mid-stream,
    // then require both paths to stop at its first occurrence
    let prompt = [9, 9, 2, 31];
    let free =
        generate_reforward(&target, &prompt, 10, None, &Sampling::Greedy)
            .unwrap();
    let eos = free[free.len() / 2];
    let want = generate_reforward(
        &target,
        &prompt,
        10,
        Some(eos),
        &Sampling::Greedy,
    )
    .unwrap();
    assert_eq!(*want.last().unwrap(), eos);
    let got = engine
        .generate(&prompt, 10, Some(eos), &Sampling::Greedy)
        .unwrap();
    assert_eq!(got.tokens, want, "eos stop");

    // context: a prompt three tokens short of the window; the oracle
    // emits seq_len - prompt + 1 tokens, the spec path must match
    let long: Vec<i32> =
        (0..d.seq_len - 3).map(|t| (t % d.vocab) as i32).collect();
    let want =
        generate_reforward(&target, &long, 20, None, &Sampling::Greedy)
            .unwrap();
    assert_eq!(want.len(), 4, "oracle context-stop arithmetic");
    let got =
        engine.generate(&long, 20, None, &Sampling::Greedy).unwrap();
    assert_eq!(got.tokens, want, "context stop");
}

#[test]
fn seeded_streams_are_bit_deterministic_across_gemm_dispatch() {
    let d = dims();
    let p = params();
    let prompt = [4, 17, 8];
    let sampling = Sampling::Temperature { temp: 0.9, seed: 0xD00D };
    let qt = PerLayerQConfig::uniform(QConfig::baseline());
    let qd = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let run = |t: Arc<PackedModel>, dr: Arc<PackedModel>| {
        SpecDecodeEngine::new(t, dr, 4)
            .unwrap()
            .generate(&prompt, 12, None, &sampling)
            .unwrap()
    };

    let target = model(QConfig::baseline(), 16);
    let draft = model(QConfig::fp4("ue5m3").unwrap(), 8);
    let a = run(target.clone(), draft.clone());
    let b = run(target.clone(), draft.clone());
    assert_eq!(a.tokens, b.tokens, "same engine inputs, same stream");
    assert_eq!(
        (a.proposed, a.accepted, a.rounds),
        (b.proposed, b.accepted, b.rounds)
    );

    // serial GEMM dispatch must not change a single bit
    let ts = Arc::new(
        PackedModel::build(&d, &p, &qt, 16, operand_cache())
            .unwrap()
            .with_gemm(PackedGemm::serial()),
    );
    let ds = Arc::new(
        PackedModel::build(&d, &p, &qd, 8, operand_cache())
            .unwrap()
            .with_gemm(PackedGemm::serial()),
    );
    let c = run(ts, ds.clone());
    assert_eq!(a.tokens, c.tokens, "serial vs threaded GEMM");

    // neither must a tensor-parallel sharded target
    let t2 = Arc::new(
        PackedModel::build_sharded(&d, &p, &qt, 16, operand_cache(), 2)
            .unwrap()
            .with_gemm(PackedGemm::serial()),
    );
    let e = run(t2, ds);
    assert_eq!(a.tokens, e.tokens, "sharded vs unsharded target");
}

#[test]
fn identical_draft_and_target_accept_every_greedy_proposal() {
    let m = model(QConfig::fp4("ue5m3").unwrap(), 16);
    let engine = SpecDecodeEngine::new(m.clone(), m.clone(), 4).unwrap();
    let got =
        engine.generate(&[5, 1, 2], 16, None, &Sampling::Greedy).unwrap();
    assert!(got.proposed > 0, "depth 4 over 16 tokens must propose");
    assert_eq!(got.accepted, got.proposed, "degenerate pair rejects");
    assert_eq!(got.acceptance(), 1.0);
    let want =
        generate_reforward(&m, &[5, 1, 2], 16, None, &Sampling::Greedy)
            .unwrap();
    assert_eq!(got.tokens, want);
}

#[test]
fn speculative_scheduler_is_stream_identical_and_drains_the_pool() {
    let d = dims();
    let qt = PerLayerQConfig::uniform(QConfig::baseline());
    let qd = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
    let target = model(QConfig::baseline(), 16);
    let draft = model(QConfig::fp4("ue5m3").unwrap(), 16);
    let reqs = || -> Vec<DecodeRequest> {
        (0..4usize)
            .map(|id| DecodeRequest {
                id: id as u64,
                prompt: (0..3 + id % 3)
                    .map(|t| ((5 * t + id) % d.vocab) as i32)
                    .collect(),
                max_new_tokens: 8,
                eos: None,
                sampling: if id % 2 == 0 {
                    Sampling::Greedy
                } else {
                    Sampling::Temperature {
                        temp: 0.8,
                        seed: 40 + id as u64,
                    }
                },
                priority: if id % 3 == 0 {
                    Priority::Batch
                } else {
                    Priority::Interactive
                },
            })
            .collect()
    };

    // the oracle: the plain scheduler, no pool, no speculation
    let mut base = Scheduler::new(
        DecodeEngine::new(target.clone()).unwrap(),
        SchedulerConfig::default(),
    );
    for r in reqs() {
        base.submit(r).unwrap();
    }
    let want = base.run().unwrap();

    // speculation mode over a two-bank pool: target pages under the
    // primary codec, draft pages under the draft bank
    let pool =
        KvPool::build_spec(&d, &qt, &qd, 16, 4, usize::MAX, false).unwrap();
    let mut sched = Scheduler::new_speculative(
        DecodeEngine::with_pool(target.clone(), pool.clone()).unwrap(),
        draft,
        3,
        SchedulerConfig::default(),
    )
    .unwrap();
    for r in reqs() {
        sched.submit(r).unwrap();
    }
    let got = sched.run().unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(
            (g.id, &g.tokens, &g.finish),
            (w.id, &w.tokens, &w.finish),
            "speculation changed a served stream"
        );
    }
    let (proposed, accepted) = sched.spec_stats().unwrap();
    assert!(proposed > 0, "no speculation happened");
    assert!(accepted <= proposed);
    drop(sched);
    assert_eq!(
        pool.used_bytes(),
        0,
        "draft + target pages must drain to zero"
    );
    let s = pool.stats();
    assert_eq!(s.allocs, s.frees, "every allocated page was freed");
}
