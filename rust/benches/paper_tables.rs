//! Regenerate every table AND figure of the paper (fast grids) — the
//! deliverable-(d) harness: workload generation, sweeps, baselines, and
//! the printed rows/series the paper reports. Runtime figures train/load
//! the model zoo on first use and are cached under results/.
//!
//! `cargo bench --bench paper_tables`

use microscale::experiments::{self, Ctx};

fn main() {
    let t0 = std::time::Instant::now();
    let mut ctx = Ctx::default_dirs(true).expect("ctx");
    let figures = [
        "1a", "1b", "2a", "2b", "2c", "3a", "3b", "3c", "4a", "4b", "5a",
        "5b", "6", "7", "8", "9", "10", "11", "12", "13", "14", "15", "16",
        "17",
    ];
    for id in figures {
        let t = std::time::Instant::now();
        match experiments::figure(&mut ctx, id) {
            Ok(out) => {
                println!("{out}");
                println!(
                    "[figure {id}: {:.1}s]\n",
                    t.elapsed().as_secs_f64()
                );
            }
            Err(e) => {
                println!("figure {id} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    for id in ["1", "2", "3"] {
        let t = std::time::Instant::now();
        match experiments::table(&mut ctx, id) {
            Ok(out) => {
                println!("{out}");
                println!("[table {id}: {:.1}s]\n", t.elapsed().as_secs_f64());
            }
            Err(e) => {
                println!("table {id} FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
    println!("{}", experiments::hwx::appendix_k());
    println!("{}", experiments::hwx::sec31_costs());
    println!("total: {:.1}s", t0.elapsed().as_secs_f64());
}
