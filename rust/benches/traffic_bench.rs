//! Serving-edge traffic benchmark: thin wrapper over the same driver
//! that backs `microscale traffic-bench` (`microscale::serve::traffic`),
//! so `cargo bench --bench traffic_bench` and the CLI produce identical
//! `BENCH_traffic.json` reports (field map in EXPERIMENTS.md §Perf).
//!
//! Pass `-- --smoke` (or set `MICROSCALE_BENCH_SMOKE=1`) for the
//! CI-sized run on a shrunken model.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let opts = microscale::serve::traffic::TrafficOpts::new(smoke);
    if let Err(e) = microscale::serve::traffic::run(&opts) {
        eprintln!("traffic bench failed: {e:#}");
        std::process::exit(1);
    }
}
