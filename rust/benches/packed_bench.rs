//! Packed-tensor + kernel benchmarks (the ISSUE-1 acceptance bench):
//!
//! 1. fake-quant a 4096×4096 tensor through the scalar reference, the
//!    tiled single-thread chunked kernel, and the full multi-threaded
//!    chunked kernel — reporting the chunked-vs-scalar speedup (target:
//!    ≥ 2× on a multi-core host);
//! 2. `PackedMxTensor` encode/decode throughput and the measured
//!    bytes/element against the Sec. 3.1 analytic storage model.
//!
//! `cargo bench --bench packed_bench` — results quoted in
//! EXPERIMENTS.md §Perf.

use std::time::Duration;

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, UE4M3, UE5M3};
use microscale::hw::memory;
use microscale::quant::{
    ChunkedKernel, PackedMxTensor, QuantKernel, QuantScheme, ScalarKernel,
};
use microscale::util::timer::{bench, black_box};

fn main() {
    let dim = 4096usize;
    let n = dim * dim;
    let budget = Duration::from_millis(1200);
    let mut rng = Pcg64::new(0xBEC);
    // granite-territory σ so the sweep exercises the regime the paper
    // cares about (scale subnormals, occasional block collapse)
    let x = rng.normal_vec_f32(n, 5e-3);

    println!("== fake-quant, {dim}x{dim} f32 (FP4 + UE4M3, bs 16) ==");
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
    let mut buf = x.clone();

    let scalar = bench("kernel/scalar", budget, || {
        buf.copy_from_slice(&x);
        black_box(ScalarKernel.fake_quant_into(&scheme, &mut buf));
    });
    println!("    -> {:.0} Melem/s", scalar.throughput(n as f64) / 1e6);

    let serial_kernel = ChunkedKernel::serial();
    let serial = bench("kernel/chunked-1t", budget, || {
        buf.copy_from_slice(&x);
        black_box(serial_kernel.fake_quant_into(&scheme, &mut buf));
    });
    println!("    -> {:.0} Melem/s", serial.throughput(n as f64) / 1e6);

    let auto_kernel = ChunkedKernel::auto();
    let auto = bench(
        &format!("kernel/chunked-{}t", auto_kernel.threads),
        budget,
        || {
            buf.copy_from_slice(&x);
            black_box(auto_kernel.fake_quant_into(&scheme, &mut buf));
        },
    );
    println!("    -> {:.0} Melem/s", auto.throughput(n as f64) / 1e6);

    let speedup_1t = scalar.median_ns / serial.median_ns;
    let speedup = scalar.median_ns / auto.median_ns;
    println!(
        "\n    chunked vs scalar: {speedup_1t:.2}x single-thread, \
         {speedup:.2}x with {} threads",
        auto_kernel.threads
    );
    println!(
        "    acceptance target (>= 2.00x): {}",
        if speedup >= 2.0 { "PASS" } else { "MISS (host-dependent)" }
    );

    println!("\n== PackedMxTensor encode/decode, {dim}x{dim} ==");
    for (scale, bs) in [(UE4M3, 32usize), (UE5M3, 8)] {
        let scheme = QuantScheme::new(ElemFormat::FP4, scale, bs);
        let enc = bench(
            &format!("packed/encode/{}/bs{bs}", scale.name),
            budget,
            || {
                black_box(PackedMxTensor::encode(&scheme, &x).unwrap());
            },
        );
        println!("    -> {:.0} Melem/s", enc.throughput(n as f64) / 1e6);
        let packed = PackedMxTensor::encode(&scheme, &x).unwrap();
        let mut out = vec![0.0f32; n];
        let dec = bench(
            &format!("packed/decode/{}/bs{bs}", scale.name),
            budget,
            || {
                packed.decode_into(&mut out);
                black_box(&out);
            },
        );
        println!("    -> {:.0} Melem/s", dec.throughput(n as f64) / 1e6);
        let analytic =
            memory::packed_bytes_per_element(packed.elem_bits(), n, bs);
        println!(
            "    payload {} bytes = {:.4} B/elem (analytic {:.4}), \
             {:.2}x vs bf16",
            packed.payload_bytes(),
            packed.bits_per_element() / 8.0,
            analytic,
            packed.compression_vs_bf16()
        );
    }
}
