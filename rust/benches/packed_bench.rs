//! Packed-tensor + kernel + native-GEMM benchmarks.
//!
//! 1. fake-quant a large tensor through the scalar reference, the tiled
//!    single-thread chunked kernel, and the full multi-threaded chunked
//!    kernel — reporting the chunked-vs-scalar speedup (ISSUE-1 target:
//!    ≥ 2× on a multi-core host);
//! 2. `PackedMxTensor` encode/decode throughput and the measured
//!    bytes/element against the Sec. 3.1 analytic storage model;
//! 3. the ISSUE-2 acceptance bench: packed-native GEMM
//!    ([`microscale::quant::gemm`]) vs the dequantize-then-naive-f32
//!    baseline on a 1024×1024×1024 FP4/UE5M3 multiply (target: ≥ 4×),
//!    with the result verified bit-exact before timing.
//!
//! `cargo bench --bench packed_bench` — results quoted in
//! EXPERIMENTS.md §Perf. Pass `-- --smoke` (or set
//! `MICROSCALE_BENCH_SMOKE=1`) for the CI-sized run on tiny shapes.
//!
//! Besides the human-readable log, the GEMM section emits a
//! machine-readable **`BENCH_gemm.json`** into the working directory so
//! the perf trajectory is tracked across PRs (field map in
//! EXPERIMENTS.md §Perf).

use std::time::Duration;

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, UE4M3, UE5M3};
use microscale::hw::memory;
use microscale::quant::gemm::{GemmOperand, PackedGemm};
use microscale::quant::matmul::matmul_t;
use microscale::quant::{
    ChunkedKernel, PackedMxTensor, QuantKernel, QuantScheme, ScalarKernel,
};
use microscale::util::json;
use microscale::util::simd::{self, SimdLevel};
use microscale::util::timer::{bench, black_box, BenchResult};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let budget = if smoke {
        Duration::from_millis(150)
    } else {
        Duration::from_millis(1200)
    };
    let dim = if smoke { 1024usize } else { 4096 };
    let n = dim * dim;
    let mut rng = Pcg64::new(0xBEC);
    // granite-territory σ so the sweep exercises the regime the paper
    // cares about (scale subnormals, occasional block collapse)
    let x = rng.normal_vec_f32(n, 5e-3);

    println!("== fake-quant, {dim}x{dim} f32 (FP4 + UE4M3, bs 16) ==");
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
    let mut buf = x.clone();

    let scalar = bench("kernel/scalar", budget, || {
        buf.copy_from_slice(&x);
        black_box(ScalarKernel.fake_quant_into(&scheme, &mut buf));
    });
    println!("    -> {:.0} Melem/s", scalar.throughput(n as f64) / 1e6);

    let serial_kernel = ChunkedKernel::serial();
    let serial = bench("kernel/chunked-1t", budget, || {
        buf.copy_from_slice(&x);
        black_box(serial_kernel.fake_quant_into(&scheme, &mut buf));
    });
    println!("    -> {:.0} Melem/s", serial.throughput(n as f64) / 1e6);

    let auto_kernel = ChunkedKernel::auto();
    let auto = bench(
        &format!("kernel/chunked-{}t", auto_kernel.threads),
        budget,
        || {
            buf.copy_from_slice(&x);
            black_box(auto_kernel.fake_quant_into(&scheme, &mut buf));
        },
    );
    println!("    -> {:.0} Melem/s", auto.throughput(n as f64) / 1e6);

    let speedup_1t = scalar.median_ns / serial.median_ns;
    let speedup = scalar.median_ns / auto.median_ns;
    println!(
        "\n    chunked vs scalar: {speedup_1t:.2}x single-thread, \
         {speedup:.2}x with {} threads",
        auto_kernel.threads
    );
    println!(
        "    acceptance target (>= 2.00x): {}",
        if speedup >= 2.0 { "PASS" } else { "MISS (host-dependent)" }
    );

    println!("\n== PackedMxTensor encode/decode, {dim}x{dim} ==");
    for (scale, bs) in [(UE4M3, 32usize), (UE5M3, 8)] {
        let scheme = QuantScheme::new(ElemFormat::FP4, scale, bs);
        let enc = bench(
            &format!("packed/encode/{}/bs{bs}", scale.name),
            budget,
            || {
                black_box(PackedMxTensor::encode(&scheme, &x).unwrap());
            },
        );
        println!("    -> {:.0} Melem/s", enc.throughput(n as f64) / 1e6);
        let packed = PackedMxTensor::encode(&scheme, &x).unwrap();
        let mut out = vec![0.0f32; n];
        let dec = bench(
            &format!("packed/decode/{}/bs{bs}", scale.name),
            budget,
            || {
                packed.decode_into(&mut out);
                black_box(&out);
            },
        );
        println!("    -> {:.0} Melem/s", dec.throughput(n as f64) / 1e6);
        let analytic =
            memory::packed_bytes_per_element(packed.elem_bits(), n, bs);
        println!(
            "    payload {} bytes = {:.4} B/elem (analytic {:.4}), \
             {:.2}x vs bf16",
            packed.payload_bytes(),
            packed.bits_per_element() / 8.0,
            analytic,
            packed.compression_vs_bf16()
        );
    }

    gemm_bench(smoke, budget);
}

/// The ISSUE-2 acceptance bench: packed-native GEMM vs
/// dequantize-then-naive-f32 on the same packed operands, plus the
/// machine-readable `BENCH_gemm.json` drop.
fn gemm_bench(smoke: bool, budget: Duration) {
    let (m, k, n) = if smoke { (128usize, 128, 128) } else { (1024, 1024, 1024) };
    let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 32);
    let mut rng = Pcg64::new(0x6E44);
    let x = rng.normal_vec_f32(m * k, 5e-3);
    let w = rng.normal_vec_f32(k * n, 5e-3);
    let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
    let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();

    println!(
        "\n== packed-native GEMM, {m}x{k}x{n} ({}, operands {:.3}+{:.3} MiB \
         packed) ==",
        scheme.id(),
        xo.payload_bytes() as f64 / (1 << 20) as f64,
        wo.payload_bytes() as f64 / (1 << 20) as f64,
    );

    // correctness gates before timing anything: the auto-dispatch
    // engine AND the scalar-pinned engine must both be bit-exact
    // against decode + matmul_t on these exact operands
    let reference = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
    for (engine, label) in [
        (PackedGemm::auto(), simd::kernel_name()),
        (PackedGemm::auto().with_simd(SimdLevel::Scalar), "scalar-pinned"),
    ] {
        let engine_out = engine.matmul(&xo, &wo).unwrap();
        assert!(
            reference
                .iter()
                .zip(&engine_out)
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "packed GEMM ({label}) disagrees with the decode reference — \
             do not trust the timings"
        );
    }
    println!(
        "    bit-exact vs dequant+matmul_t (auto '{}' + scalar): OK",
        simd::kernel_name()
    );

    let base = bench("gemm/dequant+naive-f32", budget, || {
        let dx = xo.decode();
        let dw = wo.decode();
        black_box(matmul_t(&dx, &dw, m, k, n));
    });
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    println!("    -> {:.2} GFLOP/s", flops / base.median_ns);

    // the simd axis: identical serial engine, scalar-pinned vs
    // auto-dispatch — isolates the vector kernels from threading
    let scalar_engine = PackedGemm::serial().with_simd(SimdLevel::Scalar);
    let scalar_serial = bench("gemm/packed-1t-scalar", budget, || {
        black_box(scalar_engine.matmul(&xo, &wo).unwrap());
    });
    println!("    -> {:.2} GFLOP/s", flops / scalar_serial.median_ns);

    let serial_engine = PackedGemm::serial();
    let serial = bench(
        &format!("gemm/packed-1t-{}", simd::kernel_name()),
        budget,
        || {
            black_box(serial_engine.matmul(&xo, &wo).unwrap());
        },
    );
    println!("    -> {:.2} GFLOP/s", flops / serial.median_ns);

    let auto_engine = PackedGemm::auto();
    let auto = bench(
        &format!("gemm/packed-{}t", auto_engine.threads),
        budget,
        || {
            black_box(auto_engine.matmul(&xo, &wo).unwrap());
        },
    );
    println!("    -> {:.2} GFLOP/s", flops / auto.median_ns);

    // wire bytes the packed path touches per multiply: both packed
    // operands + the f32 output
    let wire_bytes = (xo.payload_bytes() + wo.payload_bytes() + 4 * m * n) as f64;
    let speedup_serial = base.median_ns / serial.median_ns;
    let speedup_auto = base.median_ns / auto.median_ns;
    println!(
        "\n    packed-native vs dequant+naive: {speedup_serial:.2}x \
         single-thread, {speedup_auto:.2}x with {} threads",
        auto_engine.threads
    );
    let pass = speedup_auto >= 4.0;
    println!(
        "    acceptance target (>= 4.00x on 1024^3): {}",
        if smoke {
            "n/a (smoke shapes)"
        } else if pass {
            "PASS"
        } else {
            "MISS (host-dependent)"
        }
    );
    let simd_speedup = scalar_serial.median_ns / serial.median_ns;
    let simd_applicable = simd::active() != SimdLevel::Scalar;
    let simd_pass = simd_speedup >= 2.0;
    println!(
        "    simd axis ({} vs scalar, serial): {simd_speedup:.2}x — \
         target (>= 2.00x on 1024^3): {}",
        simd::kernel_name(),
        if smoke || !simd_applicable {
            "n/a"
        } else if simd_pass {
            "PASS"
        } else {
            "MISS (host-dependent)"
        }
    );

    let report = json::obj(vec![
        ("bench", json::s("packed_gemm")),
        ("smoke", json::Json::Bool(smoke)),
        (
            "shape",
            json::obj(vec![
                ("m", json::num(m as f64)),
                ("k", json::num(k as f64)),
                ("n", json::num(n as f64)),
            ]),
        ),
        ("scheme", json::s(&scheme.id())),
        ("flops_per_iter", json::num(flops)),
        ("packed_wire_bytes", json::num(wire_bytes)),
        ("paths", json::obj(vec![
            ("dequant_naive_f32", path_stats(&base, flops, None)),
            (
                "packed_serial_scalar",
                path_stats(&scalar_serial, flops, Some(wire_bytes)),
            ),
            ("packed_serial", path_stats(&serial, flops, Some(wire_bytes))),
            ("packed_threaded", path_stats(&auto, flops, Some(wire_bytes))),
        ])),
        ("threads", json::num(auto_engine.threads as f64)),
        ("speedup_serial", json::num(speedup_serial)),
        ("speedup_threaded", json::num(speedup_auto)),
        ("target_speedup", json::num(4.0)),
        // the simd axis (ISSUE 7): auto-dispatch vector kernel vs the
        // scalar-pinned kernel on the identical serial engine. The 2x
        // gate is defined on the full 1024^3 FP4/UE5M3 shape and only
        // where a vector kernel is actually active — smoke runs and
        // scalar-only hosts (or MICROSCALE_SIMD=scalar) record null.
        (
            "simd",
            json::obj(vec![
                ("kernel", json::s(simd::kernel_name())),
                ("speedup_vs_scalar", json::num(simd_speedup)),
                ("target_speedup", json::num(2.0)),
                (
                    "pass",
                    if smoke || !simd_applicable {
                        json::Json::Null
                    } else {
                        json::Json::Bool(simd_pass)
                    },
                ),
            ]),
        ),
        // the 4x target is defined on the full 1024^3 shapes only;
        // smoke runs record null so trajectory tooling can't misread a
        // tiny-shape ratio as an acceptance verdict
        (
            "pass",
            if smoke { json::Json::Null } else { json::Json::Bool(pass) },
        ),
    ]);
    let path = "BENCH_gemm.json";
    match std::fs::write(path, report.to_string()) {
        Ok(()) => println!("    wrote {path}"),
        Err(e) => println!("    could not write {path}: {e}"),
    }
}

/// Per-path stats entry for `BENCH_gemm.json`: median wall time, GFLOP/s
/// (`2mnk / t`), and — for packed paths — effective GiB/s over the wire
/// bytes actually stored (packed operands + f32 output).
fn path_stats(r: &BenchResult, flops: f64, wire_bytes: Option<f64>) -> json::Json {
    let mut fields = vec![
        ("median_ns", json::num(r.median_ns)),
        ("gflops", json::num(flops / r.median_ns)),
    ];
    if let Some(b) = wire_bytes {
        // bytes per ns == GB/s; rescale to GiB/s
        fields.push(("gib_per_s", json::num(b / r.median_ns * 1e9 / (1u64 << 30) as f64)));
    }
    json::obj(fields)
}
