//! Serving-path benchmark: thin wrapper over the same driver that backs
//! `microscale serve-bench` (`microscale::serve::bench`), so `cargo
//! bench --bench serve_bench` and the CLI produce identical
//! `BENCH_serve.json` reports (field map in EXPERIMENTS.md §Perf).
//!
//! Pass `-- --smoke` (or set `MICROSCALE_BENCH_SMOKE=1`) for the
//! CI-sized run on a shrunken model.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let opts = microscale::serve::bench::BenchOpts::new(smoke);
    if let Err(e) = microscale::serve::bench::run(&opts) {
        eprintln!("serve bench failed: {e:#}");
        std::process::exit(1);
    }
}
