//! L3 hot-path micro-benchmarks: minifloat casts, the block quantizer
//! across formats and block sizes, and the quantized GEMM.
//!
//! `cargo bench --bench quant_bench` — results quoted in
//! EXPERIMENTS.md §Perf.

use std::time::Duration;

use microscale::dist::Pcg64;
use microscale::formats::{ElemFormat, E8M0, UE4M3, UE5M3};
use microscale::quant::matmul::quantized_matmul;
use microscale::quant::{fake_quant_into, QuantScheme};
use microscale::util::timer::{bench, black_box};

fn main() {
    let budget = Duration::from_millis(400);
    let mut rng = Pcg64::new(1);
    let n = 1 << 16;
    let x = rng.normal_vec_f32(n, 0.02);

    println!("== minifloat cast (65,536 elements/iter) ==");
    for fmt in [UE4M3, UE5M3, E8M0] {
        let data = x.clone();
        let r = bench(&format!("cast/{}", fmt.name), budget, || {
            let mut acc = 0.0f32;
            for &v in &data {
                acc += fmt.cast(v.abs());
            }
            black_box(acc);
        });
        println!(
            "    -> {:.0} Melem/s",
            r.throughput(n as f64) / 1e6
        );
    }

    println!("\n== block fake-quant (65,536 elements/iter) ==");
    for (elem, name) in [(ElemFormat::FP4, "fp4"), (ElemFormat::INT4, "int4")] {
        for bs in [8usize, 16, 32, 128] {
            let scheme = QuantScheme::new(elem, UE4M3, bs);
            let mut buf = x.clone();
            let r = bench(
                &format!("fake_quant/{name}/ue4m3/bs{bs}"),
                budget,
                || {
                    buf.copy_from_slice(&x);
                    black_box(fake_quant_into(&scheme, &mut buf));
                },
            );
            println!(
                "    -> {:.0} Melem/s",
                r.throughput(n as f64) / 1e6
            );
        }
    }

    println!("\n== quantized GEMM 128x128x128 ==");
    let m = 128;
    let a = rng.normal_vec_f32(m * m, 0.05);
    let b = rng.normal_vec_f32(m * m, 0.02);
    let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
    let r = bench("qmatmul/fp4/ue4m3/bs16/128^3", budget, || {
        black_box(quantized_matmul(&scheme, &a, &b, m, m, m));
    });
    println!(
        "    -> {:.2} GFLOP/s equivalent",
        r.throughput(2.0 * (m * m * m) as f64) / 1e9
    );
}
