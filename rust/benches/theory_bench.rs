//! Theory-framework benchmarks: per-σ-point cost and full-figure sweeps
//! (the integration must stay fast enough to use interactively for
//! format exploration — Sec. 4.3).

use std::time::Duration;

use microscale::formats::{ElemFormat, UE4M3, UE5M3};
use microscale::stats::geomspace;
use microscale::theory;
use microscale::util::timer::{bench, black_box};

fn main() {
    let budget = Duration::from_millis(500);
    println!("== single MSE(σ) evaluations ==");
    for (name, sigma, n) in [
        ("mid-sigma/bs16", 0.02, 16),
        ("narrow-sigma/bs8", 1e-3, 8),
        ("wide-sigma/bs32", 0.5, 32),
    ] {
        bench(&format!("quantized_scales/{name}"), budget, || {
            black_box(theory::mse_quantized_scales(
                &ElemFormat::FP4,
                &UE4M3,
                sigma,
                n,
            ));
        });
    }
    bench("unquantized_scales/bs16", budget, || {
        black_box(theory::mse_unquantized_scales(&ElemFormat::FP4, 0.02, 16));
    });

    println!("\n== full Fig. 11-style sweep (48 σ-points x 4 block sizes) ==");
    let sigmas = geomspace(1e-4, 2.0, 48);
    bench("fig11_sweep/ue4m3", Duration::from_secs(2), || {
        for n in [4usize, 8, 16, 32] {
            for &s in &sigmas {
                black_box(theory::mse_quantized_scales(
                    &ElemFormat::FP4,
                    &UE4M3,
                    s,
                    n,
                ));
            }
        }
    });
    bench("fig11_sweep/ue5m3", Duration::from_secs(2), || {
        for n in [4usize, 8, 16, 32] {
            for &s in &sigmas {
                black_box(theory::mse_quantized_scales(
                    &ElemFormat::FP4,
                    &UE5M3,
                    s,
                    n,
                ));
            }
        }
    });
}
