//! Generation-path benchmark: thin wrapper over the same driver that
//! backs `microscale decode-bench` (`microscale::serve::decode_bench`),
//! so `cargo bench --bench decode_bench` and the CLI produce identical
//! `BENCH_decode.json` reports (field map in EXPERIMENTS.md §Perf).
//!
//! Pass `-- --smoke` (or set `MICROSCALE_BENCH_SMOKE=1`) for the
//! CI-sized run on a shrunken model.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let opts = microscale::serve::decode_bench::DecodeBenchOpts::new(smoke);
    if let Err(e) = microscale::serve::decode_bench::run(&opts) {
        eprintln!("decode bench failed: {e:#}");
        std::process::exit(1);
    }
}
