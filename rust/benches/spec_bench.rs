//! Cross-precision speculative decoding benchmark: thin wrapper over
//! the same driver that backs `microscale spec-bench`
//! (`microscale::serve::spec_bench`), so `cargo bench --bench
//! spec_bench` and the CLI produce identical `BENCH_spec.json` reports
//! (field map in EXPERIMENTS.md §Perf).
//!
//! Pass `-- --smoke` (or set `MICROSCALE_BENCH_SMOKE=1`) for the
//! CI-sized run on a shrunken model and grid.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let opts = microscale::serve::spec_bench::SpecBenchOpts::new(smoke);
    if let Err(e) = microscale::serve::spec_bench::run(&opts) {
        eprintln!("spec bench failed: {e:#}");
        std::process::exit(1);
    }
}
