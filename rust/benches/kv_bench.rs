//! Memory-bounded KV benchmark: thin wrapper over the same driver that
//! backs `microscale kv-bench` (`microscale::serve::kv_bench`), so
//! `cargo bench --bench kv_bench` and the CLI produce identical
//! `BENCH_kv.json` reports (field map in EXPERIMENTS.md §Perf).
//!
//! Pass `-- --smoke` (or set `MICROSCALE_BENCH_SMOKE=1`) for the
//! CI-sized run on a shrunken model.

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("MICROSCALE_BENCH_SMOKE").is_ok();
    let opts = microscale::serve::kv_bench::KvBenchOpts::new(smoke);
    if let Err(e) = microscale::serve::kv_bench::run(&opts) {
        eprintln!("kv bench failed: {e:#}");
        std::process::exit(1);
    }
}
