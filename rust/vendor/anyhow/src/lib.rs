//! Minimal in-tree reimplementation of the `anyhow` API surface used by
//! the `microscale` crate (the sandbox builds fully offline, so the real
//! crates.io `anyhow` cannot be fetched).
//!
//! Implemented subset:
//!
//! * [`Error`] — a boxed message with an optional source chain; `{}`
//!   prints the outermost message, `{:#}` prints the whole chain
//!   separated by `": "` (matching anyhow's alternate formatting).
//! * [`Result<T>`] with the `Error` default.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * Blanket `From<E: std::error::Error>` so `?` converts io/parse/etc.
//!   errors. As in real anyhow, `Error` itself deliberately does NOT
//!   implement `std::error::Error` (that is what makes the blanket
//!   conversion coherent).

use std::fmt;

/// `Result<T, anyhow::Error>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

struct ErrorImpl {
    msg: String,
    source: Option<Box<ErrorImpl>>,
}

/// An error message with an optional chain of underlying causes.
pub struct Error(Box<ErrorImpl>);

impl Error {
    /// Construct from a displayable message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error(Box::new(ErrorImpl { msg: m.to_string(), source: None }))
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error(Box::new(ErrorImpl { msg: c.to_string(), source: Some(self.0) }))
    }

    /// Iterate the messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(&self.0);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_ref();
        }
        msgs.into_iter()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.0.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        let causes: Vec<&str> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context layers.
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut inner: Option<Box<ErrorImpl>> = None;
        for m in msgs.into_iter().rev() {
            inner = Some(Box::new(ErrorImpl { msg: m, source: inner }));
        }
        Error(inner.expect("at least one message"))
    }
}

/// Attach context to the error variant of a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "nope")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::msg("root").context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("nope"));
    }

    #[test]
    fn context_on_option_and_result() {
        let n: Option<u32> = None;
        assert_eq!(format!("{}", n.context("missing").unwrap_err()), "missing");
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("doing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "doing x: nope");
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {ok}");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        fn g(n: usize) -> Result<()> {
            ensure!(n == 3);
            Ok(())
        }
        assert!(format!("{}", g(2).unwrap_err()).contains("n == 3"));
    }
}
