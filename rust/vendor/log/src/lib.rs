//! Minimal in-tree reimplementation of the `log` facade API surface used
//! by the `microscale` crate (the sandbox builds fully offline, so the
//! real crates.io `log` cannot be fetched).
//!
//! Implemented subset: [`Level`], [`LevelFilter`], [`Metadata`],
//! [`Record`], the [`Log`] trait, [`set_logger`]/[`set_max_level`], and
//! the `error!`/`warn!`/`info!`/`debug!`/`trace!` macros. Semantics match
//! the real facade: no logger installed (or level filtered out) means the
//! record is silently dropped.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a record (ordered: `Error < Warn < .. < Trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 1,
    /// Recoverable problems worth surfacing.
    Warn,
    /// High-level progress (the default CLI verbosity).
    Info,
    /// Developer diagnostics.
    Debug,
    /// Very fine-grained tracing.
    Trace,
}

impl Level {
    /// Uppercase static name, e.g. `"INFO"`.
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    /// Disable all logging.
    Off = 0,
    /// Allow `Error` only.
    Error,
    /// Allow `Error..=Warn`.
    Warn,
    /// Allow `Error..=Info`.
    Info,
    /// Allow `Error..=Debug`.
    Debug,
    /// Allow everything.
    Trace,
}

/// Metadata about a record (its level and target module).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.level
    }

    /// The record's target (module path of the call site).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// A single log record: metadata plus the formatted message arguments.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    /// The record's target (module path of the call site).
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    /// The record's metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    /// The message, ready for `{}` formatting.
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink; install one with [`set_logger`].
pub trait Log: Sync + Send {
    /// Whether a record with this metadata would be logged.
    fn enabled(&self, metadata: &Metadata) -> bool;
    /// Consume a record.
    fn log(&self, record: &Record);
    /// Flush buffered output.
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // LevelFilter::Off

/// Error returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first call wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, ::std::module_path!(), ::std::format_args!($($arg)+))
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static COUNT: AtomicU64 = AtomicU64::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= Level::Info
        }
        fn log(&self, r: &Record) {
            if self.enabled(r.metadata()) {
                let _ = format!("{}", r.args());
                COUNT.fetch_add(1, Ordering::Relaxed);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info <= Level::Info);
        assert_eq!(Level::Warn.as_str(), "WARN");
    }

    #[test]
    fn filtered_dispatch() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Info);
        let before = COUNT.load(Ordering::Relaxed);
        info!("hello {}", 1);
        debug!("dropped by max level");
        trace!("also dropped");
        assert_eq!(COUNT.load(Ordering::Relaxed), before + 1);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
