//! API-compatible **stub** of the `xla-rs` PJRT bindings.
//!
//! The sandbox image carries no native XLA/PJRT shared library, so this
//! crate provides the exact type/method surface `microscale::runtime`
//! compiles against, with every operation that would need the native
//! runtime returning a descriptive [`Error`] at *call time*. Everything
//! that does not need PJRT (the quantizer, theory, distributions,
//! hardware model — 14 of the paper's figures) runs without it; the
//! runtime-bound figures fail gracefully with the message below.
//!
//! Substituting a real build of `xla-rs` (same method surface) under
//! `vendor/xla` re-enables the PJRT paths with no source changes — see
//! DESIGN.md §7.

use std::fmt;

/// Error raised by every stubbed PJRT operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: native XLA/PJRT runtime not available in this build \
             (stub vendor/xla crate; see DESIGN.md §7)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias matching xla-rs.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to/from device buffers and literals.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// A parsed HLO module (stub: retains only the source path).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Parse an HLO text file. The stub fails if the file is unreadable
    /// (matching the real binding's first error) and otherwise defers the
    /// failure to compile time.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("HLO text file not found: {path}")));
        }
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// PJRT client handle (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    /// Upload a host buffer to a device-resident buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Device-resident buffer (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute on host literals (uploads, runs, returns output buffers).
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }

    /// Execute on device-resident buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// A host-side literal value (stub: holds f32 data only).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape to new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out as a typed vector. Stub: device round-trips never
    /// succeed, so there is nothing typed to copy.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    /// First element of the literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(Error::stub("Literal::get_first_element"))
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::decompose_tuple"))
    }

    /// Extract the single element of a 1-tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("Literal::to_tuple1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_stubbed() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }

    #[test]
    fn literal_vec1_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 3]).is_err());
    }
}
