//! # microscale
//!
//! Production-quality reproduction of *"Is Finer Better? The Limits of
//! Microscaling Formats in Large Language Models"* (Fasoli et al., IBM
//! Research, 2026).
//!
//! The paper discovers **perplexity inversion** — quantization error that
//! *increases* as the microscaling block size shrinks — traces it to the
//! limited dynamic range of quantized FP8 scales interacting with narrow
//! tensor distributions, builds a first-principles theoretical framework
//! for the three error contributions, and proposes the **UE5M3** scale
//! format as a hardware-friendly mitigation.
//!
//! This crate is the L3 layer of a three-layer rust+JAX+Pallas stack:
//!
//! * [`formats`] / [`quant`] — bit-exact re-implementation of every
//!   numeric format and the block microscaling quantizer (validated
//!   against the python oracle via golden vectors), the
//!   [`quant::kernel`] execution engine (scalar reference + tiled
//!   multi-threaded chunked kernel behind one trait),
//!   [`quant::packed`] — truly bit-packed MX tensor storage with one
//!   scale byte per block — and [`quant::gemm`] — the packed-domain
//!   GEMM engine multiplying element codes directly (decode LUTs +
//!   per-block scale fusion), bit-identical to dequantize-then-multiply;
//! * [`theory`] — the paper's analytical MSE framework (Sec. 4,
//!   App. E–H) as fast closed-form/numerical integration;
//! * [`dist`] / [`stats`] — synthetic distribution substrate and metrics;
//! * [`model`] — transformer weight store, synthetic corpus, σ-calibrated
//!   model zoo, downstream probes;
//! * [`runtime`] — PJRT CPU client executing the AOT-lowered HLO
//!   artifacts (python runs only at build time);
//! * [`serve`] — native packed-domain inference serving: the surrogate
//!   transformer on prepacked weights ([`serve::PackedModel`]), a
//!   micro-batching admission queue, a multi-worker engine with latency
//!   histograms, and the process-wide prepacked weight-operand cache —
//!   the model runs end to end without XLA artifacts;
//! * [`coordinator`] — experiment job expansion, caching, worker pool and
//!   result sinks driving every figure/table of the paper;
//! * [`experiments`] — one generator per paper figure/table;
//! * [`hw`] — the Appendix-K hardware cost model;
//! * [`report`] — table/series renderers and tiny JSON/CSV codecs.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod coordinator;
pub mod dist;
pub mod experiments;
pub mod formats;
pub mod hw;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod theory;
pub mod util;

/// Crate-level result alias (anyhow-based, like the rest of the stack).
pub type Result<T> = anyhow::Result<T>;
