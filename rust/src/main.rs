//! `microscale` — CLI for the paper reproduction.
//!
//! ```text
//! microscale figure <id>        reproduce a paper figure (1a..17)
//! microscale table <id>         reproduce a paper table (1, 2, 3)
//! microscale all                every figure + table (respects cache)
//! microscale hw                 Fig. 4(a) + App. K + Sec. 3.1 hardware model
//! microscale train              train the base model (--steps N)
//! microscale models             build the σ-transformed model zoo
//! microscale eval               one perplexity point (--model --scale --bs ...)
//! microscale theory             MSE-σ theory sweep (--elem --scale --bs)
//! microscale quantize           fake-quant an f32 binary file
//! microscale serve-bench        packed-domain serving bench (BENCH_serve.json)
//! microscale decode-bench       KV-cached generation bench (BENCH_decode.json)
//! microscale spec-bench         speculative-decoding format sweep (BENCH_spec.json)
//! microscale kv-bench           paged-KV memory/throughput bench (BENCH_kv.json)
//! microscale traffic-bench      serving-edge traffic bench (BENCH_traffic.json)
//! microscale tune               mixed-precision auto-tuner (BENCH_tune.json,
//!                               emits tuned_qconfig.json for --qconfig-file)
//! microscale kv-sweep           KV block-size anomaly sweep on live decode traces
//! microscale selftest           quick smoke of the full stack
//! ```
//!
//! Global flags: `--fast` (reduced grids), `--results DIR`, `--models DIR`,
//! `--artifacts DIR`, `--train-steps N`, `--quiet`.

use std::io::Write as _;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use microscale::experiments::{self, Ctx};
use microscale::formats::{scale_format, ElemFormat};
use microscale::model::{weights::Params, Corpus};
use microscale::quant::{fake_quant, QuantScheme};
use microscale::runtime::eval::{self, DeviceParams};
use microscale::runtime::train::{train, TrainConfig};
use microscale::runtime::QConfig;
use microscale::stats::geomspace;
use microscale::theory;
use microscale::util::cli::Args;

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, m: &log::Metadata) -> bool {
        m.level() <= log::Level::Info
    }
    fn log(&self, r: &log::Record) {
        if self.enabled(r.metadata()) {
            eprintln!("[{}] {}", r.level().as_str().to_lowercase(), r.args());
        }
    }
    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Result<Ctx> {
    let mut ctx = Ctx::new(
        PathBuf::from(args.get_or("artifacts", "artifacts")),
        PathBuf::from(args.get_or("results", "results")),
        PathBuf::from(args.get_or("models", "models")),
        args.has("fast"),
    )?;
    ctx.train_steps = args.get_usize("train-steps", 240)?;
    Ok(ctx)
}

fn run() -> Result<()> {
    let args = Args::from_env();
    if !args.has("quiet") {
        let _ = log::set_logger(&LOGGER);
        log::set_max_level(log::LevelFilter::Info);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "figure" => {
            let id = args
                .positional
                .get(1)
                .context("usage: microscale figure <id>")?;
            let mut ctx = ctx_from(&args)?;
            println!("{}", experiments::figure(&mut ctx, id)?);
        }
        "table" => {
            let id = args
                .positional
                .get(1)
                .context("usage: microscale table <id>")?;
            let mut ctx = ctx_from(&args)?;
            println!("{}", experiments::table(&mut ctx, id)?);
        }
        "all" => {
            let mut ctx = ctx_from(&args)?;
            let mut out = String::new();
            for id in [
                "1a", "1b", "2a", "2b", "2c", "3a", "3b", "3c", "4a", "4b",
                "5a", "5b", "6", "7", "8", "9", "10", "11", "12", "13",
                "14", "15", "16", "17",
            ] {
                log::info!("figure {id}...");
                out.push_str(&experiments::figure(&mut ctx, id)?);
                out.push('\n');
            }
            for id in experiments::ALL_TABLES {
                log::info!("table {id}...");
                out.push_str(&experiments::table(&mut ctx, id)?);
                out.push('\n');
            }
            out.push_str(&experiments::hwx::appendix_k());
            out.push_str(&experiments::hwx::sec31_costs());
            experiments::ppl::export_csv(&mut ctx)?;
            ctx.sink()?.text("all_figures.txt", &out)?;
            println!("{out}");
        }
        "hw" => {
            println!("{}", experiments::hwx::fig4a());
            println!("{}", experiments::hwx::appendix_k());
            println!("{}", experiments::hwx::sec31_costs());
        }
        "train" => {
            let ctx = ctx_from(&args)?;
            let sess = ctx.session()?;
            let m = sess.manifest().clone();
            let corpus = Corpus::default_language(m.model.vocab);
            let steps = args.get_usize("steps", 240)?;
            let cfg = TrainConfig {
                steps,
                lr: args.get_f64("lr", 1.5e-3)?,
                warmup: steps / 10 + 1,
                weight_decay: args.get_f64("wd", 0.01)?,
                seed: args.get_usize("seed", 1)? as u64,
                log_every: (steps / 20).max(1),
            };
            let init = Params::init(&m, 2026);
            let (trained, curve) = train(sess, &corpus, &init, &cfg)?;
            let out = PathBuf::from(
                args.get_or("out", &format!("models/base-s{steps}.bin")),
            );
            if let Some(p) = out.parent() {
                std::fs::create_dir_all(p).ok();
            }
            trained.save(&out)?;
            println!("saved {} params to {}", trained.numel(), out.display());
            for p in curve {
                println!("step {:>5}  loss {:.4}", p.step, p.loss);
            }
        }
        "models" => {
            let mut ctx = ctx_from(&args)?;
            let models = experiments::ppl::ensure_models(&mut ctx)?;
            let n_layers = ctx.session()?.manifest().model.n_layers;
            for m in &models {
                let spec = m.params.sigma_spectrum(n_layers);
                let sigmas: Vec<f64> = spec.iter().map(|(_, s)| *s).collect();
                let below = sigmas.iter().filter(|&&s| s < 2e-2).count();
                println!(
                    "{:<24} {} tensors, stored-σ ∈ [{:.1e}, {:.1e}], {}/{} below σ=2e-2",
                    m.name,
                    sigmas.len(),
                    sigmas.iter().cloned().fold(f64::MAX, f64::min),
                    sigmas.iter().cloned().fold(0.0, f64::max),
                    below,
                    sigmas.len()
                );
            }
        }
        "eval" => {
            let mut ctx = ctx_from(&args)?;
            let models = experiments::ppl::ensure_models(&mut ctx)?;
            let want = args.get_or("model", "granite-like");
            let m = models
                .iter()
                .find(|m| m.name == want)
                .with_context(|| format!("unknown model {want:?}"))?;
            let qcfg = if args.get_or("scale", "ue4m3") == "none" {
                QConfig::baseline()
            } else {
                QConfig::named(
                    &args.get_or("elem", "fp4_e2m1"),
                    &args.get_or("scale", "ue4m3"),
                    args.has("per-tensor"),
                )?
            };
            let bs = args.get_usize("bs", 8)?;
            let ppl = experiments::ppl::ppl_point(&mut ctx, m, &qcfg, bs)?;
            println!("{want} {} bs{bs}: perplexity {ppl:.4}", qcfg.id());
        }
        "theory" => {
            let elem = ElemFormat::from_name(&args.get_or("elem", "fp4_e2m1"))
                .context("bad --elem")?;
            let scale = scale_format(&args.get_or("scale", "ue4m3"))
                .context("bad --scale")?;
            let bs = args.get_usize("bs", 16)?;
            let lo = args.get_f64("sigma-lo", 1e-4)?;
            let hi = args.get_f64("sigma-hi", 2.0)?;
            let k = args.get_usize("points", 33)?;
            println!("sigma,mse_total,xi_ne_xmax,xi_eq_xmax,s_zero");
            for s in geomspace(lo, hi, k) {
                let b = theory::mse_quantized_scales(&elem, &scale, s, bs);
                println!(
                    "{s:.6e},{:.6e},{:.6e},{:.6e},{:.6e}",
                    b.total(),
                    b.xi_ne_xmax,
                    b.xi_eq_xmax,
                    b.s_zero
                );
            }
        }
        "quantize" => {
            let input = args.get("in").context("--in FILE (raw f32 LE)")?;
            let bytes = std::fs::read(input)?;
            let mut x: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let scheme = QuantScheme::new(
                ElemFormat::from_name(&args.get_or("elem", "fp4_e2m1"))
                    .context("bad --elem")?,
                scale_format(&args.get_or("scale", "ue4m3"))
                    .context("bad --scale")?,
                args.get_usize("bs", 16)?,
            )
            .with_per_tensor(args.has("per-tensor"));
            let pad = (scheme.block_size - x.len() % scheme.block_size)
                % scheme.block_size;
            x.extend(std::iter::repeat(0.0).take(pad));
            let xq = fake_quant(&scheme, &x);
            let mse = microscale::stats::mse_f32(&x, &xq);
            let out = args.get_or("out", &format!("{input}.fq"));
            let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
            for v in &xq[..xq.len() - pad] {
                f.write_all(&v.to_le_bytes())?;
            }
            println!(
                "{}: {} elems, mse {mse:.3e}, wrote {out}",
                scheme.id(),
                x.len() - pad
            );
        }
        "serve-bench" => {
            let mut opts =
                microscale::serve::bench::BenchOpts::new(args.has("smoke"));
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            opts.workers = args.get_usize("workers", opts.workers)?;
            opts.rounds = args.get_usize("rounds", opts.rounds)?;
            opts.serial_requests =
                args.get_usize("serial-requests", opts.serial_requests)?;
            if let Some(bs) = args.get("batch-sizes") {
                opts.batch_sizes = bs
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--batch-sizes {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(sc) = args.get("shards") {
                opts.shard_counts = sc
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--shards {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(q) = args.get("qconfig") {
                let cfg = microscale::runtime::qconfig::PerLayerQConfig::parse(q)
                    .with_context(|| format!("--qconfig {q:?}"))?;
                opts.qconfigs = Some(vec![(q.to_string(), cfg)]);
            }
            if let Some(f) = args.get("qconfig-file") {
                let (label, cfg, bs, _kv) =
                    microscale::coordinator::tuner::load_qconfig_file(
                        std::path::Path::new(f),
                    )?;
                opts.qconfigs = Some(vec![(label, cfg)]);
                opts.block_size = Some(bs);
            }
            microscale::serve::bench::run(&opts)?;
        }
        "decode-bench" => {
            let mut opts = microscale::serve::decode_bench::DecodeBenchOpts::new(
                args.has("smoke"),
            );
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            opts.prompt_len = args.get_usize("prompt", opts.prompt_len)?;
            opts.max_new = args.get_usize("max-new", opts.max_new)?;
            opts.rounds = args.get_usize("rounds", opts.rounds)?;
            opts.baseline_requests = args
                .get_usize("baseline-requests", opts.baseline_requests)?;
            if let Some(cs) = args.get("concurrency") {
                opts.concurrency = cs
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--concurrency {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(sc) = args.get("shards") {
                opts.shard_counts = sc
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--shards {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(ks) = args.get("spec") {
                opts.spec_ks = ks
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--spec {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(q) = args.get("qconfig") {
                let cfg = microscale::runtime::qconfig::PerLayerQConfig::parse(q)
                    .with_context(|| format!("--qconfig {q:?}"))?;
                opts.qconfigs = Some(vec![(q.to_string(), cfg)]);
            }
            if let Some(f) = args.get("qconfig-file") {
                let (label, cfg, bs, _kv) =
                    microscale::coordinator::tuner::load_qconfig_file(
                        std::path::Path::new(f),
                    )?;
                opts.qconfigs = Some(vec![(label, cfg)]);
                opts.block_size = Some(bs);
            }
            microscale::serve::decode_bench::run(&opts)?;
        }
        "spec-bench" => {
            let mut opts = microscale::serve::spec_bench::SpecBenchOpts::new(
                args.has("smoke"),
            );
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            opts.k = args.get_usize("k", opts.k)?;
            opts.prompt_len = args.get_usize("prompt", opts.prompt_len)?;
            opts.max_new = args.get_usize("max-new", opts.max_new)?;
            opts.requests = args.get_usize("requests", opts.requests)?;
            if let Some(bs) = args.get("block-sizes") {
                opts.block_sizes = bs
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--block-sizes {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            microscale::serve::spec_bench::run(&opts)?;
        }
        "kv-bench" => {
            let mut opts =
                microscale::serve::kv_bench::KvBenchOpts::new(args.has("smoke"));
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            opts.concurrency = args.get_usize("concurrency", opts.concurrency)?;
            opts.prompt_len = args.get_usize("prompt", opts.prompt_len)?;
            opts.max_new = args.get_usize("max-new", opts.max_new)?;
            opts.requests = args.get_usize("requests", opts.requests)?;
            opts.page_rows = args.get_usize("page-rows", opts.page_rows)?;
            opts.budget_seqs = args.get_f64("budget-seqs", opts.budget_seqs)?;
            if let Some(f) = args.get("qconfig-file") {
                let (_label, cfg, bs, kv) =
                    microscale::coordinator::tuner::load_qconfig_file(
                        std::path::Path::new(f),
                    )?;
                opts.block_size = Some(bs);
                opts.tuned = Some((cfg, kv));
            }
            microscale::serve::kv_bench::run(&opts)?;
        }
        "traffic-bench" => {
            let mut opts = microscale::serve::traffic::TrafficOpts::new(
                args.has("smoke"),
            );
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            opts.requests = args.get_usize("requests", opts.requests)?;
            opts.concurrency = args.get_usize("concurrency", opts.concurrency)?;
            opts.seed = args.get_usize("seed", opts.seed as usize)? as u64;
            opts.prefix_len = args.get_usize("prefix-len", opts.prefix_len)?;
            opts.shared_ratio =
                args.get_f64("shared-ratio", opts.shared_ratio)?;
            opts.batch_frac = args.get_f64("batch-frac", opts.batch_frac)?;
            opts.cancel_frac = args.get_f64("cancel-frac", opts.cancel_frac)?;
            opts.burst_len = args.get_usize("burst-len", opts.burst_len)?;
            opts.rate_per_s = args.get_f64("rate", opts.rate_per_s)?;
            opts.burst_gap_ms =
                args.get_f64("burst-gap-ms", opts.burst_gap_ms)?;
            opts.page_rows = args.get_usize("page-rows", opts.page_rows)?;
            opts.budget_seqs = args.get_f64("budget-seqs", opts.budget_seqs)?;
            // SLO limits are opt-in: absent flags leave the report's
            // slo_verdict null (latency is host-dependent)
            for (flag, slot) in [
                ("slo-ttft-p95-ms", &mut opts.slo_ttft_p95_ms),
                ("slo-itl-p95-ms", &mut opts.slo_itl_p95_ms),
            ] {
                if let Some(v) = args.get(flag) {
                    *slot = Some(v.parse::<f64>().map_err(|e| {
                        anyhow::anyhow!("--{flag} {v:?}: {e}")
                    })?);
                }
            }
            microscale::serve::traffic::run(&opts)?;
        }
        "tune" => {
            let mut opts = microscale::coordinator::tuner::TuneOpts::new(
                args.has("smoke"),
            );
            if let Some(out) = args.get("out") {
                opts.out = PathBuf::from(out);
            }
            if let Some(emit) = args.get("emit") {
                opts.emit = PathBuf::from(emit);
            }
            opts.seed = args.get_usize("seed", opts.seed as usize)? as u64;
            opts.budget_frac =
                args.get_f64("budget-frac", opts.budget_frac)?;
            if let Some(b) = args.get("budget-bytes") {
                opts.budget_bytes = Some(b.parse::<usize>().map_err(|e| {
                    anyhow::anyhow!("--budget-bytes {b:?}: {e}")
                })?);
            }
            if let Some(v) = args.get("elems") {
                opts.elems =
                    v.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(v) = args.get("scales") {
                opts.scales =
                    v.split(',').map(|s| s.trim().to_string()).collect();
            }
            if let Some(v) = args.get("block-sizes") {
                opts.block_sizes = v
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|e| {
                            anyhow::anyhow!("--block-sizes {s:?}: {e}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
            }
            if args.has("no-rotate") {
                opts.rotate = false;
            }
            microscale::coordinator::tuner::run(&opts)?;
        }
        "kv-sweep" => {
            let fast = args.has("fast");
            let csv = PathBuf::from(args.get_or("results", "results"))
                .join("kv_anomaly.csv");
            println!(
                "{}",
                experiments::kvx::anomaly_sweep(fast, Some(csv.as_path()))?
            );
        }
        "selftest" => {
            let ctx = ctx_from(&args)?;
            let sess = ctx.session()?;
            let m = sess.manifest().clone();
            println!("artifacts: {} ({} params)", m.artifacts.len(), m.param_count());
            let corpus = Corpus::default_language(m.model.vocab);
            let params = Params::init(&m, 1);
            let dev = DeviceParams::upload(sess, &params)?;
            let batches = corpus.batches(9, 1, m.eval_batch, m.model.seq_len + 1);
            let base = eval::perplexity(sess, &dev, &QConfig::baseline(), 8, &batches)?;
            let q = eval::perplexity(sess, &dev, &QConfig::fp4("ue4m3")?, 8, &batches)?;
            println!("random-init ppl: baseline {base:.2}, ue4m3 {q:.2}");
            let (da, dd) = microscale::hw::pe::appendix_k_comparison();
            println!("hw model: Δarea {da:+.2}%, Δdelay {dd:+.1} ps");
            let b = theory::mse_quantized_scales(
                &ElemFormat::FP4,
                &microscale::formats::UE4M3,
                0.02,
                16,
            );
            println!("theory @ σ=0.02, bs16: {:.3e}", b.total());
            println!("selftest OK");
        }
        other => {
            println!(
                "microscale — reproduction of 'Is Finer Better?' (IBM, 2026)\n\
                 \n\
                 commands: figure <id> | table <1|2|3> | all | hw | train |\n\
                 models | eval | theory | quantize | serve-bench |\n\
                 decode-bench | spec-bench | kv-bench | traffic-bench |\n\
                 tune | kv-sweep | selftest\n\
                 figures: 1a 1b 2a 2b 2c 3a 3b 3c 4a 4b 5a 5b 6 7 8 9 10 11\n\
                 12 13 14 15 16 17\n\
                 flags: --fast --results DIR --models DIR --artifacts DIR\n\
                 --train-steps N --quiet\n\
                 serve-bench flags: --smoke --workers N --batch-sizes 8,32\n\
                 --rounds N --serial-requests N --shards 1,2,4 --qconfig CFG\n\
                 --qconfig-file tuned_qconfig.json --out FILE\n\
                 decode-bench flags: --smoke --concurrency 1,4,8 --prompt N\n\
                 --max-new N --rounds N --baseline-requests N --shards 1,2\n\
                 --spec 1,2,4 --qconfig CFG --qconfig-file FILE --out FILE\n\
                 spec-bench flags: --smoke --k N --prompt N --max-new N\n\
                 --requests N --block-sizes 4,8,16,32 --out FILE\n\
                 kv-bench flags: --smoke --concurrency N --prompt N\n\
                 --max-new N --requests N --page-rows N --budget-seqs X\n\
                 --qconfig-file FILE --out FILE\n\
                 tune flags: --smoke --seed N --budget-frac X\n\
                 --budget-bytes N --elems fp4_e2m1,fp8_e4m3\n\
                 --scales ue4m3,ue5m3,e8m0 --block-sizes 8,16,32\n\
                 --no-rotate --out FILE --emit FILE\n\
                 traffic-bench flags: --smoke --requests N --concurrency N\n\
                 --seed N --prefix-len N --shared-ratio X --batch-frac X\n\
                 --cancel-frac X --burst-len N --rate X --burst-gap-ms X\n\
                 --page-rows N --budget-seqs X\n\
                 --slo-ttft-p95-ms X --slo-itl-p95-ms X --out FILE\n\
                 kv-sweep flags: --fast --results DIR"
            );
            if other != "help" {
                bail!("unknown command {other:?}");
            }
        }
    }
    Ok(())
}
