//! Level enumeration and Voronoi boundaries for quantization grids.
//!
//! The theoretical framework (Sec. 4) integrates the per-bin error over
//! each quantization level's Voronoi cell `[a_j, b_j]` (eq. 2/3) and sums
//! the probability mass of each *scale* level's cell (eq. 6/33). This
//! module enumerates the positive levels of a [`MiniFloat`] or integer
//! grid and their round-to-nearest boundaries.

use super::{ElemFormat, MiniFloat};

/// A quantization level and its Voronoi cell under round-to-nearest.
#[derive(Debug, Clone, Copy)]
pub struct Level {
    /// The representable value.
    pub q: f64,
    /// Lower cell boundary (inputs in `[lo, hi)` round to `q`).
    pub lo: f64,
    /// Upper cell boundary.
    pub hi: f64,
}

/// Enumerate the positive levels of a minifloat grid, capped at
/// `max_levels` (guards E8M0/BF16 whose full enumeration is huge but whose
/// tail carries no probability mass for our σ ranges).
pub fn positive_levels(fmt: &MiniFloat, max_levels: usize) -> Vec<f64> {
    let mut out = Vec::new();
    let m = fmt.m_bits;
    let quantum = 2.0f64.powi(fmt.e_min - m);
    // subnormals: r * quantum for r = 1 .. 2^m - 1
    for r in 1..(1i64 << m) {
        if out.len() >= max_levels {
            return out;
        }
        let v = r as f64 * quantum;
        if v >= f32::MIN_POSITIVE as f64 {
            out.push(v);
        }
    }
    // normals
    let mut e = fmt.e_min;
    loop {
        for r in (1i64 << m)..(1i64 << (m + 1)) {
            let v = r as f64 * 2.0f64.powi(e - m);
            if v > fmt.max_val as f64 || out.len() >= max_levels {
                return out;
            }
            if v >= f32::MIN_POSITIVE as f64 {
                out.push(v);
            }
        }
        e += 1;
    }
}

/// Positive levels of an element format (FP: minifloat levels; INT: 1..max).
pub fn elem_positive_levels(fmt: &ElemFormat) -> Vec<f64> {
    match fmt {
        ElemFormat::Fp(f) => positive_levels(f, 4096),
        ElemFormat::Int(m) => (1..=(*m as i64)).map(|v| v as f64).collect(),
    }
}

/// Voronoi cells of the *positive* levels (plus the implicit 0 level),
/// under round-to-nearest: cell(q_j) = [(q_{j-1}+q_j)/2, (q_j+q_{j+1})/2],
/// the last cell extending to `top` (saturation absorbs everything above).
pub fn voronoi(levels: &[f64], top: f64) -> Vec<Level> {
    let mut out = Vec::with_capacity(levels.len());
    for (j, &q) in levels.iter().enumerate() {
        let lo = if j == 0 {
            q / 2.0 // boundary with the 0 level
        } else {
            (levels[j - 1] + q) / 2.0
        };
        let hi = if j + 1 < levels.len() {
            (q + levels[j + 1]) / 2.0
        } else {
            top
        };
        out.push(Level { q, lo, hi });
    }
    out
}

/// The zero-level cell `[0, q_1/2)` (paper's `[0, s_min/2]`, App. F.3).
pub fn zero_cell_hi(levels: &[f64]) -> f64 {
    levels.first().map(|&q| q / 2.0).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FP4_E2M1, UE4M3, UE5M3};

    #[test]
    fn fp4_levels() {
        let lv = positive_levels(&FP4_E2M1, 100);
        assert_eq!(lv, vec![0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn ue4m3_level_count_and_range() {
        let lv = positive_levels(&UE4M3, 10_000);
        // 7 subnormals + 14 full exponents (e_min..=7) x 8 mantissas +
        // 7 levels at e=8 (capped at 448 = 1.75 * 2^8, i.e. r = 8..=14).
        assert_eq!(lv[0], 2.0f64.powi(-9));
        assert_eq!(*lv.last().unwrap(), 448.0);
        assert_eq!(lv.len(), 7 + 14 * 8 + 7);
        // strictly increasing
        assert!(lv.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn ue5m3_extends_low_range() {
        let lv = positive_levels(&UE5M3, 10_000);
        assert_eq!(lv[0], 2.0f64.powi(-17));
        assert_eq!(*lv.last().unwrap(), 122880.0);
    }

    #[test]
    fn voronoi_cells_tile_the_axis() {
        let lv = positive_levels(&UE4M3, 10_000);
        let cells = voronoi(&lv, 1e9);
        assert_eq!(cells[0].lo, zero_cell_hi(&lv));
        for w in cells.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
        }
        // every cell contains its level
        for c in &cells {
            assert!(c.lo <= c.q && c.q <= c.hi, "{c:?}");
        }
    }

    #[test]
    fn voronoi_matches_cast() {
        // midpoint-rounding cells agree with the RNE cast away from ties
        let lv = positive_levels(&UE4M3, 10_000);
        let cells = voronoi(&lv, f64::INFINITY);
        let mut rng = crate::dist::Pcg64::new(3);
        for _ in 0..2000 {
            let x = (10.0f64.powf(rng.uniform() * 8.0 - 4.0)) as f32;
            let y = UE4M3.cast(x) as f64;
            let cell = cells
                .iter()
                .find(|c| (x as f64) >= c.lo && (x as f64) < c.hi);
            match cell {
                Some(c) => assert_eq!(y, c.q, "x={x}"),
                None => assert_eq!(y, 0.0, "x={x}"),
            }
        }
    }
}
