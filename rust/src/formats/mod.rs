//! Numeric format registry: every element and scale format of the paper.
//!
//! A format is a saturating, signed- or unsigned-magnitude
//! [`MiniFloat`] grid (parametric mantissa bits / min normal exponent /
//! max value), mirroring `python/compile/kernels/ref.py` bit-for-bit
//! (enforced by `rust/tests/golden.rs`). Integer element formats (INT4)
//! are a separate cast.
//!
//! | name        | m | e_min | max      | min subnormal | paper ref     |
//! |-------------|---|-------|----------|---------------|---------------|
//! | FP4 E2M1    | 1 | 0     | 6        | 0.5           | Sec. 2.1      |
//! | FP6 E2M3    | 3 | 0     | 7.5      | 2^-3          | OCP elements  |
//! | FP6 E3M2    | 2 | -2    | 28       | 2^-4          | OCP elements  |
//! | FP8 E4M3    | 3 | -6    | 448      | 2^-9          | OCP elements  |
//! | UE4M3       | 3 | -6    | 448      | 2^-9          | Sec. 2.1      |
//! | UE5M3       | 3 | -14   | 122880   | 2^-17         | Sec. 5.2 ours |
//! | UE4M4       | 4 | -6    | 496      | 2^-10         | App. J        |
//! | UE5M1       | 1 | -14   | 98304    | 2^-15         | App. H        |
//! | UE4M2       | 2 | -6    | 448      | 2^-8          | App. H        |
//! | E8M0 (PoT)  | 0 | -126  | 2^127    | —             | OCP MX        |
//! | BF16 scale  | 7 | -126  | 3.39e38  | —             | "unquantized" |

pub mod levels;

use crate::util::{floor_log2, ldexp2};

/// A saturating minifloat grid; see module docs. `Copy`-able and cheap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MiniFloat {
    /// Mantissa bits (excluding the implicit leading 1).
    pub m_bits: i32,
    /// Minimum normal exponent; values below `2^e_min` are subnormal on
    /// this grid.
    pub e_min: i32,
    /// Largest representable magnitude (casts saturate here).
    pub max_val: f32,
    /// Stable display/cache-key name (e.g. `"ue5m3"`).
    pub name: &'static str,
}

impl MiniFloat {
    /// Const constructor (all the named formats below use it).
    pub const fn new(
        m_bits: i32,
        e_min: i32,
        max_val: f32,
        name: &'static str,
    ) -> Self {
        MiniFloat { m_bits, e_min, max_val, name }
    }

    /// Smallest positive representable value: the subnormal quantum
    /// `2^(e_min - m_bits)` (paper's `s_min`, App. F.3).
    pub fn min_subnormal(&self) -> f32 {
        ldexp2(1.0, self.e_min - self.m_bits)
    }

    /// Round non-negative `x` to this grid (RNE, saturating).
    ///
    /// Bit-identical to `ref.cast_minifloat`: clamp to max, flush
    /// f32-subnormal inputs (DAZ — XLA CPU semantics), extract the grid
    /// exponent from the f32 exponent field, round half-even on the
    /// exactly-rescaled value.
    /// (A branchless select-style formulation was tried and measured
    /// SLOWER on this target — no SIMD materialized and the scalar path
    /// paid for the extra selects; see EXPERIMENTS.md §Perf — so the
    /// early-return form stays.)
    #[inline(always)]
    pub fn cast(&self, x: f32) -> f32 {
        let xc = if x < self.max_val { x } else { self.max_val };
        if !(xc >= f32::MIN_POSITIVE) {
            return 0.0; // zero, negative, NaN, or f32-subnormal (DAZ)
        }
        let g = floor_log2(xc);
        let p = g.max(self.e_min) - self.m_bits;
        let y = ldexp2(xc, -p);
        let r = y.round_ties_even();
        ldexp2(r, p)
    }

    /// Signed-magnitude cast (element formats).
    #[inline(always)]
    pub fn cast_signed(&self, x: f32) -> f32 {
        let m = self.cast(x.abs());
        if x.is_sign_negative() {
            -m
        } else {
            m
        }
    }
}

/// INT-k symmetric element cast: RNE then clamp to ±int_max (App. G).
#[inline]
pub fn cast_int_symmetric(x: f32, int_max: f32) -> f32 {
    x.round_ties_even().clamp(-int_max, int_max)
}

// -- element formats ---------------------------------------------------------

/// FP4 E2M1 — the paper's primary element format (Sec. 2.1).
pub const FP4_E2M1: MiniFloat = MiniFloat::new(1, 0, 6.0, "fp4_e2m1");
/// FP6 E2M3 — OCP MX element option (precision-leaning).
pub const FP6_E2M3: MiniFloat = MiniFloat::new(3, 0, 7.5, "fp6_e2m3");
/// FP6 E3M2 — OCP MX element option (range-leaning).
pub const FP6_E3M2: MiniFloat = MiniFloat::new(2, -2, 28.0, "fp6_e3m2");
/// FP8 E4M3 — OCP MX element option (same grid the UE4M3 scale uses,
/// but signed); exercised by the packed-tensor path ([`crate::quant::packed`]).
pub const FP8_E4M3: MiniFloat = MiniFloat::new(3, -6, 448.0, "fp8_e4m3");

// -- scale formats ------------------------------------------------------------

pub const UE4M3: MiniFloat = MiniFloat::new(3, -6, 448.0, "ue4m3");
/// The paper's proposed format (Sec. 5.2): the unused sign bit of UE4M3
/// repurposed as a 5th exponent bit. Same precision, min subnormal drops
/// from 2^-9 to 2^-17.
pub const UE5M3: MiniFloat = MiniFloat::new(3, -14, 122880.0, "ue5m3");
/// App. J alternative: the unused bit extends the mantissa instead.
pub const UE4M4: MiniFloat = MiniFloat::new(4, -6, 496.0, "ue4m4");
/// FP6 scale candidates (App. H), sign bit repurposed.
pub const UE5M1: MiniFloat = MiniFloat::new(1, -14, 98304.0, "ue5m1");
pub const UE4M2: MiniFloat = MiniFloat::new(2, -6, 448.0, "ue4m2");
/// OCP MX power-of-two scale, clamped to the normal-f32 exponent range.
pub const E8M0: MiniFloat = MiniFloat::new(0, -126, 1.7014118e38, "e8m0");
/// Quasi-continuous "non-quantized" scales (Fig. 1(a) baseline).
pub const BF16_SCALE: MiniFloat =
    MiniFloat::new(7, -126, 3.3895314e38, "bf16");

/// Every scale format the experiments sweep (Sec. 2.1 + App. H/J).
pub const SCALE_FORMATS: [MiniFloat; 7] =
    [UE4M3, UE5M3, UE4M4, UE5M1, UE4M2, E8M0, BF16_SCALE];

/// Look up a scale format by its stable name (CLI flags, cache keys).
pub fn scale_format(name: &str) -> Option<MiniFloat> {
    SCALE_FORMATS.iter().copied().find(|f| f.name == name)
}

/// Element format spec: either a minifloat or a symmetric integer grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ElemFormat {
    /// Signed-magnitude minifloat elements (FP4/FP6/FP8).
    Fp(MiniFloat),
    /// `Int(max)`: integers in [-max, max] (INT4 => 7).
    Int(f32),
}

impl ElemFormat {
    /// FP4 E2M1 elements (the paper's default).
    pub const FP4: ElemFormat = ElemFormat::Fp(FP4_E2M1);
    /// FP8 E4M3 elements (OCP MXFP8).
    pub const FP8: ElemFormat = ElemFormat::Fp(FP8_E4M3);
    /// Symmetric INT4 elements, levels −7..=7 (App. G).
    pub const INT4: ElemFormat = ElemFormat::Int(7.0);

    /// Parse a format name as used in CLI flags and cache keys.
    pub fn from_name(name: &str) -> Option<ElemFormat> {
        match name {
            "fp4_e2m1" | "fp4" => Some(ElemFormat::FP4),
            "fp6_e2m3" => Some(ElemFormat::Fp(FP6_E2M3)),
            "fp6_e3m2" => Some(ElemFormat::Fp(FP6_E3M2)),
            "fp8_e4m3" | "fp8" => Some(ElemFormat::FP8),
            "int4" => Some(ElemFormat::INT4),
            "int8" => Some(ElemFormat::Int(127.0)),
            _ => None,
        }
    }

    /// Stable display/cache-key name (inverse of [`ElemFormat::from_name`]).
    pub fn name(&self) -> &'static str {
        match self {
            ElemFormat::Fp(f) => f.name,
            ElemFormat::Int(m) if *m == 7.0 => "int4",
            ElemFormat::Int(m) if *m == 127.0 => "int8",
            ElemFormat::Int(_) => "int",
        }
    }

    /// `C` in s = Q(absmax / C): the element format's max value.
    #[inline]
    pub fn max_val(&self) -> f32 {
        match self {
            ElemFormat::Fp(f) => f.max_val,
            ElemFormat::Int(m) => *m,
        }
    }

    #[inline]
    pub fn cast(&self, x: f32) -> f32 {
        match self {
            ElemFormat::Fp(f) => f.cast_signed(x),
            ElemFormat::Int(m) => cast_int_symmetric(x, *m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_min_subnormals() {
        assert_eq!(UE4M3.min_subnormal(), 2f32.powi(-9));
        assert_eq!(UE5M3.min_subnormal(), 2f32.powi(-17));
        assert_eq!(UE4M4.min_subnormal(), 2f32.powi(-10));
        assert_eq!(UE5M1.min_subnormal(), 2f32.powi(-15));
        assert_eq!(UE4M2.min_subnormal(), 2f32.powi(-8));
        assert_eq!(FP4_E2M1.min_subnormal(), 0.5);
    }

    #[test]
    fn fp4_level_set() {
        let mut seen = std::collections::BTreeSet::new();
        let mut x = -8.0f32;
        while x <= 8.0 {
            seen.insert((FP4_E2M1.cast_signed(x).abs() * 2.0) as i32);
            x += 0.003;
        }
        let want: std::collections::BTreeSet<i32> =
            [0, 1, 2, 3, 4, 6, 8, 12].into_iter().collect();
        assert_eq!(seen, want);
    }

    #[test]
    fn saturation_and_ties() {
        assert_eq!(UE4M3.cast(449.0), 448.0);
        assert_eq!(UE4M3.cast(1e30), 448.0);
        assert_eq!(UE4M3.cast(1.0625), 1.0); // tie -> even
        assert_eq!(UE4M3.cast(1.1875), 1.25);
        assert_eq!(UE4M3.cast(2f32.powi(-10)), 0.0); // tie at s_min/2 -> 0
        assert_eq!(UE4M3.cast(2f32.powi(-10) * 1.1), 2f32.powi(-9));
        assert_eq!(UE5M3.cast(2f32.powi(-18)), 0.0);
        assert_eq!(UE5M3.cast(2f32.powi(-17)), 2f32.powi(-17));
    }

    #[test]
    fn e8m0_is_power_of_two() {
        for x in [0.7f32, 0.8, 3.0, 5.9, 100.0] {
            let y = E8M0.cast(x);
            assert_eq!(y.to_bits() & 0x007F_FFFF, 0, "{x} -> {y}");
        }
        assert_eq!(E8M0.cast(0.7), 0.5);
        assert_eq!(E8M0.cast(0.8), 1.0);
    }

    #[test]
    fn int4_levels() {
        let mut seen = std::collections::BTreeSet::new();
        let mut x = -9.0f32;
        while x <= 9.0 {
            seen.insert(cast_int_symmetric(x, 7.0) as i32);
            x += 0.01;
        }
        assert_eq!(seen, (-7..=7).collect());
    }

    #[test]
    fn cast_monotone() {
        crate::util::check::property("cast monotone", 50, |g| {
            let fmt = *g.pick(&SCALE_FORMATS);
            let a = g.log_uniform(1e-12, 1e6) as f32;
            let b = g.log_uniform(1e-12, 1e6) as f32;
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(fmt.cast(lo) <= fmt.cast(hi), "{fmt:?} {lo} {hi}");
        });
    }

    #[test]
    fn ue5m3_grid_nests_ue4m3() {
        // every UE4M3-representable value is UE5M3-representable, so the
        // UE5M3 cast error is pointwise <= the UE4M3 cast error below the
        // shared max (the formal core of the Sec. 5.2 claim)
        crate::util::check::property("ue5m3 nests ue4m3", 80, |g| {
            let x = g.log_uniform(1e-7, 448.0) as f32;
            let e43 = (UE4M3.cast(x) - x).abs();
            let e53 = (UE5M3.cast(x) - x).abs();
            assert!(e53 <= e43 + f32::EPSILON * x.abs(), "x={x} {e53} {e43}");
            // and UE4M3 outputs are fixed points of the UE5M3 cast
            let y = UE4M3.cast(x);
            assert_eq!(UE5M3.cast(y), y);
        });
    }

    #[test]
    fn signed_cast_is_odd() {
        crate::util::check::property("cast odd symmetry", 60, |g| {
            let fmt = if g.bool() { FP4_E2M1 } else { FP6_E3M2 };
            let x = (g.normal(0.0, 2.0)) as f32;
            assert_eq!(fmt.cast_signed(-x).to_bits(), (-fmt.cast_signed(x)).to_bits());
        });
    }

    #[test]
    fn cast_idempotent_on_outputs() {
        crate::util::check::property("cast idempotent", 50, |g| {
            let fmt = *g.pick(&SCALE_FORMATS);
            let x = g.log_uniform(1e-12, 1e6) as f32;
            let y = fmt.cast(x);
            assert_eq!(fmt.cast(y), y);
        });
    }
}
