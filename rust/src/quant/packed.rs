//! Packed MX tensors: real bit-packed storage for microscaling formats.
//!
//! The analytic storage model (Sec. 3.1, [`crate::hw::memory`]) prices a
//! block format at `elem_bits/8 + scale_bits/8/N` bytes per element.
//! This module *materializes* that layout so the compression claims can
//! be measured on real bytes and the decode path can be timed:
//!
//! * element field — one `elem_bits`-wide code per value (4 bits for
//!   FP4/INT4, 6 for FP6, 8 for FP8/INT8), bit-packed LSB-first into a
//!   contiguous byte stream. Codes are sign-magnitude: the top bit is the
//!   sign (preserving `-0.0`, which the fake-quant path produces for
//!   small negative inputs), the low bits index the format's magnitude
//!   level table ([`crate::formats::levels`]).
//! * scale field — **one byte per block**, a level-table index over the
//!   non-negative scale grid. Every FP8/FP6 scale format of the paper
//!   fits: UE4M3 has 127 levels incl. zero, UE5M3 exactly 256 (the
//!   repurposed sign bit doubles the exponent range — the whole point of
//!   the format), E8M0 255. BF16 scales need 16 bits and are rejected
//!   ([`PackedMxTensor::encode`] returns an error; the experiments treat
//!   BF16 scales as the *unquantized* baseline, which is never
//!   materialized in packed form).
//! * an f32 per-tensor factor (eq. 11) when the scheme uses "-S"
//!   variants.
//!
//! **Round-trip contract**: `decode(encode(x))` is bit-identical to
//! [`super::fake_quant`]`(scheme, x)` — the packed representation is a
//! lossless re-encoding of the quantizer's output, enforced by a
//! property test over random (σ, block size, element, scale) draws.

use crate::formats::levels::{elem_positive_levels, positive_levels};
use crate::formats::{ElemFormat, MiniFloat};

use super::QuantScheme;

/// Codes-per-level lookup for one non-negative quantization grid.
///
/// `levels[0]` is always `0.0`; magnitudes are encoded as their index.
#[derive(Debug, Clone)]
pub struct LevelCodec {
    levels: Vec<f32>,
    /// bits needed for a magnitude index
    mag_bits: u32,
}

impl LevelCodec {
    fn from_levels(levels: Vec<f32>) -> LevelCodec {
        debug_assert!(!levels.is_empty() && levels[0] == 0.0);
        debug_assert!(levels.windows(2).all(|w| w[0] < w[1]));
        // bit length of the largest index = ceil(log2(level count))
        let mag_bits = usize::BITS - (levels.len() - 1).leading_zeros();
        LevelCodec { levels, mag_bits: mag_bits.max(1) }
    }

    /// Codec for an element format's magnitude grid.
    pub fn for_elem(elem: &ElemFormat) -> LevelCodec {
        let mut levels = vec![0.0f32];
        levels.extend(elem_positive_levels(elem).into_iter().map(|v| v as f32));
        LevelCodec::from_levels(levels)
    }

    /// Codec for a scale format's non-negative grid; `None` when the
    /// format does not fit one byte (BF16 "unquantized" scales).
    pub fn for_scale(scale: &MiniFloat) -> Option<LevelCodec> {
        let pos = positive_levels(scale, 257);
        if pos.len() >= 256 {
            return None;
        }
        let mut levels = vec![0.0f32];
        levels.extend(pos.into_iter().map(|v| v as f32));
        Some(LevelCodec::from_levels(levels))
    }

    /// Bits per magnitude index.
    pub fn mag_bits(&self) -> u32 {
        self.mag_bits
    }

    /// Number of representable non-negative values (incl. zero).
    pub fn level_count(&self) -> usize {
        self.levels.len()
    }

    /// Exact-match encode of a non-negative grid value to its index;
    /// `None` if `mag` is not on the grid (inputs must come from the
    /// format's own cast — that is the round-trip contract; NaN, which
    /// the cast pipeline can only produce in pathological
    /// per-tensor-overflow regimes, is not on any grid).
    pub fn encode_mag(&self, mag: f32) -> Option<u32> {
        let i = self.levels.partition_point(|&l| l < mag);
        if i < self.levels.len() && self.levels[i].to_bits() == mag.to_bits() {
            Some(i as u32)
        } else {
            None
        }
    }

    /// Decode an index back to its grid value.
    #[inline]
    pub fn decode(&self, idx: u32) -> f32 {
        self.levels[idx as usize]
    }

    /// Signed decode LUT over the full sign-magnitude code space
    /// (`1 << (mag_bits + 1)` entries, the GEMM engine's "decode LUT":
    /// 16 entries for FP4, 64 for FP6, 256 for FP8). Entry
    /// `sign << mag_bits | mag` holds `±levels[mag]`; the negative-zero
    /// code decodes to `-0.0` (preserving the quantizer's signed zeros),
    /// and magnitude indices past the level table — which
    /// [`LevelCodec::encode_mag`] never produces — decode to `0.0` so the
    /// table is total.
    pub fn signed_lut(&self) -> Vec<f32> {
        let half = 1usize << self.mag_bits;
        let mut lut = vec![0.0f32; 2 * half];
        for code in 0..2 * half {
            let mag = code & (half - 1);
            let v = self.levels.get(mag).copied().unwrap_or(0.0);
            lut[code] = if code >= half { -v } else { v };
        }
        lut
    }
}

/// Quantize one block of raw values to sign-magnitude element codes —
/// the single implementation of the per-block encode pipeline
/// (absmax → scale cast → element cast → code), shared by
/// [`PackedMxTensor::encode`] and the GEMM operand encoder
/// ([`crate::quant::gemm::GemmOperand::quantize`]) so the two packed
/// encoders cannot drift apart. The scalar reference
/// [`super::fake_quant_block`] stays a separate implementation on
/// purpose: it is the golden-pinned oracle both encoders are
/// property-tested against.
///
/// Returns the cast block scale. `codes` must be at least
/// `block.len()` long; it is written for every element when the scale
/// is nonzero and left untouched for a collapsed block (callers keep
/// zero-initialized buffers, and code 0 is the canonical `+0.0`).
pub(crate) fn encode_block(
    scheme: &QuantScheme,
    elem_codec: &LevelCodec,
    s_t: f32,
    block: &[f32],
    codes: &mut [u8],
) -> crate::Result<f32> {
    let sign_shift = elem_codec.mag_bits();
    // SIMD-dispatched absmax fold (crate::util::simd) — the one
    // data-parallel stage of the encode pipeline. Each |v·s_t| is a
    // single rounded op per element and max is order-free over the
    // non-NaN results, so every level returns identical bits; the
    // cast + binary-search element encode below stays scalar (its
    // per-element control flow does not vectorize cheaply).
    let absmax = crate::util::simd::absmax_scaled(block, s_t);
    let s = scheme.scale.cast(absmax / scheme.elem.max_val());
    if s > 0.0 {
        for (cd, &v) in codes.iter_mut().zip(block) {
            let q = scheme.elem.cast((v * s_t) / s);
            let sign = (q.is_sign_negative() as u32) << sign_shift;
            let mag = elem_codec.encode_mag(q.abs()).ok_or_else(|| {
                anyhow::anyhow!(
                    "quantized value {q} is not on the {} grid \
                     (degenerate per-tensor overflow?)",
                    scheme.elem.name()
                )
            })?;
            *cd = (sign | mag) as u8;
        }
    }
    Ok(s)
}

/// Pack one code byte per element into `out`, LSB-first at `bits` per
/// code — the exact stream layout [`BitWriter`] produces (pinned by a
/// test below), writing into a caller-provided region instead of a
/// growable buffer. Shared with the KV page codec
/// ([`crate::serve::kvpool`]) so the two packed element-field layouts
/// cannot drift apart. `out` must hold `ceil(codes.len()·bits/8)`
/// bytes.
pub(crate) fn pack_codes(codes: &[u8], bits: u32, out: &mut [u8]) {
    let mut acc = 0u32;
    let mut nbits = 0u32;
    let mut i = 0usize;
    for &c in codes {
        acc |= (c as u32) << nbits;
        nbits += bits;
        while nbits >= 8 {
            out[i] = acc as u8;
            i += 1;
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out[i] = acc as u8;
    }
}

/// Inverse of [`pack_codes`]: read `out.len()` fixed-width codes from
/// `data`, one byte per code (matches [`BitReader`] — same test).
pub(crate) fn unpack_codes(data: &[u8], bits: u32, out: &mut [u8]) {
    let mask = (1u32 << bits) - 1;
    let mut acc = 0u32;
    let mut nbits = 0u32;
    let mut i = 0usize;
    for o in out.iter_mut() {
        while nbits < bits {
            acc |= (data[i] as u32) << nbits;
            i += 1;
            nbits += 8;
        }
        *o = (acc & mask) as u8;
        acc >>= bits;
        nbits -= bits;
    }
}

/// LSB-first bit packer for fixed-width codes.
struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn with_capacity(bits: usize) -> BitWriter {
        BitWriter { buf: Vec::with_capacity((bits + 7) / 8), acc: 0, nbits: 0 }
    }

    #[inline]
    fn push(&mut self, code: u32, bits: u32) {
        debug_assert!(bits <= 32 && (bits == 32 || code < (1u32 << bits)));
        self.acc |= (code as u64) << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.buf.push(self.acc as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push(self.acc as u8);
        }
        self.buf
    }
}

/// LSB-first bit reader matching [`BitWriter`].
struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(buf: &'a [u8]) -> BitReader<'a> {
        BitReader { buf, pos: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    fn read(&mut self, bits: u32) -> u32 {
        while self.nbits < bits {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
        let v = (self.acc & ((1u64 << bits) - 1)) as u32;
        self.acc >>= bits;
        self.nbits -= bits;
        v
    }
}

/// A microscaling tensor stored on real packed bytes.
///
/// See the module docs for the layout; construct with
/// [`PackedMxTensor::encode`], recover values with
/// [`PackedMxTensor::decode`] / [`PackedMxTensor::decode_into`].
pub struct PackedMxTensor {
    scheme: QuantScheme,
    len: usize,
    elem_bits: u32,
    /// eq. 11 factor the decode divides by (1.0 when per-tensor is off)
    s_t: f32,
    /// one scale-grid index per block
    scale_codes: Vec<u8>,
    /// bit-packed sign-magnitude element codes
    elem_data: Vec<u8>,
    elem_codec: LevelCodec,
    scale_codec: LevelCodec,
}

impl PackedMxTensor {
    /// Quantize `x` under `scheme` directly into packed form.
    ///
    /// Errors when the scheme has no packed representation (BF16 scales,
    /// or integer elements wider than 8 bits). `x.len()` must be a
    /// multiple of the block size.
    pub fn encode(scheme: &QuantScheme, x: &[f32]) -> crate::Result<PackedMxTensor> {
        let bs = scheme.block_size;
        anyhow::ensure!(bs > 0, "block size must be positive");
        anyhow::ensure!(
            x.len() % bs == 0,
            "len {} not divisible by block size {}",
            x.len(),
            bs
        );
        let elem_codec = LevelCodec::for_elem(&scheme.elem);
        let elem_bits = elem_codec.mag_bits() + 1; // + sign
        anyhow::ensure!(
            elem_bits <= 8,
            "element format {} needs {elem_bits} bits/code (max 8)",
            scheme.elem.name()
        );
        let Some(scale_codec) = LevelCodec::for_scale(&scheme.scale) else {
            anyhow::bail!(
                "scale format {} does not fit a 1-byte code (quasi-continuous \
                 scales have no packed MX representation)",
                scheme.scale.name
            );
        };

        // replicate the fake-quant pipeline exactly (see round-trip
        // contract): pre-scale, per-block cast, signs from the cast output
        let s_t = if scheme.per_tensor {
            let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            scheme.per_tensor_factor(absmax)
        } else {
            1.0
        };

        let n_blocks = x.len() / bs;
        let mut scale_codes = Vec::with_capacity(n_blocks);
        let mut w = BitWriter::with_capacity(x.len() * elem_bits as usize);
        let mut blk_codes = vec![0u8; bs];
        for block in x.chunks(bs) {
            blk_codes.fill(0); // collapsed blocks stay all-zero (App. F.3)
            let s = encode_block(scheme, &elem_codec, s_t, block, &mut blk_codes)?;
            let s_code = scale_codec.encode_mag(s).ok_or_else(|| {
                anyhow::anyhow!("scale {s} is not on the {} grid", scheme.scale.name)
            })?;
            scale_codes.push(s_code as u8);
            for &c in blk_codes.iter().take(block.len()) {
                w.push(c as u32, elem_bits);
            }
        }

        Ok(PackedMxTensor {
            scheme: *scheme,
            len: x.len(),
            elem_bits,
            s_t,
            scale_codes,
            elem_data: w.finish(),
            elem_codec,
            scale_codec,
        })
    }

    /// Dequantize into a fresh vector (bit-identical to
    /// [`super::fake_quant`] on the original input).
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        self.decode_into(&mut out);
        out
    }

    /// Dequantize into a caller-provided buffer of exactly
    /// [`PackedMxTensor::len`] elements.
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len, "decode buffer size");
        let bs = self.scheme.block_size;
        let mut r = BitReader::new(&self.elem_data);
        let sign_shift = self.elem_bits - 1;
        let mag_mask = (1u32 << sign_shift) - 1;
        for (block, &code) in out.chunks_mut(bs).zip(&self.scale_codes) {
            let s = self.scale_codec.decode(code as u32);
            if s > 0.0 {
                for v in block.iter_mut() {
                    let c = r.read(self.elem_bits);
                    // same op order as the quantizer: s * (±mag), then
                    // the eq. 11 un-scaling division
                    let mut y = s * self.elem_codec.decode(c & mag_mask);
                    if c >> sign_shift != 0 {
                        y = -y;
                    }
                    if self.s_t != 1.0 {
                        y /= self.s_t;
                    }
                    *v = y;
                }
            } else {
                for v in block.iter_mut() {
                    let _ = r.read(self.elem_bits);
                    *v = if self.s_t != 1.0 { 0.0 / self.s_t } else { 0.0 };
                }
            }
        }
    }

    /// Number of logical f32 elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the tensor has zero elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The quantization scheme this tensor was packed under.
    pub fn scheme(&self) -> &QuantScheme {
        &self.scheme
    }

    /// Bits per element code (sign + magnitude index).
    pub fn elem_bits(&self) -> u32 {
        self.elem_bits
    }

    /// The decoded scale of block `b`.
    pub fn block_scale(&self, b: usize) -> f32 {
        self.scale_codec.decode(self.scale_codes[b] as u32)
    }

    /// All block scales, decoded to f32 (one per block, in order).
    pub fn block_scales_f32(&self) -> Vec<f32> {
        self.scale_codes
            .iter()
            .map(|&c| self.scale_codec.decode(c as u32))
            .collect()
    }

    /// The eq. 11 per-tensor factor this tensor was packed under
    /// (`1.0` when per-tensor scaling is off).
    pub fn per_tensor_factor(&self) -> f32 {
        self.s_t
    }

    /// Unpack the bit-packed element field into one sign-magnitude code
    /// byte per element (the layout the GEMM engine computes on; see
    /// [`crate::quant::gemm::GemmOperand::from_packed`]).
    pub fn unpack_codes(&self) -> Vec<u8> {
        let mut r = BitReader::new(&self.elem_data);
        (0..self.len).map(|_| r.read(self.elem_bits) as u8).collect()
    }

    /// Payload bytes actually stored: packed element field + one scale
    /// byte per block (matches
    /// [`crate::hw::memory::packed_payload_bytes`] exactly).
    pub fn payload_bytes(&self) -> usize {
        self.elem_data.len() + self.scale_codes.len()
    }

    /// Measured storage cost in bits per element.
    pub fn bits_per_element(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.payload_bytes() as f64 * 8.0 / self.len as f64
    }

    /// Compression ratio vs a 16-bit (BF16) baseline.
    pub fn compression_vs_bf16(&self) -> f64 {
        16.0 / self.bits_per_element()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{BF16_SCALE, E8M0, FP6_E2M3, FP6_E3M2, UE4M3, UE5M3};
    use crate::hw::memory;
    use crate::quant::fake_quant;

    const PACKABLE_ELEMS: [ElemFormat; 6] = [
        ElemFormat::FP4,
        ElemFormat::Fp(FP6_E2M3),
        ElemFormat::Fp(FP6_E3M2),
        ElemFormat::FP8,
        ElemFormat::INT4,
        ElemFormat::Int(127.0),
    ];

    #[test]
    fn code_widths_match_the_formats() {
        let widths: Vec<u32> = PACKABLE_ELEMS
            .iter()
            .map(|e| LevelCodec::for_elem(e).mag_bits() + 1)
            .collect();
        assert_eq!(widths, vec![4, 6, 6, 8, 4, 8]);
        // UE5M3 uses its byte exactly: 255 positive levels + zero
        assert_eq!(LevelCodec::for_scale(&UE5M3).unwrap().level_count(), 256);
        assert_eq!(LevelCodec::for_scale(&UE4M3).unwrap().level_count(), 127);
        assert_eq!(LevelCodec::for_scale(&E8M0).unwrap().level_count(), 255);
    }

    #[test]
    fn bf16_scales_have_no_packed_form() {
        assert!(LevelCodec::for_scale(&BF16_SCALE).is_none());
        let scheme = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8);
        let err = PackedMxTensor::encode(&scheme, &[0.0; 8]).unwrap_err();
        assert!(format!("{err}").contains("1-byte"));
    }

    #[test]
    fn roundtrip_bit_exact_with_fake_quant() {
        // The ISSUE-level acceptance property: encode→decode equals the
        // fake-quant reference bit for bit, across formats, scales,
        // block sizes {8,16,32,64}, random σ, and the eq. 11 variants.
        crate::util::check::property("packed roundtrip", 80, |g| {
            let bs = *g.pick(&[8usize, 16, 32, 64]);
            let blocks = g.usize_in(1, 24);
            let sigma = g.log_uniform(1e-5, 10.0);
            let x = g.normal_vec_f32(bs * blocks, sigma);
            let scheme = QuantScheme::new(
                *g.pick(&PACKABLE_ELEMS),
                *g.pick(&[UE4M3, UE5M3, E8M0]),
                bs,
            )
            .with_per_tensor(g.bool());
            let packed = PackedMxTensor::encode(&scheme, &x).unwrap();
            let want = fake_quant(&scheme, &x);
            let got = packed.decode();
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} elem {i}: packed {a} vs fake_quant {b} (x={})",
                    scheme.id(),
                    x[i]
                );
            }
        });
    }

    #[test]
    fn decode_into_matches_decode() {
        let mut rng = crate::dist::Pcg64::new(5);
        let x = rng.normal_vec_f32(512, 0.01);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
        let p = PackedMxTensor::encode(&scheme, &x).unwrap();
        let a = p.decode();
        let mut b = vec![0.0f32; 512];
        p.decode_into(&mut b);
        assert!(a.iter().zip(&b).all(|(u, v)| u.to_bits() == v.to_bits()));
        assert_eq!(p.len(), 512);
        assert!(!p.is_empty());
        assert_eq!(p.scheme().block_size, 16);
    }

    #[test]
    fn payload_matches_memory_model() {
        let mut rng = crate::dist::Pcg64::new(6);
        for (elem, bits) in [
            (ElemFormat::FP4, 4u32),
            (ElemFormat::Fp(FP6_E2M3), 6),
            (ElemFormat::FP8, 8),
        ] {
            for bs in [8usize, 16, 32] {
                let n = bs * 50;
                let x = rng.normal_vec_f32(n, 0.02);
                let scheme = QuantScheme::new(elem, UE5M3, bs);
                let p = PackedMxTensor::encode(&scheme, &x).unwrap();
                assert_eq!(p.elem_bits(), bits);
                assert_eq!(
                    p.payload_bytes(),
                    memory::packed_payload_bytes(bits, n, bs),
                    "{} bs{bs}",
                    elem.name()
                );
                // measured bits/elem equals the Sec. 3.1 analytic model
                // with 8-bit scales
                let analytic = bits as f64 + 8.0 / bs as f64;
                assert!((p.bits_per_element() - analytic).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn fp4_bs32_hits_the_ocp_storage_point() {
        // MXFP4 with FP8 scales at N=32: 4.25 bits/elem → ~3.76x vs bf16
        let mut rng = crate::dist::Pcg64::new(8);
        let x = rng.normal_vec_f32(32 * 64, 0.02);
        let p = PackedMxTensor::encode(
            &QuantScheme::new(ElemFormat::FP4, UE4M3, 32),
            &x,
        )
        .unwrap();
        assert!((p.bits_per_element() - 4.25).abs() < 1e-12);
        assert!((p.compression_vs_bf16() - 16.0 / 4.25).abs() < 1e-12);
    }

    #[test]
    fn block_scales_are_recoverable() {
        let mut rng = crate::dist::Pcg64::new(9);
        let x = rng.normal_vec_f32(8 * 16, 5e-3);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let p = PackedMxTensor::encode(&scheme, &x).unwrap();
        let scales = crate::quant::fake_quant_into(&scheme, &mut x.clone());
        for (b, s) in scales.iter().enumerate() {
            assert_eq!(p.block_scale(b).to_bits(), s.to_bits(), "block {b}");
        }
    }

    #[test]
    fn bitrw_roundtrip() {
        let mut w = BitWriter::with_capacity(100 * 6);
        let codes: Vec<u32> = (0..100u32).map(|i| (i * 37) % 64).collect();
        for &c in &codes {
            w.push(c, 6);
        }
        let buf = w.finish();
        assert_eq!(buf.len(), (100 * 6 + 7) / 8);
        let mut r = BitReader::new(&buf);
        for &c in &codes {
            assert_eq!(r.read(6), c);
        }
    }

    #[test]
    fn slice_packers_match_bitwriter_stream() {
        // pack_codes/unpack_codes (the KV page codec's element field)
        // must produce byte-for-byte the BitWriter stream — one layout,
        // two writers
        for bits in [4u32, 6, 8] {
            let n = 53usize; // odd count: exercises the trailing byte
            let codes: Vec<u8> =
                (0..n).map(|i| ((i * 29) % (1 << bits)) as u8).collect();
            let mut w = BitWriter::with_capacity(n * bits as usize);
            for &c in &codes {
                w.push(c as u32, bits);
            }
            let want = w.finish();
            let mut got = vec![0u8; (n * bits as usize + 7) / 8];
            pack_codes(&codes, bits, &mut got);
            assert_eq!(got, want, "{bits}-bit pack");
            let mut back = vec![0u8; n];
            unpack_codes(&got, bits, &mut back);
            assert_eq!(back, codes, "{bits}-bit unpack");
        }
    }
}
