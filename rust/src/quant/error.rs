//! Quantization-error statistics: the measurements behind Figs. 2, 3, 6,
//! 7, 9 (per-tensor MSE vs σ; per-block MSE comparisons across block
//! sizes).

use super::{default_kernel, QuantScheme};
use crate::stats;

/// Per-tensor MSE of `x` under `scheme` (f64 accumulation).
///
/// Quantization runs on [`default_kernel`] (bit-identical to the scalar
/// reference, but tiled and threaded — these sweeps are the hot path of
/// every runtime-free figure).
pub fn tensor_mse(scheme: &QuantScheme, x: &[f32]) -> f64 {
    let xq = default_kernel().fake_quant(scheme, x);
    stats::mse_f32(x, &xq)
}

/// Per-tensor MSE and the tensor's pre-quantization σ (Fig. 2(b,c) axes).
pub fn mse_vs_sigma(scheme: &QuantScheme, x: &[f32]) -> (f64, f64) {
    let sigma = stats::std_dev_f32(x);
    (sigma, tensor_mse(scheme, x))
}

/// Per-block MSE pairs for the Fig. 2(a)/Fig. 6 density plots.
///
/// The tensor is split into reference blocks of `ref_block` elements; each
/// reference block's MSE is computed under quantization with block size
/// `ref_block` and with `fine_block` (< ref_block), using the *same
/// elements* — the paper's "compute the MSE in terms of the larger block
/// to enable a direct block-to-block comparison".
pub fn per_block_mse_pairs(
    elem_scale: &QuantScheme,
    x: &[f32],
    fine_block: usize,
    ref_block: usize,
) -> Vec<(f64, f64)> {
    assert!(ref_block % fine_block == 0 && ref_block >= fine_block);
    let coarse = QuantScheme { block_size: ref_block, ..*elem_scale };
    let fine = QuantScheme { block_size: fine_block, ..*elem_scale };
    let xc = default_kernel().fake_quant(&coarse, x);
    let xf = default_kernel().fake_quant(&fine, x);
    let mut out = Vec::with_capacity(x.len() / ref_block);
    for b in 0..x.len() / ref_block {
        let r = b * ref_block..(b + 1) * ref_block;
        out.push((
            stats::mse_f32(&x[r.clone()], &xf[r.clone()]),
            stats::mse_f32(&x[r.clone()], &xc[r]),
        ));
    }
    out
}

/// Fraction of reference blocks where the finer quantization has strictly
/// larger error (the "above the diagonal" mass of Fig. 2(a): ~25% for
/// granite-like tensors).
pub fn fraction_fine_worse(pairs: &[(f64, f64)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().filter(|(fine, coarse)| fine > coarse).count() as f64
        / pairs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, BF16_SCALE, UE4M3};

    #[test]
    fn per_block_pairs_shape() {
        let mut rng = Pcg64::new(4);
        let x = rng.normal_vec_f32(1024, 0.01);
        let s = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let pairs = per_block_mse_pairs(&s, &x, 8, 16);
        assert_eq!(pairs.len(), 64);
        assert!(pairs.iter().all(|(a, b)| *a >= 0.0 && *b >= 0.0));
    }

    #[test]
    fn narrow_tensor_has_large_above_diagonal_mass() {
        // Fig. 2(a): granite-like narrow tensors put substantial per-block
        // mass above the diagonal (finer block worse) under UE4M3 scales
        // (paper reports ~25%). Note individual blocks can sit above the
        // diagonal even with unquantized scales (the FP4 grid is
        // non-uniform — "typically, although not strictly", Sec. 3.1);
        // the scale-quantization anomaly shows in the AGGREGATE error.
        let mut rng = Pcg64::new(5);
        let x = rng.normal_vec_f32(1 << 15, 5e-3);
        let s = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let pairs = per_block_mse_pairs(&s, &x, 8, 16);
        let frac = fraction_fine_worse(&pairs);
        assert!(frac > 0.15, "above-diagonal fraction {frac}");
        // aggregate inversion under UE4M3 at this σ ...
        let (sum_f, sum_c) = pairs
            .iter()
            .fold((0.0, 0.0), |(a, b), (f, c)| (a + f, b + c));
        assert!(sum_f > sum_c, "expected aggregate inversion: {sum_f} vs {sum_c}");
        // ... and NO aggregate inversion with quasi-unquantized scales
        let sb = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8);
        let pb = per_block_mse_pairs(&sb, &x, 8, 16);
        let (bf, bc) = pb.iter().fold((0.0, 0.0), |(a, b), (f, c)| (a + f, b + c));
        assert!(bf < bc, "bf16 aggregate should be monotone: {bf} vs {bc}");
    }

    #[test]
    fn measured_mse_agrees_with_theory_across_format_grid() {
        // The tuner's scoring contract (DESIGN.md §16): the measured
        // per-tensor MSE this module reports must track the closed-form
        // Gaussian prediction in `theory` across the whole candidate
        // grid the auto-tuner searches — {FP4, FP8} elements ×
        // {UE4M3, UE5M3, E8M0} scales × block sizes 4..32 — at both a
        // benign σ and the anomaly-regime σ the demo model uses. The
        // band is generous (Monte-Carlo noise at 2^17 samples plus the
        // theory's own cap-enumeration truncation), but a broken scale
        // cast or block addressing bug misses it by orders of
        // magnitude.
        use crate::formats::{E8M0, UE5M3};
        use crate::theory;
        let mut seed = 100u64;
        for elem in [ElemFormat::FP4, ElemFormat::FP8] {
            for scale in [UE4M3, UE5M3, E8M0] {
                for bs in [4usize, 8, 16, 32] {
                    for sigma in [0.02, 6e-3] {
                        seed += 1;
                        let mut rng = Pcg64::new(seed);
                        let x = rng.normal_vec_f32(1 << 17, sigma);
                        let scheme = QuantScheme::new(elem, scale, bs);
                        let measured = tensor_mse(&scheme, &x);
                        let predicted = theory::mse_quantized_scales(
                            &elem, &scale, sigma, bs,
                        )
                        .total();
                        assert!(
                            predicted > 0.0,
                            "{}/σ={sigma}: predicted {predicted}",
                            scheme.id()
                        );
                        let ratio = measured / predicted;
                        assert!(
                            (0.8..=1.25).contains(&ratio),
                            "{}/σ={sigma}: measured {measured:.4e} vs \
                             predicted {predicted:.4e} (ratio {ratio:.3})",
                            scheme.id()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mse_vs_sigma_reports_sigma() {
        let mut rng = Pcg64::new(6);
        let x = rng.normal_vec_f32(1 << 14, 0.02);
        let s = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
        let (sigma, mse) = mse_vs_sigma(&s, &x);
        assert!((sigma - 0.02).abs() < 0.002);
        assert!(mse > 0.0);
    }
}
