//! Native packed-domain microscaling GEMM: multiply two quantized
//! operands directly on their integer element codes.
//!
//! The experiment path fake-quantizes to f32 and runs a plain f32 GEMM
//! ([`super::matmul`]); real microscaling hardware never materializes
//! those floats — it feeds element *codes* into the MAC array and fuses
//! the two block scales into the partial sum once per block pair
//! ([`crate::hw::pe`] models exactly that datapath). This module is the
//! CPU realization of the same dataflow:
//!
//! * [`GemmOperand`] — a quantized matrix stored as one sign-magnitude
//!   code byte per element plus one decoded f32 scale per block, with
//!   blocks running along the contraction dimension *row-aligned* (each
//!   row is blocked independently; a trailing partial block per row is
//!   allowed, so odd shapes work). Weights are prepacked through
//!   [`GemmOperand::quantize_transposed`], hoisting the per-call
//!   transpose of the old path out of the GEMM.
//! * [`PackedGemm`] — the engine: per block pair it fuses the scale
//!   product `ss = s_x · s_w` once, then accumulates code products
//!   through small decode LUTs (16-entry for FP4, 64-entry for FP6,
//!   256-entry for FP8), cache-blocked over n-tiles and parallelized
//!   across output row panels ([`crate::util::par`]).
//!
//! # Bit-exactness contract (FP elements)
//!
//! For minifloat elements the engine is **bit-identical** to decoding
//! both operands and running the sequential reference
//! [`super::matmul::matmul_t`]. This is not a coincidence but a theorem
//! about significand widths: every factor pairing is exact in f32 —
//! scale products carry ≤ 8+8 significant bits (bf16 scales are the
//! worst case), code products ≤ 4+4, and the fused product
//! `(s_x·s_w)·(e_x·e_w)` therefore carries ≤ 24 significant bits, the
//! f32 significand exactly. Both groupings compute the same real number
//! exactly, so every term matches the decoded product bit for bit; the
//! engine then adds terms in the same `t = 0..k` order as `matmul_t`
//! (tiling and row-panel threading never reorder a single output's
//! accumulation), so whole outputs match bit for bit. The significand
//! argument needs one more hypothesis — every intermediate must stay in
//! the *normal* f32 exponent range — which bounded scale grids
//! (UE4M3/UE5M3 and friends) always satisfy; for unbounded ones (bf16,
//! e8m0) the engine checks the operands' actual scale ranges
//! (`fusion_safe`) and falls back to decode + multiply on extreme
//! tensors, keeping the contract unconditional. The
//! `rust/tests/packed_gemm.rs` property suite enforces it across every
//! element × scale × block-size × shape combination.
//!
//! # Integer elements
//!
//! INT4/INT8 elements take the faster hardware-shaped path: exact i32
//! partial sums per block pair, then one fused `acc += ss · psum` per
//! block — fewer rounding steps than the f32 reference, so it is *not*
//! bit-comparable to `matmul_t` (it is closer to the exact value).
//! It is still deterministic: byte-identical for any thread count and
//! tile size, which the determinism tests pin down.
//!
//! # Per-tensor ("-S") schemes
//!
//! The eq. 11 division by `s_t` makes per-term fusion inexact, so
//! per-tensor operands fall back to decode + [`super::matmul::matmul_t`]
//! inside [`PackedGemm::matmul`] — same answer, none of the speed.

use std::sync::OnceLock;

use crate::formats::ElemFormat;
use crate::util::par;
use crate::util::simd::{self, SimdLevel};

use super::kernel::plan_threads;
use super::matmul::matmul_t;
use super::packed::{encode_block, LevelCodec, PackedMxTensor};
use super::QuantScheme;

/// f32 lanes per vector register group: 8 for AVX2, 4 for NEON. The
/// interleaved weight panels and the column-split alignment are laid
/// out at this width; it is an arch constant, so one panel layout
/// serves every kernel the process can dispatch to.
#[cfg(target_arch = "aarch64")]
const SIMD_LANES: usize = 4;
#[cfg(not(target_arch = "aarch64"))]
const SIMD_LANES: usize = 8;

/// Lazily built weight-side layout for the vector kernels: rows grouped
/// in [`SIMD_LANES`]-wide **lane groups**, codes interleaved t-major
/// (`codes[g·stride·L + t·L + lane]`) and scales block-major
/// (`scales[g·bpr·L + b·L + lane]`), so one vector load at position `t`
/// fetches the codes of `L` adjacent output columns. Padded lanes (the
/// last group when `rows % L != 0`) carry code 0 and scale 0.0: their
/// fused scale is exactly `0.0`, every term contributes `+0.0`, and the
/// store masks them out — they can never perturb a real output.
struct SimdPanels {
    codes: Vec<u8>,
    scales: Vec<f32>,
}

/// A quantized matrix in GEMM-ready packed-domain layout (see module
/// docs): `rows × cols`, blocks along `cols`, one code byte per element
/// and one decoded f32 scale per block.
pub struct GemmOperand {
    scheme: QuantScheme,
    rows: usize,
    cols: usize,
    /// ceil(cols / block_size): row-aligned blocks per row.
    blocks_per_row: usize,
    /// padded row stride in elements (`blocks_per_row * block_size`);
    /// pad positions hold code 0 and are never accumulated.
    stride: usize,
    /// bits per sign-magnitude code in the wire format.
    elem_bits: u32,
    /// `rows * stride` sign-magnitude code bytes.
    codes: Vec<u8>,
    /// `rows * blocks_per_row` decoded block scales.
    scales: Vec<f32>,
    /// eq. 11 per-tensor factor (1.0 = off).
    s_t: f32,
    /// wire-format bytes per block scale (1 when the scale format fits a
    /// code byte, 2 for bf16 scales).
    scale_bytes: usize,
    /// smallest nonzero block scale (`f32::INFINITY` when every block
    /// collapsed) — input to the `fusion_safe` range check.
    scale_min_nz: f32,
    /// largest block scale.
    scale_max: f32,
    elem_codec: LevelCodec,
    /// interleaved vector-kernel panels, built on first SIMD multiply
    /// (weight operands are packed once and multiplied many times, so
    /// the cost amortizes to zero on the serve path). Not counted in
    /// [`GemmOperand::resident_bytes`], which prices the canonical
    /// codes + scales representation the cache accounts for.
    panels: OnceLock<SimdPanels>,
}

impl GemmOperand {
    /// Quantize row-major `rows × cols` data under `scheme`, blocking
    /// each row independently along `cols` (the contraction dimension).
    ///
    /// Unlike [`PackedMxTensor::encode`] this accepts any shape (a
    /// trailing partial block per row is fine) and any scale format
    /// including bf16 (scales are carried as decoded f32 either way).
    pub fn quantize(
        scheme: &QuantScheme,
        data: &[f32],
        rows: usize,
        cols: usize,
    ) -> crate::Result<GemmOperand> {
        anyhow::ensure!(scheme.block_size > 0, "block size must be positive");
        anyhow::ensure!(
            data.len() == rows * cols,
            "data len {} != {rows}x{cols}",
            data.len()
        );
        let elem_codec = LevelCodec::for_elem(&scheme.elem);
        let elem_bits = elem_codec.mag_bits() + 1;
        anyhow::ensure!(
            elem_bits <= 8,
            "element format {} needs {elem_bits} bits/code (max 8)",
            scheme.elem.name()
        );
        let bs = scheme.block_size;
        let blocks_per_row = cols.div_ceil(bs);
        let stride = blocks_per_row * bs;
        let scale_bytes = if LevelCodec::for_scale(&scheme.scale).is_some() {
            1
        } else {
            2
        };

        // same pipeline as the fake-quant reference: eq. 11 pre-scale,
        // per-block absmax -> scale cast -> element cast -> code
        let s_t = if scheme.per_tensor {
            let absmax = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            scheme.per_tensor_factor(absmax)
        } else {
            1.0
        };

        let mut codes = vec![0u8; rows * stride];
        let mut scales = vec![0.0f32; rows * blocks_per_row];
        let mut scale_min_nz = f32::INFINITY;
        let mut scale_max = 0.0f32;
        for r in 0..rows {
            let row = &data[r * cols..(r + 1) * cols];
            for b in 0..blocks_per_row {
                let t0 = b * bs;
                let tl = bs.min(cols - t0);
                let crow = &mut codes[r * stride + t0..r * stride + t0 + tl];
                // the shared per-block pipeline (packed.rs) — collapsed
                // blocks leave their zero codes in place (App. F.3)
                let s = encode_block(
                    scheme,
                    &elem_codec,
                    s_t,
                    &row[t0..t0 + tl],
                    crow,
                )?;
                scales[r * blocks_per_row + b] = s;
                if s > 0.0 && s < scale_min_nz {
                    scale_min_nz = s;
                }
                if s > scale_max {
                    scale_max = s;
                }
            }
        }

        Ok(GemmOperand {
            scheme: *scheme,
            rows,
            cols,
            blocks_per_row,
            stride,
            elem_bits,
            codes,
            scales,
            s_t,
            scale_bytes,
            scale_min_nz,
            scale_max,
            elem_codec,
            panels: OnceLock::new(),
        })
    }

    /// Quantize a row-major `k × n` weight matrix as the **transposed**
    /// `n × k` operand (blocks along `k`, one block row per output
    /// column) — the prepacked form [`PackedGemm::matmul`] consumes.
    /// Pack once, multiply many times.
    pub fn quantize_transposed(
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
    ) -> crate::Result<GemmOperand> {
        anyhow::ensure!(
            w.len() == k * n,
            "weight len {} != {k}x{n}",
            w.len()
        );
        GemmOperand::quantize(scheme, &super::matmul::transpose(w, k, n), n, k)
    }

    /// Reinterpret an already-packed flat tensor as a `rows × cols` GEMM
    /// operand. Requires `cols` to be a multiple of the block size so
    /// the flat blocking coincides with row-aligned blocking.
    pub fn from_packed(
        p: &PackedMxTensor,
        rows: usize,
        cols: usize,
    ) -> crate::Result<GemmOperand> {
        anyhow::ensure!(
            p.len() == rows * cols,
            "packed len {} != {rows}x{cols}",
            p.len()
        );
        let scheme = *p.scheme();
        anyhow::ensure!(
            cols % scheme.block_size == 0,
            "cols {cols} not divisible by block size {} (flat blocks would \
             span rows)",
            scheme.block_size
        );
        let scales = p.block_scales_f32();
        let mut scale_min_nz = f32::INFINITY;
        let mut scale_max = 0.0f32;
        for &s in &scales {
            if s > 0.0 && s < scale_min_nz {
                scale_min_nz = s;
            }
            if s > scale_max {
                scale_max = s;
            }
        }
        Ok(GemmOperand {
            scheme,
            rows,
            cols,
            blocks_per_row: cols / scheme.block_size,
            stride: cols,
            elem_bits: p.elem_bits(),
            codes: p.unpack_codes(),
            scales,
            s_t: p.per_tensor_factor(),
            scale_bytes: 1,
            scale_min_nz,
            scale_max,
            elem_codec: LevelCodec::for_elem(&scheme.elem),
            panels: OnceLock::new(),
        })
    }

    /// Dequantize to row-major `rows × cols` f32 — the reference-path
    /// view of this operand (bit-identical to what the fake-quant
    /// pipeline would have produced under the same blocking).
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let bs = self.scheme.block_size;
        let sign_shift = self.elem_bits - 1;
        let mag_mask = (1u32 << sign_shift) - 1;
        for r in 0..self.rows {
            for b in 0..self.blocks_per_row {
                let t0 = b * bs;
                let tl = bs.min(self.cols - t0);
                let s = self.scales[r * self.blocks_per_row + b];
                for t in t0..t0 + tl {
                    let c = self.codes[r * self.stride + t] as u32;
                    let y = if s > 0.0 {
                        let mut y = s * self.elem_codec.decode(c & mag_mask);
                        if c >> sign_shift != 0 {
                            y = -y;
                        }
                        if self.s_t != 1.0 {
                            y /= self.s_t;
                        }
                        y
                    } else {
                        0.0
                    };
                    out[r * self.cols + t] = y;
                }
            }
        }
        out
    }

    /// Logical rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical columns (the contraction dimension).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The quantization scheme this operand was packed under.
    pub fn scheme(&self) -> &QuantScheme {
        &self.scheme
    }

    /// The eq. 11 per-tensor factor (1.0 when off).
    pub fn per_tensor_factor(&self) -> f32 {
        self.s_t
    }

    /// Wire-format payload bytes: the bit-packed element field (codes at
    /// `elem_bits` each, rounded up to whole bytes) plus the per-block
    /// scales. The in-RAM working set is larger (one byte per code) —
    /// this prices what moves over a memory bus, matching
    /// [`crate::hw::memory::packed_payload_bytes`] for 1-byte scales.
    pub fn payload_bytes(&self) -> usize {
        (self.rows * self.cols * self.elem_bits as usize).div_ceil(8)
            + self.rows * self.blocks_per_row * self.scale_bytes
    }

    /// In-RAM working-set bytes of this operand (one byte per code plus
    /// f32 block scales) — what a cache retaining it actually holds, as
    /// opposed to the wire-format [`GemmOperand::payload_bytes`].
    pub fn resident_bytes(&self) -> usize {
        self.codes.len() + 4 * self.scales.len()
    }

    /// Measured wire-format storage cost in bits per element.
    pub fn bits_per_element(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.payload_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }

    /// Order-sensitive FNV-1a digest over the packed payload (shape,
    /// element codes, scale bits, per-tensor factor): a cheap identity
    /// check for the serve-side operand cache — two operands packed from
    /// the same tensor under the same scheme always digest equal, and
    /// any flipped code or scale bit changes the digest.
    pub fn bits_digest(&self) -> u64 {
        let meta = [
            self.rows as u64,
            self.cols as u64,
            self.scheme.block_size as u64,
            self.s_t.to_bits() as u64,
        ];
        let words = meta
            .into_iter()
            .chain(self.codes.iter().map(|&c| c as u64))
            .chain(self.scales.iter().map(|&s| s.to_bits() as u64));
        crate::util::fnv1a_words(words, crate::util::FNV_OFFSET_BASIS)
    }

    /// A new operand holding rows `r0..r1` of this one: same scheme,
    /// same per-tensor factor, and byte-identical codes/scales for the
    /// kept rows (quantization is fully per-row, so slicing commutes
    /// with packing — except under `per_tensor`, where the retained
    /// parent `s_t` was fit to the *whole* tensor's absmax and a
    /// re-quantize of the slice would differ).
    ///
    /// For a transposed weight operand
    /// ([`GemmOperand::quantize_transposed`]) rows are output columns,
    /// so this is the column-shard primitive
    /// [`crate::quant::shard::ShardedOperand`] builds on.
    pub fn slice_rows(&self, r0: usize, r1: usize) -> crate::Result<GemmOperand> {
        anyhow::ensure!(
            r0 < r1 && r1 <= self.rows,
            "row slice {r0}..{r1} out of range for {} rows",
            self.rows
        );
        let scales =
            self.scales[r0 * self.blocks_per_row..r1 * self.blocks_per_row]
                .to_vec();
        let mut scale_min_nz = f32::INFINITY;
        let mut scale_max = 0.0f32;
        for &s in &scales {
            if s > 0.0 && s < scale_min_nz {
                scale_min_nz = s;
            }
            if s > scale_max {
                scale_max = s;
            }
        }
        Ok(GemmOperand {
            scheme: self.scheme,
            rows: r1 - r0,
            cols: self.cols,
            blocks_per_row: self.blocks_per_row,
            stride: self.stride,
            elem_bits: self.elem_bits,
            codes: self.codes[r0 * self.stride..r1 * self.stride].to_vec(),
            scales,
            s_t: self.s_t,
            scale_bytes: self.scale_bytes,
            scale_min_nz,
            scale_max,
            elem_codec: LevelCodec::for_elem(&self.scheme.elem),
            panels: OnceLock::new(),
        })
    }

    /// Stack operands row-wise into one: the inverse of
    /// [`GemmOperand::slice_rows`] over a contiguous partition.
    /// Requires identical scheme, column count, and per-tensor factor
    /// bits; the result's codes and scales are the parts' bytes
    /// concatenated, so `concat_rows(split(op)).bits_digest() ==
    /// op.bits_digest()`.
    pub fn concat_rows(parts: &[&GemmOperand]) -> crate::Result<GemmOperand> {
        anyhow::ensure!(!parts.is_empty(), "nothing to concatenate");
        let head = parts[0];
        let mut rows = 0usize;
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        for p in parts {
            anyhow::ensure!(
                p.scheme == head.scheme,
                "scheme mismatch across row parts"
            );
            anyhow::ensure!(
                p.cols == head.cols,
                "column mismatch across row parts: {} vs {}",
                p.cols,
                head.cols
            );
            anyhow::ensure!(
                p.s_t.to_bits() == head.s_t.to_bits(),
                "per-tensor factor mismatch across row parts"
            );
            rows += p.rows;
            codes.extend_from_slice(&p.codes);
            scales.extend_from_slice(&p.scales);
        }
        let mut scale_min_nz = f32::INFINITY;
        let mut scale_max = 0.0f32;
        for &s in &scales {
            if s > 0.0 && s < scale_min_nz {
                scale_min_nz = s;
            }
            if s > scale_max {
                scale_max = s;
            }
        }
        Ok(GemmOperand {
            scheme: head.scheme,
            rows,
            cols: head.cols,
            blocks_per_row: head.blocks_per_row,
            stride: head.stride,
            elem_bits: head.elem_bits,
            codes,
            scales,
            s_t: head.s_t,
            scale_bytes: head.scale_bytes,
            scale_min_nz,
            scale_max,
            elem_codec: LevelCodec::for_elem(&head.scheme.elem),
            panels: OnceLock::new(),
        })
    }

    /// The interleaved vector-kernel view of this operand (see
    /// [`SimdPanels`]), built on first use and cached for the operand's
    /// lifetime. Pure re-layout of the canonical codes/scales — no
    /// value changes — so it cannot affect results, only speed.
    fn simd_panels(&self) -> &SimdPanels {
        self.panels.get_or_init(|| {
            let l = SIMD_LANES;
            let groups = self.rows.div_ceil(l).max(1);
            let bpr = self.blocks_per_row;
            let mut codes = vec![0u8; groups * self.stride * l];
            let mut scales = vec![0.0f32; groups * bpr * l];
            for j in 0..self.rows {
                let (g, lane) = (j / l, j % l);
                let src = &self.codes[j * self.stride..(j + 1) * self.stride];
                let dst = &mut codes[g * self.stride * l..];
                for (t, &c) in src.iter().enumerate() {
                    dst[t * l + lane] = c;
                }
                let ssrc = &self.scales[j * bpr..(j + 1) * bpr];
                let sdst = &mut scales[g * bpr * l..];
                for (b, &s) in ssrc.iter().enumerate() {
                    sdst[b * l + lane] = s;
                }
            }
            SimdPanels { codes, scales }
        })
    }
}

/// Decode tables for one element format, built once per GEMM call.
enum Engine {
    /// ≤4-bit codes: fused 16×16 signed code-product LUT (1 KiB).
    ProdLut4(Box<[f32; 256]>),
    /// 5–6-bit codes: fused 64×64 signed code-product LUT (16 KiB).
    ProdLut6(Box<[f32; 4096]>),
    /// 8-bit FP codes: two 256-entry signed decode LUTs (a fused product
    /// table would be 256 KiB — cache-hostile).
    TwoLut(Box<[f32; 256]>),
    /// Integer elements: signed i32 code values, exact block psums.
    IntPsum(Box<[i32; 256]>),
}

impl Engine {
    fn build(op: &GemmOperand) -> Engine {
        let sl = op.elem_codec.signed_lut();
        match op.scheme.elem {
            ElemFormat::Fp(_) if op.elem_bits <= 4 => {
                let mut plut = Box::new([0.0f32; 256]);
                for (a, &va) in sl.iter().enumerate() {
                    for (b, &vb) in sl.iter().enumerate() {
                        plut[(a << 4) | b] = va * vb;
                    }
                }
                Engine::ProdLut4(plut)
            }
            ElemFormat::Fp(_) if op.elem_bits <= 6 => {
                let mut plut = Box::new([0.0f32; 4096]);
                for (a, &va) in sl.iter().enumerate() {
                    for (b, &vb) in sl.iter().enumerate() {
                        plut[(a << 6) | b] = va * vb;
                    }
                }
                Engine::ProdLut6(plut)
            }
            ElemFormat::Fp(_) => {
                let mut lut = Box::new([0.0f32; 256]);
                lut[..sl.len()].copy_from_slice(&sl);
                Engine::TwoLut(lut)
            }
            ElemFormat::Int(_) => {
                let half = 1usize << (op.elem_bits - 1);
                let mut ilut = Box::new([0i32; 256]);
                for (code, slot) in ilut.iter_mut().enumerate().take(sl.len()) {
                    let mag = (code & (half - 1)) as i32;
                    *slot = if code >= half { -mag } else { mag };
                }
                Engine::IntPsum(ilut)
            }
        }
    }
}

/// The packed-domain GEMM engine (see module docs). Configuration knobs
/// change only *speed*, never bytes of the result.
#[derive(Debug, Clone, Copy)]
pub struct PackedGemm {
    /// Output columns per cache tile: one tile of weight code rows
    /// (`tile_n × k` bytes) is streamed per activation row, so size it
    /// to keep the tile L2-resident.
    pub tile_n: usize,
    /// Worker-thread cap; output rows are split across workers (or
    /// output columns, when there are fewer rows than workers).
    pub threads: usize,
    /// Minimum `m·k·n` product before threads are used.
    pub par_threshold: usize,
    /// Vector instruction set for the FP inner kernels
    /// ([`crate::util::simd`]; DESIGN.md §13). Any level is clamped to
    /// what the host supports at dispatch time; every level produces
    /// bit-identical results, so this knob — like the others — changes
    /// only speed.
    pub simd: SimdLevel,
}

impl PackedGemm {
    /// Production configuration: 64-column tiles, one worker per logical
    /// CPU, threading from 2 Mi multiply-accumulates up, vector kernels
    /// per the process-wide [`simd::active`] dispatch.
    pub fn auto() -> PackedGemm {
        PackedGemm {
            tile_n: 64,
            threads: par::max_threads(),
            par_threshold: 1 << 21,
            simd: simd::active(),
        }
    }

    /// Single-threaded variant (benches isolate tiling from threading).
    pub fn serial() -> PackedGemm {
        PackedGemm { threads: 1, ..PackedGemm::auto() }
    }

    /// This engine pinned to an explicit [`SimdLevel`] — the hook the
    /// differential suites and the bench's `simd` axis use to compare
    /// instruction sets inside one process, independent of the latched
    /// `MICROSCALE_SIMD`.
    pub fn with_simd(mut self, level: SimdLevel) -> PackedGemm {
        self.simd = level;
        self
    }

    /// Multiply `x` (`m × k`) by the prepacked transposed weights `w`
    /// (`n × k`), returning the row-major `m × n` product.
    ///
    /// Both operands must share the same scheme and contraction length.
    /// FP-element results are bit-identical to
    /// `matmul_t(x.decode(), w.decode())`; see the module docs for the
    /// INT and per-tensor variants.
    pub fn matmul(
        &self,
        x: &GemmOperand,
        w: &GemmOperand,
    ) -> crate::Result<Vec<f32>> {
        anyhow::ensure!(
            x.scheme == w.scheme,
            "operand schemes differ: {} vs {}",
            x.scheme.id(),
            w.scheme.id()
        );
        anyhow::ensure!(
            x.cols == w.cols,
            "contraction mismatch: x is {}x{}, w is {}x{}",
            x.rows,
            x.cols,
            w.rows,
            w.cols
        );
        let (m, n, k) = (x.rows, w.rows, x.cols);
        if m * n == 0 || k == 0 {
            // k == 0 is an explicit short-circuit, not a reliance on
            // empty loop bounds: a zero-length contraction is the empty
            // sum, i.e. an all-zero m×n result on every engine path
            // (regression-pinned in rust/tests/packed_gemm.rs)
            return Ok(vec![0.0f32; m * n]);
        }
        let fp_elems = matches!(x.scheme.elem, ElemFormat::Fp(_));
        if x.s_t != 1.0 || w.s_t != 1.0 || (fp_elems && !fusion_safe(x, w)) {
            // eq. 11 division breaks per-term fusion exactness, and
            // out-of-normal-range scale products break the regrouping
            // argument (see fusion_safe) — decode instead, which is the
            // reference by definition
            return Ok(matmul_t(&x.decode(), &w.decode(), m, k, n));
        }
        let engine = Engine::build(x);
        let tile_n = self.tile_n.max(1);
        // resolve the vector level for this (engine, host) pair: FP
        // kernels have AVX2 bodies (FP4 additionally a NEON one);
        // integer psums and unsupported hosts run scalar. DESIGN.md §13
        // tabulates exactly this mapping.
        let level = match (self.simd.clamped(), &engine) {
            (SimdLevel::Avx2, Engine::IntPsum(_)) => SimdLevel::Scalar,
            (SimdLevel::Neon, Engine::ProdLut4(_)) => SimdLevel::Neon,
            (SimdLevel::Neon, _) => SimdLevel::Scalar,
            (l, _) => l,
        };
        if level != SimdLevel::Scalar {
            // build the interleaved weight panels once, outside the
            // worker split (OnceLock makes racing builds safe, but
            // doing it here keeps the workers compute-only)
            let _ = w.simd_panels();
        }
        // every path accumulates each output's terms in the same
        // ascending-t order, one (r, j) range per worker — which rows
        // or columns a worker owns can never change a byte
        let run = |r0: usize,
                   r1: usize,
                   j0: usize,
                   j1: usize,
                   out: &mut [f32],
                   out_cols: usize| {
            match (&engine, level) {
                #[cfg(target_arch = "x86_64")]
                (Engine::ProdLut4(plut), SimdLevel::Avx2) => unsafe {
                    prod_panel_fp4_avx2(x, w, plut, r0, r1, j0, j1, out, out_cols)
                },
                #[cfg(target_arch = "x86_64")]
                (Engine::ProdLut6(plut), SimdLevel::Avx2) => unsafe {
                    prod_panel_fp6_avx2(x, w, plut, r0, r1, j0, j1, out, out_cols)
                },
                #[cfg(target_arch = "x86_64")]
                (Engine::TwoLut(lut), SimdLevel::Avx2) => unsafe {
                    twolut_panel_avx2(x, w, lut, r0, r1, j0, j1, out, out_cols)
                },
                #[cfg(target_arch = "aarch64")]
                (Engine::ProdLut4(plut), SimdLevel::Neon) => unsafe {
                    prod_panel_fp4_neon(x, w, plut, r0, r1, j0, j1, out, out_cols)
                },
                (Engine::ProdLut4(plut), _) => prod_panel::<4, 256>(
                    x, w, plut, r0, r1, j0, j1, out, out_cols, tile_n,
                ),
                (Engine::ProdLut6(plut), _) => prod_panel::<6, 4096>(
                    x, w, plut, r0, r1, j0, j1, out, out_cols, tile_n,
                ),
                (Engine::TwoLut(lut), _) => twolut_panel(
                    x, w, lut, r0, r1, j0, j1, out, out_cols, tile_n,
                ),
                (Engine::IntPsum(ilut), _) => int_panel(
                    x, w, ilut, r0, r1, j0, j1, out, out_cols, tile_n,
                ),
            }
        };
        let mut out = vec![0.0f32; m * n];
        // single-row activations (every KV-cached decode step lands
        // here) and sub-threshold shapes skip the threading machinery
        // entirely: the setup cost is pure overhead on the m = 1 hot
        // path. Same panel code, same accumulation order —
        // bit-identical either way (packed_gemm tests pin it).
        let threads = if m == 1 {
            1
        } else {
            plan_threads(
                m.saturating_mul(n).saturating_mul(k.max(1)),
                self.threads,
                self.par_threshold,
            )
        };
        if threads <= 1 {
            run(0, m, 0, n, &mut out, n);
        } else if threads <= m {
            par::par_chunks_mut(&mut out, n, threads, |off, chunk| {
                let r0 = off / n;
                run(r0, r0 + chunk.len() / n, 0, n, chunk, n)
            });
        } else {
            // small-m, wide-n shapes (decode/prefill tails): a row
            // split can never use more than m workers, so fan out over
            // the *column* axis instead. Workers compute disjoint
            // lane-group-aligned column ranges into private buffers,
            // scattered back in fixed order — each output is produced
            // by exactly one worker running the identical per-output
            // term sequence, so the split stays bit-identical
            // (pinned for m ∈ {2,3} in rust/tests/packed_gemm.rs).
            let ranges = split_columns(n, threads);
            let parts = par::par_map(ranges.clone(), threads, |(j0, j1)| {
                let mut buf = vec![0.0f32; m * (j1 - j0)];
                run(0, m, j0, j1, &mut buf, j1 - j0);
                buf
            });
            for ((j0, j1), part) in ranges.into_iter().zip(parts) {
                let width = j1 - j0;
                for i in 0..m {
                    out[i * n + j0..i * n + j1]
                        .copy_from_slice(&part[i * width..(i + 1) * width]);
                }
            }
        }
        Ok(out)
    }
}

/// Partition `0..n` into at most `parts` contiguous column ranges,
/// aligned to [`SIMD_LANES`] lane groups (except the final boundary at
/// `n`) so every worker's range starts on a vector-store boundary.
/// Alignment is a speed concern only — outputs are computed
/// independently, so any split yields identical bytes.
fn split_columns(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let groups = n.div_ceil(SIMD_LANES);
    let parts = parts.min(groups).max(1);
    let base = groups / parts;
    let extra = groups % parts;
    let mut out = Vec::with_capacity(parts);
    let mut g0 = 0usize;
    for p in 0..parts {
        let g1 = g0 + base + usize::from(p < extra);
        out.push(((g0 * SIMD_LANES).min(n), (g1 * SIMD_LANES).min(n)));
        g0 = g1;
    }
    out
}

impl Default for PackedGemm {
    fn default() -> Self {
        PackedGemm::auto()
    }
}

/// One-shot convenience: quantize both operands under `scheme` and run
/// the packed-native GEMM (`x`: row-major `m × k`, `w`: row-major
/// `k × n`, blocks along `k` on both sides).
pub fn packed_matmul(
    scheme: &QuantScheme,
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> crate::Result<Vec<f32>> {
    let xo = GemmOperand::quantize(scheme, x, m, k)?;
    let wo = GemmOperand::quantize_transposed(scheme, w, k, n)?;
    PackedGemm::auto().matmul(&xo, &wo)
}

/// Whether the fused-product regrouping is bit-exact for this operand
/// pair: the module-docs significand argument additionally needs every
/// intermediate — the decoded values `s·lvl`, the scale product
/// `s_x·s_w`, and the full term — to stay in the *normal* f32 range (or
/// be exactly zero). Significand widths say nothing about exponents:
/// on unbounded scale grids (bf16, e8m0) an extreme tensor can push
/// `s_x·s_w` to `inf` or a term into the subnormal range, where the two
/// groupings round differently. The bounds are evaluated in f64 from
/// the operands' actual scale ranges; UE4M3/UE5M3-class scale formats
/// (max 122880, min subnormal 2⁻¹⁷) can never fail them.
fn fusion_safe(x: &GemmOperand, w: &GemmOperand) -> bool {
    let lc = &x.elem_codec;
    if lc.level_count() < 2 {
        return true; // no nonzero magnitudes: every product is a signed zero
    }
    let lvl_min = lc.decode(1) as f64;
    let lvl_max = lc.decode(lc.level_count() as u32 - 1) as f64;
    let min_pos = f32::MIN_POSITIVE as f64;
    let max = f32::MAX as f64;
    // per-operand: decoded values s·lvl are exact (normal or zero); an
    // all-collapsed operand has scale_min_nz = +inf and scale_max = 0,
    // which passes vacuously
    let op_ok = |smin_nz: f64, smax: f64| {
        smax * lvl_max <= max && smin_nz * lvl_min >= min_pos
    };
    let ss_min = x.scale_min_nz as f64 * w.scale_min_nz as f64;
    let ss_max = x.scale_max as f64 * w.scale_max as f64;
    op_ok(x.scale_min_nz as f64, x.scale_max as f64)
        && op_ok(w.scale_min_nz as f64, w.scale_max as f64)
        // the fused scale product itself stays normal…
        && ss_max <= max
        && ss_min >= min_pos
        // …and so does every nonzero term (s_x·s_w)·(e_x·e_w)
        && ss_max * (lvl_max * lvl_max) <= max
        && ss_min * (lvl_min * lvl_min) >= min_pos
}

/// FP inner kernels over a fused code-product LUT (`EB`-bit codes,
/// `N = 1 << (2·EB)` entries). Each output's terms are accumulated in
/// ascending `t` with one rounded add per term — the exact op sequence
/// of [`matmul_t`] on the decoded operands (module docs).
#[allow(clippy::too_many_arguments)]
fn prod_panel<const EB: usize, const N: usize>(
    x: &GemmOperand,
    w: &GemmOperand,
    plut: &[f32; N],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
    tile_n: usize,
) {
    let mask = (1usize << EB) - 1;
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    for jt0 in (j0..j1).step_by(tile_n) {
        let jt1 = (jt0 + tile_n).min(j1);
        for r in r0..r1 {
            let cx = &x.codes[r * stride..(r + 1) * stride];
            let sx = &x.scales[r * bpr..(r + 1) * bpr];
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            let mut j = jt0;
            // 4-wide register blocking: four independent accumulator
            // chains hide the f32 add latency the naive loop serializes on
            while j + 4 <= jt1 {
                let cw0 = &w.codes[j * stride..(j + 1) * stride];
                let cw1 = &w.codes[(j + 1) * stride..(j + 2) * stride];
                let cw2 = &w.codes[(j + 2) * stride..(j + 3) * stride];
                let cw3 = &w.codes[(j + 3) * stride..(j + 4) * stride];
                let sw0 = &w.scales[j * bpr..(j + 1) * bpr];
                let sw1 = &w.scales[(j + 1) * bpr..(j + 2) * bpr];
                let sw2 = &w.scales[(j + 2) * bpr..(j + 3) * bpr];
                let sw3 = &w.scales[(j + 3) * bpr..(j + 4) * bpr];
                let mut acc = [0.0f32; 4];
                for b in 0..bpr {
                    let sxb = sx[b];
                    let ss =
                        [sxb * sw0[b], sxb * sw1[b], sxb * sw2[b], sxb * sw3[b]];
                    let t0 = b * bs;
                    let tl = bs.min(x.cols - t0);
                    for t in t0..t0 + tl {
                        let ix = ((cx[t] as usize) & mask) << EB;
                        acc[0] += ss[0] * plut[ix | ((cw0[t] as usize) & mask)];
                        acc[1] += ss[1] * plut[ix | ((cw1[t] as usize) & mask)];
                        acc[2] += ss[2] * plut[ix | ((cw2[t] as usize) & mask)];
                        acc[3] += ss[3] * plut[ix | ((cw3[t] as usize) & mask)];
                    }
                }
                orow[j - j0] = acc[0];
                orow[j + 1 - j0] = acc[1];
                orow[j + 2 - j0] = acc[2];
                orow[j + 3 - j0] = acc[3];
                j += 4;
            }
            while j < jt1 {
                let cw = &w.codes[j * stride..(j + 1) * stride];
                let sw = &w.scales[j * bpr..(j + 1) * bpr];
                let mut acc = 0.0f32;
                for b in 0..bpr {
                    let ss = sx[b] * sw[b];
                    let t0 = b * bs;
                    let tl = bs.min(x.cols - t0);
                    for t in t0..t0 + tl {
                        let ix = ((cx[t] as usize) & mask) << EB;
                        acc += ss * plut[ix | ((cw[t] as usize) & mask)];
                    }
                }
                orow[j - j0] = acc;
                j += 1;
            }
        }
    }
}

/// FP8 inner kernel: two 256-entry decode LUT loads per term instead of
/// one 256 KiB product table. `ss·(lx·lw)` is exact at ≤ 24 significand
/// bits, so the bit-exactness argument is unchanged.
#[allow(clippy::too_many_arguments)]
fn twolut_panel(
    x: &GemmOperand,
    w: &GemmOperand,
    lut: &[f32; 256],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
    tile_n: usize,
) {
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    for jt0 in (j0..j1).step_by(tile_n) {
        let jt1 = (jt0 + tile_n).min(j1);
        for r in r0..r1 {
            let cx = &x.codes[r * stride..(r + 1) * stride];
            let sx = &x.scales[r * bpr..(r + 1) * bpr];
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            let mut j = jt0;
            while j + 2 <= jt1 {
                let cw0 = &w.codes[j * stride..(j + 1) * stride];
                let cw1 = &w.codes[(j + 1) * stride..(j + 2) * stride];
                let sw0 = &w.scales[j * bpr..(j + 1) * bpr];
                let sw1 = &w.scales[(j + 1) * bpr..(j + 2) * bpr];
                let mut acc = [0.0f32; 2];
                for b in 0..bpr {
                    let sxb = sx[b];
                    let ss = [sxb * sw0[b], sxb * sw1[b]];
                    let t0 = b * bs;
                    let tl = bs.min(x.cols - t0);
                    for t in t0..t0 + tl {
                        let lx = lut[cx[t] as usize];
                        acc[0] += ss[0] * (lx * lut[cw0[t] as usize]);
                        acc[1] += ss[1] * (lx * lut[cw1[t] as usize]);
                    }
                }
                orow[j - j0] = acc[0];
                orow[j + 1 - j0] = acc[1];
                j += 2;
            }
            while j < jt1 {
                let cw = &w.codes[j * stride..(j + 1) * stride];
                let sw = &w.scales[j * bpr..(j + 1) * bpr];
                let mut acc = 0.0f32;
                for b in 0..bpr {
                    let ss = sx[b] * sw[b];
                    let t0 = b * bs;
                    let tl = bs.min(x.cols - t0);
                    for t in t0..t0 + tl {
                        acc += ss * (lut[cx[t] as usize] * lut[cw[t] as usize]);
                    }
                }
                orow[j - j0] = acc;
                j += 1;
            }
        }
    }
}

/// Integer inner kernel: exact i32 partial sums per block pair, one
/// fused `acc += ss · psum` per block — the PE datapath of
/// [`crate::hw::pe`] verbatim. Pad codes decode to integer 0, so the
/// loop runs whole (padded) blocks with a constant trip count.
#[allow(clippy::too_many_arguments)]
fn int_panel(
    x: &GemmOperand,
    w: &GemmOperand,
    ilut: &[i32; 256],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
    tile_n: usize,
) {
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    for jt0 in (j0..j1).step_by(tile_n) {
        let jt1 = (jt0 + tile_n).min(j1);
        for r in r0..r1 {
            let cx = &x.codes[r * stride..(r + 1) * stride];
            let sx = &x.scales[r * bpr..(r + 1) * bpr];
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            for j in jt0..jt1 {
                let cw = &w.codes[j * stride..(j + 1) * stride];
                let sw = &w.scales[j * bpr..(j + 1) * bpr];
                let mut acc = 0.0f32;
                for b in 0..bpr {
                    let t0 = b * bs;
                    let mut psum = 0i32;
                    for t in t0..t0 + bs {
                        psum += ilut[cx[t] as usize] * ilut[cw[t] as usize];
                    }
                    acc += (sx[b] * sw[b]) * psum as f32;
                }
                orow[j - j0] = acc;
            }
        }
    }
}

/// AVX2 FP4 kernel: one lane group (8 output columns) per accumulator
/// register, weights read from the interleaved [`SimdPanels`]. Each
/// lane runs the scalar single-column kernel's exact op sequence —
/// `ss = sx[b] * sw[b]` (one rounded mul), then ascending-`t`
/// `acc += ss * plut[(cx[t] << 4) | cw[t]]` (one rounded mul + add per
/// term) — so bit-equality with [`prod_panel`] is structural, not a
/// rounding theorem. The 16-entry product-LUT row selected by the
/// activation code is resolved per lane via [`simd::x86::lut16`]
/// (`vpermps` + blend), the in-register form of the OCP MX FP4 code
/// space. No FMA anywhere: fusing mul+add would change results.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn prod_panel_fp4_avx2(
    x: &GemmOperand,
    w: &GemmOperand,
    plut: &[f32; 256],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
) {
    use core::arch::x86_64::*;
    debug_assert_eq!(j0 % 8, 0, "column ranges are lane-group aligned");
    let panels = w.simd_panels();
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    let mask = _mm256_set1_epi32(15);
    for g in (j0 / 8)..j1.div_ceil(8) {
        let jlo = g * 8;
        let jhi = (jlo + 8).min(j1);
        let pc = &panels.codes[g * stride * 8..][..stride * 8];
        let ps = &panels.scales[g * bpr * 8..][..bpr * 8];
        for r in r0..r1 {
            let cx = &x.codes[r * stride..][..stride];
            let sx = &x.scales[r * bpr..][..bpr];
            let mut acc = _mm256_setzero_ps();
            for b in 0..bpr {
                let sw = _mm256_loadu_ps(ps.as_ptr().add(b * 8));
                let ss = _mm256_mul_ps(_mm256_set1_ps(sx[b]), sw);
                let t0 = b * bs;
                let tl = bs.min(x.cols - t0);
                for t in t0..t0 + tl {
                    let ix = ((cx[t] as usize) & 15) << 4;
                    let lo = _mm256_loadu_ps(plut.as_ptr().add(ix));
                    let hi = _mm256_loadu_ps(plut.as_ptr().add(ix + 8));
                    let idx = _mm256_and_si256(
                        simd::x86::load8_u8_i32(pc.as_ptr().add(t * 8)),
                        mask,
                    );
                    let p = simd::x86::lut16(lo, hi, idx);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(ss, p));
                }
            }
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            if jhi - jlo == 8 {
                _mm256_storeu_ps(orow.as_mut_ptr().add(jlo - j0), acc);
            } else {
                // padded lanes (scale 0.0, code 0) accumulate exact
                // zeros; mask them off on the partial store
                let mut tmp = [0.0f32; 8];
                _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
                orow[jlo - j0..][..jhi - jlo]
                    .copy_from_slice(&tmp[..jhi - jlo]);
            }
        }
    }
}

/// AVX2 FP6 kernel: identical loop structure to [`prod_panel_fp4_avx2`]
/// but the 64-entry product-LUT row no longer fits a register shuffle,
/// so lanes gather from `plut[(cx[t] & 63) << 6 ..]` with `vgatherdps`.
/// Same per-lane op sequence as the scalar kernel — bit-identical.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn prod_panel_fp6_avx2(
    x: &GemmOperand,
    w: &GemmOperand,
    plut: &[f32; 4096],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
) {
    use core::arch::x86_64::*;
    debug_assert_eq!(j0 % 8, 0, "column ranges are lane-group aligned");
    let panels = w.simd_panels();
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    let mask = _mm256_set1_epi32(63);
    for g in (j0 / 8)..j1.div_ceil(8) {
        let jlo = g * 8;
        let jhi = (jlo + 8).min(j1);
        let pc = &panels.codes[g * stride * 8..][..stride * 8];
        let ps = &panels.scales[g * bpr * 8..][..bpr * 8];
        for r in r0..r1 {
            let cx = &x.codes[r * stride..][..stride];
            let sx = &x.scales[r * bpr..][..bpr];
            let mut acc = _mm256_setzero_ps();
            for b in 0..bpr {
                let sw = _mm256_loadu_ps(ps.as_ptr().add(b * 8));
                let ss = _mm256_mul_ps(_mm256_set1_ps(sx[b]), sw);
                let t0 = b * bs;
                let tl = bs.min(x.cols - t0);
                for t in t0..t0 + tl {
                    let ix = ((cx[t] as usize) & 63) << 6;
                    let idx = _mm256_and_si256(
                        simd::x86::load8_u8_i32(pc.as_ptr().add(t * 8)),
                        mask,
                    );
                    let p =
                        _mm256_i32gather_ps::<4>(plut.as_ptr().add(ix), idx);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(ss, p));
                }
            }
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            if jhi - jlo == 8 {
                _mm256_storeu_ps(orow.as_mut_ptr().add(jlo - j0), acc);
            } else {
                let mut tmp = [0.0f32; 8];
                _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
                orow[jlo - j0..][..jhi - jlo]
                    .copy_from_slice(&tmp[..jhi - jlo]);
            }
        }
    }
}

/// AVX2 FP8 kernel: the dual-256-entry-LUT path vectorized. The
/// activation level `lx = lut[cx[t]]` broadcasts (it is shared by the
/// whole lane group); the weight levels gather per lane; then
/// `acc += ss * (lx * lw)` with the scalar kernel's exact mul/add
/// sequence and parenthesization — bit-identical to [`twolut_panel`].
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
unsafe fn twolut_panel_avx2(
    x: &GemmOperand,
    w: &GemmOperand,
    lut: &[f32; 256],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
) {
    use core::arch::x86_64::*;
    debug_assert_eq!(j0 % 8, 0, "column ranges are lane-group aligned");
    let panels = w.simd_panels();
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    for g in (j0 / 8)..j1.div_ceil(8) {
        let jlo = g * 8;
        let jhi = (jlo + 8).min(j1);
        let pc = &panels.codes[g * stride * 8..][..stride * 8];
        let ps = &panels.scales[g * bpr * 8..][..bpr * 8];
        for r in r0..r1 {
            let cx = &x.codes[r * stride..][..stride];
            let sx = &x.scales[r * bpr..][..bpr];
            let mut acc = _mm256_setzero_ps();
            for b in 0..bpr {
                let sw = _mm256_loadu_ps(ps.as_ptr().add(b * 8));
                let ss = _mm256_mul_ps(_mm256_set1_ps(sx[b]), sw);
                let t0 = b * bs;
                let tl = bs.min(x.cols - t0);
                for t in t0..t0 + tl {
                    let lx = _mm256_set1_ps(lut[cx[t] as usize]);
                    let idx = simd::x86::load8_u8_i32(pc.as_ptr().add(t * 8));
                    let lw = _mm256_i32gather_ps::<4>(lut.as_ptr(), idx);
                    acc = _mm256_add_ps(
                        acc,
                        _mm256_mul_ps(ss, _mm256_mul_ps(lx, lw)),
                    );
                }
            }
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            if jhi - jlo == 8 {
                _mm256_storeu_ps(orow.as_mut_ptr().add(jlo - j0), acc);
            } else {
                let mut tmp = [0.0f32; 8];
                _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
                orow[jlo - j0..][..jhi - jlo]
                    .copy_from_slice(&tmp[..jhi - jlo]);
            }
        }
    }
}

/// NEON FP4 kernel: one lane group (4 output columns) per accumulator,
/// the 16-entry product-LUT row resolved with `vqtbl4q_u8` over the
/// four table registers from [`simd::neon::lut16_table`]. Per-lane op
/// sequence matches [`prod_panel`] exactly (`vmulq_n_f32` computes
/// `sw[b] * sx[b]`, the same rounded product as the scalar
/// `sx[b] * sw[b]`); no FMA — bit-identical.
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
unsafe fn prod_panel_fp4_neon(
    x: &GemmOperand,
    w: &GemmOperand,
    plut: &[f32; 256],
    r0: usize,
    r1: usize,
    j0: usize,
    j1: usize,
    out: &mut [f32],
    out_cols: usize,
) {
    use core::arch::aarch64::*;
    debug_assert_eq!(j0 % 4, 0, "column ranges are lane-group aligned");
    let panels = w.simd_panels();
    let bpr = x.blocks_per_row;
    let bs = x.scheme.block_size;
    let stride = x.stride;
    for g in (j0 / 4)..j1.div_ceil(4) {
        let jlo = g * 4;
        let jhi = (jlo + 4).min(j1);
        let pc = &panels.codes[g * stride * 4..][..stride * 4];
        let ps = &panels.scales[g * bpr * 4..][..bpr * 4];
        for r in r0..r1 {
            let cx = &x.codes[r * stride..][..stride];
            let sx = &x.scales[r * bpr..][..bpr];
            let mut acc = vdupq_n_f32(0.0);
            for b in 0..bpr {
                let ss = vmulq_n_f32(vld1q_f32(ps.as_ptr().add(b * 4)), sx[b]);
                let t0 = b * bs;
                let tl = bs.min(x.cols - t0);
                for t in t0..t0 + tl {
                    let ix = ((cx[t] as usize) & 15) << 4;
                    let tbl = simd::neon::lut16_table(plut.as_ptr().add(ix));
                    let idx = simd::neon::lut16_indices(pc.as_ptr().add(t * 4));
                    let p = vreinterpretq_f32_u8(vqtbl4q_u8(tbl, idx));
                    acc = vaddq_f32(acc, vmulq_f32(ss, p));
                }
            }
            let orow = &mut out[(r - r0) * out_cols..][..out_cols];
            if jhi - jlo == 4 {
                vst1q_f32(orow.as_mut_ptr().add(jlo - j0), acc);
            } else {
                let mut tmp = [0.0f32; 4];
                vst1q_f32(tmp.as_mut_ptr(), acc);
                orow[jlo - j0..][..jhi - jlo]
                    .copy_from_slice(&tmp[..jhi - jlo]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, BF16_SCALE, UE4M3, UE5M3};

    #[test]
    fn operand_decode_matches_fake_quant_when_aligned() {
        // with cols % bs == 0, row-aligned blocking coincides with the
        // flat fake-quant blocking, so decode == fake_quant bit for bit
        let mut rng = Pcg64::new(21);
        let (rows, cols) = (7, 48);
        let x = rng.normal_vec_f32(rows * cols, 4e-3);
        for scale in [UE4M3, UE5M3, BF16_SCALE] {
            let scheme = QuantScheme::new(ElemFormat::FP4, scale, 16);
            let op = GemmOperand::quantize(&scheme, &x, rows, cols).unwrap();
            let want = crate::quant::fake_quant(&scheme, &x);
            let got = op.decode();
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} elem {i}", scheme.id());
            }
        }
    }

    #[test]
    fn operand_handles_partial_trailing_blocks() {
        let mut rng = Pcg64::new(22);
        let (rows, cols) = (3, 13); // 13 = 8 + 5: one partial block/row
        let x = rng.normal_vec_f32(rows * cols, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let op = GemmOperand::quantize(&scheme, &x, rows, cols).unwrap();
        let y = op.decode();
        assert_eq!(y.len(), rows * cols);
        // each row's trailing 5 elements quantize under their own scale:
        // re-quantize row-by-row with explicit padding-free blocks
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            let mut head = row[..8].to_vec();
            crate::quant::fake_quant_into(&scheme, &mut head);
            let tail_scale = {
                let absmax =
                    row[8..].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                scheme.scale.cast(absmax / scheme.elem.max_val())
            };
            for (t, &v) in head.iter().enumerate() {
                assert_eq!(y[r * cols + t].to_bits(), v.to_bits(), "row {r} t {t}");
            }
            for (t, &v) in row[8..].iter().enumerate() {
                let want = if tail_scale > 0.0 {
                    tail_scale * scheme.elem.cast(v / tail_scale)
                } else {
                    0.0
                };
                assert_eq!(
                    y[r * cols + 8 + t].to_bits(),
                    want.to_bits(),
                    "row {r} tail {t}"
                );
            }
        }
    }

    #[test]
    fn from_packed_equals_direct_quantize() {
        let mut rng = Pcg64::new(23);
        let (rows, cols) = (5, 32);
        let x = rng.normal_vec_f32(rows * cols, 0.01);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let p = PackedMxTensor::encode(&scheme, &x).unwrap();
        let a = GemmOperand::from_packed(&p, rows, cols).unwrap();
        let b = GemmOperand::quantize(&scheme, &x, rows, cols).unwrap();
        assert_eq!(a.codes, b.codes);
        let bits = |s: &[f32]| s.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.scales), bits(&b.scales));
        assert_eq!(a.payload_bytes(), b.payload_bytes());
        assert_eq!(a.payload_bytes(), p.payload_bytes());
    }

    #[test]
    fn packed_gemm_bit_exact_vs_decode_reference() {
        // the in-crate smoke version of the tests/packed_gemm.rs suite
        let mut rng = Pcg64::new(24);
        let (m, k, n) = (4, 24, 5);
        let x = rng.normal_vec_f32(m * k, 0.02);
        let w = rng.normal_vec_f32(k * n, 0.02);
        for elem in [ElemFormat::FP4, ElemFormat::FP8] {
            let scheme = QuantScheme::new(elem, UE5M3, 8);
            let xo = GemmOperand::quantize(&scheme, &x, m, k).unwrap();
            let wo = GemmOperand::quantize_transposed(&scheme, &w, k, n).unwrap();
            let want = matmul_t(&xo.decode(), &wo.decode(), m, k, n);
            let got = PackedGemm::serial().matmul(&xo, &wo).unwrap();
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} out {i}", scheme.id());
            }
        }
    }

    #[test]
    fn payload_accounting_counts_wire_bytes() {
        let mut rng = Pcg64::new(25);
        let (rows, cols) = (4, 33); // 5 blocks of 8 per row (one partial)
        let x = rng.normal_vec_f32(rows * cols, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let op = GemmOperand::quantize(&scheme, &x, rows, cols).unwrap();
        assert_eq!(op.payload_bytes(), (4 * 33 * 4).div_ceil(8) + 4 * 5);
        // bf16 scales cost two bytes per block on the wire
        let scheme = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8);
        let op = GemmOperand::quantize(&scheme, &x, rows, cols).unwrap();
        assert_eq!(op.payload_bytes(), (4 * 33 * 4).div_ceil(8) + 4 * 5 * 2);
    }
}
