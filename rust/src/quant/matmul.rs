//! Quantized GEMM semantics on the CPU side.
//!
//! The heavy model matmuls run through the AOT HLO artifacts; this module
//! provides the same microscaling-GEMM semantics natively in Rust for
//! (a) unit/property tests against the runtime path, (b) the quant_service
//! example, and (c) the L3 perf benches.
//!
//! Two execution paths compute those semantics:
//!
//! * **Reference** ([`quantized_matmul_with`]): fake-quantize both
//!   operands to f32, transpose the weights, run the sequential
//!   [`matmul_t`] triple loop. Golden-pinned, slow.
//! * **Packed-native** ([`super::gemm`]): quantize straight to packed
//!   element codes and multiply in the code domain. Bit-identical to the
//!   reference whenever the blockings coincide (`k` a multiple of the
//!   block size), several times faster.
//!
//! [`quantized_matmul`] picks via [`gemm_path_for`]: packed-native for
//! minifloat elements on aligned shapes, reference otherwise;
//! `MICROSCALE_KERNEL`-style env pinning is available through
//! `MICROSCALE_GEMM=reference|packed` when bisecting a discrepancy.
//! On the packed path the weight operand comes from the process-wide
//! [`super::opcache::operand_cache`], so sweeps that re-multiply the
//! same weight tensor under the same scheme encode it exactly once.
//! Single-row activations (`m == 1` — the KV-cached decode hot path,
//! one new token per step) additionally short-circuit the engine's
//! tile/threading setup inside [`PackedGemm::matmul`]; the serial and
//! panel paths share one accumulation order, so the fast path is
//! bit-identical (pinned in `rust/tests/packed_gemm.rs`).

use crate::formats::ElemFormat;

use super::gemm::{GemmOperand, PackedGemm};
use super::{default_kernel, QuantKernel, QuantScheme};

/// Which engine a `quantized_matmul` call runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmPath {
    /// Fake-quantize + sequential f32 triple loop (golden-pinned).
    Reference,
    /// Code-domain engine ([`super::gemm::PackedGemm`]), bit-identical
    /// on its eligible shapes.
    PackedNative,
}

/// Decide the execution path for a `(scheme, k)` GEMM: the packed-native
/// engine whenever it is bit-equivalent to the reference — minifloat
/// elements, no eq. 11 per-tensor pre-scaling (the engine would only
/// fall back to decode + multiply, all cost and no win), and `k` a
/// multiple of the block size so flat and row-aligned blockings agree.
/// `MICROSCALE_GEMM=reference` / `=packed` forces one side (debug aid;
/// forcing `packed` on unaligned `k` changes which elements share a
/// block, i.e. the quantization itself). The env is **latched**: it is
/// read once per process on the first dispatch and cached — this
/// function runs per GEMM call, and a syscall-backed `env::var` on that
/// hot path cost real decode throughput. Set it before the first
/// matmul; later changes have no effect.
pub fn gemm_path_for(scheme: &QuantScheme, k: usize) -> GemmPath {
    static FORCED: std::sync::OnceLock<Option<GemmPath>> =
        std::sync::OnceLock::new();
    let forced = FORCED.get_or_init(|| {
        match std::env::var("MICROSCALE_GEMM").as_deref() {
            Ok("reference") => Some(GemmPath::Reference),
            Ok("packed") => Some(GemmPath::PackedNative),
            _ => None,
        }
    });
    if let Some(path) = forced {
        return *path;
    }
    let aligned = scheme.block_size > 0 && k % scheme.block_size == 0;
    let fp_elems = matches!(scheme.elem, ElemFormat::Fp(_));
    if aligned && !scheme.per_tensor && fp_elems {
        GemmPath::PackedNative
    } else {
        GemmPath::Reference
    }
}

/// Row-major (m×k) · (k×n) with both operands microscaling-fake-quantized
/// along the contraction dimension (weights per output column, i.e. on the
/// transposed view), mirroring `ref.quantized_matmul`.
///
/// Dispatches per [`gemm_path_for`] — the result is bit-identical either
/// way; use [`quantized_matmul_with`] to pin the reference kernel path
/// explicitly (benches do) or [`super::gemm::packed_matmul`] to demand
/// the packed engine.
pub fn quantized_matmul(
    scheme: &QuantScheme,
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    if gemm_path_for(scheme, k) == GemmPath::PackedNative {
        let packed = GemmOperand::quantize(scheme, x, m, k).and_then(|xo| {
            // weights route through the shared operand cache: sweeps
            // multiply the same (tensor, scheme) pair many times, and
            // re-encoding the weight operand per call dominated the old
            // profile. A hit returns the operand the first encode
            // produced, so cached and fresh calls are bit-identical.
            let wo = super::opcache::operand_cache()
                .get_or_pack_transposed(scheme, w, k, n)?;
            PackedGemm::auto().matmul(&xo, &wo)
        });
        if let Ok(out) = packed {
            return out;
        }
        // unpackable scheme (shouldn't happen for registry formats):
        // fall through to the reference path
    }
    quantized_matmul_with(default_kernel(), scheme, x, w, m, k, n)
}

/// [`quantized_matmul`] pinned to the fake-quant **reference** path with
/// an explicit [`QuantKernel`].
pub fn quantized_matmul_with(
    kernel: &dyn QuantKernel,
    scheme: &QuantScheme,
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let xq = kernel.fake_quant(scheme, x); // rows contiguous: blocks along k
    // transpose w to (n, k) so its blocks run along k as well
    let wtq = kernel.fake_quant(scheme, &transpose(w, k, n));
    matmul_t(&xq, &wtq, m, k, n)
}

/// Row-major transpose of a `k × n` matrix into `n × k` — the operand
/// layout both GEMM paths block along the contraction dimension.
pub fn transpose(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n);
    let mut wt = vec![0.0f32; n * k];
    for i in 0..k {
        for j in 0..n {
            wt[j * k + i] = w[i * n + j];
        }
    }
    wt
}

/// Plain f32 GEMM with the second operand transposed: (m×k) · (n×k)ᵀ.
pub fn matmul_t(x: &[f32], wt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xr = &x[i * k..(i + 1) * k];
        for j in 0..n {
            let wr = &wt[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += xr[t] * wr[t];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Reference unquantized GEMM (row-major operands).
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for t in 0..k {
            let xv = x[i * k + t];
            if xv == 0.0 {
                continue;
            }
            let wr = &w[t * n..(t + 1) * n];
            let or = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                or[j] += xv * wr[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, BF16_SCALE, UE4M3};

    #[test]
    fn quantized_matmul_close_to_exact_for_wide_scales() {
        let mut rng = Pcg64::new(8);
        let (m, k, n) = (8, 32, 8);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1.0);
        let exact = matmul(&x, &w, m, k, n);
        let s = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8);
        let q = quantized_matmul(&s, &x, &w, m, k, n);
        // FP4 elements: coarse but correlated; relative Frobenius error
        // bounded well below 1
        let num: f64 = exact
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = exact.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(num / den < 0.05, "rel err {}", num / den);
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let mut rng = Pcg64::new(9);
        let (m, k, n) = (5, 7, 3);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1.0);
        let a = matmul(&x, &w, m, k, n);
        let b = matmul_t(&x, &transpose(&w, k, n), m, k, n);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn dispatch_rules() {
        let fp4 = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        assert_eq!(gemm_path_for(&fp4, 64), GemmPath::PackedNative);
        // unaligned k: flat blocking spans rows, only the reference does that
        assert_eq!(gemm_path_for(&fp4, 63), GemmPath::Reference);
        // integer elements: psum path is not bit-comparable -> reference
        let int4 = QuantScheme::new(ElemFormat::INT4, UE4M3, 8);
        assert_eq!(gemm_path_for(&int4, 64), GemmPath::Reference);
        // per-tensor: eq. 11 spans the whole tensor -> reference
        assert_eq!(
            gemm_path_for(&fp4.with_per_tensor(true), 64),
            GemmPath::Reference
        );
    }

    #[test]
    fn packed_dispatch_is_bit_identical_to_reference() {
        let mut rng = Pcg64::new(11);
        let (m, k, n) = (6, 48, 10);
        let x = rng.normal_vec_f32(m * k, 5e-3);
        let w = rng.normal_vec_f32(k * n, 5e-3);
        for scheme in [
            QuantScheme::new(ElemFormat::FP4, UE4M3, 8),
            QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 16),
            QuantScheme::new(ElemFormat::FP8, crate::formats::UE5M3, 12),
        ] {
            assert_eq!(gemm_path_for(&scheme, k), GemmPath::PackedNative);
            let a = quantized_matmul(&scheme, &x, &w, m, k, n);
            let b = quantized_matmul_with(
                &crate::quant::ScalarKernel,
                &scheme,
                &x,
                &w,
                m,
                k,
                n,
            );
            assert_eq!(a.len(), b.len());
            for (i, (u, v)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "{} out {i}: {u} vs {v}",
                    scheme.id()
                );
            }
        }
    }

    #[test]
    fn narrow_weights_suffer_under_ue4m3() {
        let mut rng = Pcg64::new(10);
        let (m, k, n) = (8, 64, 8);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1e-3);
        let exact = matmul(&x, &w, m, k, n);
        let err = |scheme: &QuantScheme| -> f64 {
            let q = quantized_matmul(scheme, &x, &w, m, k, n);
            exact
                .iter()
                .zip(&q)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let e43 = err(&QuantScheme::new(ElemFormat::FP4, UE4M3, 8));
        let e53 = err(&QuantScheme::new(
            ElemFormat::FP4,
            crate::formats::UE5M3,
            8,
        ));
        assert!(e53 < e43, "ue5m3 {e53} vs ue4m3 {e43}");
    }
}
