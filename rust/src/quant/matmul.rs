//! Quantized GEMM semantics on the CPU side.
//!
//! The heavy model matmuls run through the AOT HLO artifacts; this module
//! provides the same microscaling-GEMM semantics natively in Rust for
//! (a) unit/property tests against the runtime path, (b) the quant_service
//! example, and (c) the L3 perf benches.

use super::{default_kernel, QuantKernel, QuantScheme};

/// Row-major (m×k) · (k×n) with both operands microscaling-fake-quantized
/// along the contraction dimension (weights per output column, i.e. on the
/// transposed view), mirroring `ref.quantized_matmul`.
///
/// Quantization runs on [`default_kernel`]; use
/// [`quantized_matmul_with`] to pin a specific kernel (benches do).
pub fn quantized_matmul(
    scheme: &QuantScheme,
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    quantized_matmul_with(default_kernel(), scheme, x, w, m, k, n)
}

/// [`quantized_matmul`] with an explicit [`QuantKernel`].
pub fn quantized_matmul_with(
    kernel: &dyn QuantKernel,
    scheme: &QuantScheme,
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let xq = kernel.fake_quant(scheme, x); // rows contiguous: blocks along k
    // transpose w to (n, k) so its blocks run along k as well
    let mut wt = vec![0.0f32; n * k];
    for i in 0..k {
        for j in 0..n {
            wt[j * k + i] = w[i * n + j];
        }
    }
    let wtq = kernel.fake_quant(scheme, &wt);
    matmul_t(&xq, &wtq, m, k, n)
}

/// Plain f32 GEMM with the second operand transposed: (m×k) · (n×k)ᵀ.
pub fn matmul_t(x: &[f32], wt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xr = &x[i * k..(i + 1) * k];
        for j in 0..n {
            let wr = &wt[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for t in 0..k {
                acc += xr[t] * wr[t];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// Reference unquantized GEMM (row-major operands).
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for t in 0..k {
            let xv = x[i * k + t];
            if xv == 0.0 {
                continue;
            }
            let wr = &w[t * n..(t + 1) * n];
            let or = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                or[j] += xv * wr[j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, BF16_SCALE, UE4M3};

    #[test]
    fn quantized_matmul_close_to_exact_for_wide_scales() {
        let mut rng = Pcg64::new(8);
        let (m, k, n) = (8, 32, 8);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1.0);
        let exact = matmul(&x, &w, m, k, n);
        let s = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8);
        let q = quantized_matmul(&s, &x, &w, m, k, n);
        // FP4 elements: coarse but correlated; relative Frobenius error
        // bounded well below 1
        let num: f64 = exact
            .iter()
            .zip(&q)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let den: f64 = exact.iter().map(|&a| (a as f64).powi(2)).sum();
        assert!(num / den < 0.05, "rel err {}", num / den);
    }

    #[test]
    fn matmul_t_matches_matmul() {
        let mut rng = Pcg64::new(9);
        let (m, k, n) = (5, 7, 3);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1.0);
        let mut wt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                wt[j * k + i] = w[i * n + j];
            }
        }
        let a = matmul(&x, &w, m, k, n);
        let b = matmul_t(&x, &wt, m, k, n);
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn narrow_weights_suffer_under_ue4m3() {
        let mut rng = Pcg64::new(10);
        let (m, k, n) = (8, 64, 8);
        let x = rng.normal_vec_f32(m * k, 1.0);
        let w = rng.normal_vec_f32(k * n, 1e-3);
        let exact = matmul(&x, &w, m, k, n);
        let err = |scheme: &QuantScheme| -> f64 {
            let q = quantized_matmul(scheme, &x, &w, m, k, n);
            exact
                .iter()
                .zip(&q)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum()
        };
        let e43 = err(&QuantScheme::new(ElemFormat::FP4, UE4M3, 8));
        let e53 = err(&QuantScheme::new(
            ElemFormat::FP4,
            crate::formats::UE5M3,
            8,
        ));
        assert!(e53 < e43, "ue5m3 {e53} vs ue4m3 {e43}");
    }
}
