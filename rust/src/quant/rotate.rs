//! Hadamard pre-rotation for microscaled linears (DESIGN.md §16).
//!
//! The paper's block-size anomaly is a *narrow-distribution* failure:
//! when a block's absmax divided by the element max falls below the
//! quantized scale format's smallest subnormal, the whole block
//! collapses to zero (`s_zero` in [`crate::theory`]). A normalized
//! Walsh–Hadamard rotation on the contraction dimension mixes every
//! channel into every output coordinate, replacing each block's local
//! spread with the tensor's global RMS — narrow channels are lifted out
//! of the scale-underflow region at the cost of widening nothing (H is
//! orthonormal, ‖Hx‖₂ = ‖x‖₂). LATMiX (PAPERS.md) and the
//! `fast_hadamard_transform` dependency of the source repo's
//! environment ground the technique; here it is exact, dependency-free,
//! and CPU-side.
//!
//! Contract: `H` is the normalized Sylvester Hadamard matrix, symmetric
//! and self-inverse (`H = Hᵀ = H⁻¹`). A linear `y = xW` becomes
//! `y = (xH)(HW)` — rotating activation *rows* and weight *columns*
//! (the contraction dimension) leaves the output basis untouched, so
//! there is no epilogue to undo and attention/KV paths downstream are
//! oblivious. The "inverse rotation" is folded into the prepacked
//! weight operand at build time. Non-power-of-two dimensions use a
//! block-diagonal cover: greedily the largest power-of-two chunk, then
//! recurse on the remainder (`d = 2^a + 2^b + …`, a strictly decreasing
//! sum — each chunk gets its own FWHT, cross-chunk mixing is skipped).
//!
//! Determinism: the in-place butterfly fixes the f32 evaluation order,
//! so rotated packed and rotated reference paths see bit-identical
//! inputs — the repo's packed==reference contract survives rotation by
//! both sides calling the same functions here.

/// In-place normalized FWHT over `x` (length MUST be a power of two).
///
/// Classic butterfly: `log2(n)` passes of paired sum/difference, then
/// one multiply by `n^-1/2`. `n^-1/2` is exact in f32 only for even
/// powers of two, so normalization uses `1.0 / sqrt(n)` — determinism
/// (same bits every call) is what the contract needs, not exactness.
pub fn fwht_pow2(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two(), "fwht_pow2 needs a power of two");
    if n <= 1 {
        return;
    }
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= norm;
    }
}

/// The block-diagonal power-of-two cover of `d`: chunk `(offset, len)`
/// pairs, largest chunk first, lengths strictly decreasing powers of
/// two summing to `d` (the binary expansion of `d`).
pub fn pow2_chunks(d: usize) -> Vec<(usize, usize)> {
    let mut chunks = Vec::new();
    let mut off = 0;
    let mut rem = d;
    while rem > 0 {
        let len = if rem.is_power_of_two() {
            rem
        } else {
            rem.next_power_of_two() / 2
        };
        chunks.push((off, len));
        off += len;
        rem -= len;
    }
    chunks
}

/// In-place block-diagonal FWHT over one vector of any length.
pub fn fwht(x: &mut [f32]) {
    for (off, len) in pow2_chunks(x.len()) {
        fwht_pow2(&mut x[off..off + len]);
    }
}

/// Rotate every row of a row-major `rows × d` matrix in place: the
/// activation-side transform (`x → xH`; H symmetric, so right- and
/// left-multiplication agree on a row vector).
pub fn fwht_rows(x: &mut [f32], d: usize) {
    if d == 0 {
        return;
    }
    debug_assert_eq!(x.len() % d, 0, "matrix len {} not a multiple of d {d}", x.len());
    for row in x.chunks_exact_mut(d) {
        fwht(row);
    }
}

/// Rotate every column of a row-major `k × n` matrix: the weight-side
/// transform (`W → HW` over the contraction dimension `k`). Returns a
/// new matrix; the column gather/scatter goes through a scratch vector
/// so each column sees the identical f32 butterfly as [`fwht`].
pub fn fwht_cols(w: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(w.len(), k * n, "weight len {} != {k}x{n}", w.len());
    let mut out = w.to_vec();
    if k == 0 || n == 0 {
        return out;
    }
    let mut col = vec![0.0f32; k];
    for j in 0..n {
        for i in 0..k {
            col[i] = w[i * n + j];
        }
        fwht(&mut col);
        for i in 0..k {
            out[i * n + j] = col[i];
        }
    }
    out
}

/// Rotate the columns of an `n × k` row-major *transposed* weight (each
/// row is one output channel's k-vector over the contraction dim): the
/// form the operand cache packs. Equivalent to `transpose(fwht_cols)`.
pub fn fwht_rows_transposed(wt: &mut [f32], k: usize) {
    fwht_rows(wt, k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;

    fn gauss(n: usize, seed: u64) -> Vec<f32> {
        Pcg64::new(seed).normal_vec_f32(n, 1.0)
    }

    #[test]
    fn matches_dense_hadamard_n8() {
        // H_8 by direct Sylvester construction vs the butterfly.
        let n = 8;
        let mut h = vec![vec![1.0f64]];
        while h.len() < n {
            let m = h.len();
            let mut nh = vec![vec![0.0f64; 2 * m]; 2 * m];
            for i in 0..m {
                for j in 0..m {
                    nh[i][j] = h[i][j];
                    nh[i][j + m] = h[i][j];
                    nh[i + m][j] = h[i][j];
                    nh[i + m][j + m] = -h[i][j];
                }
            }
            h = nh;
        }
        let x = gauss(n, 7);
        let mut fast = x.clone();
        fwht_pow2(&mut fast);
        let norm = 1.0 / (n as f64).sqrt();
        for i in 0..n {
            let dense: f64 = (0..n)
                .map(|j| h[i][j] * x[j] as f64)
                .sum::<f64>()
                * norm;
            assert!(
                (dense - fast[i] as f64).abs() < 1e-5,
                "row {i}: dense {dense} vs fast {}",
                fast[i]
            );
        }
    }

    #[test]
    fn self_inverse_round_trip() {
        for d in [1usize, 2, 8, 64, 96, 100, 257] {
            let x = gauss(d, 42 + d as u64);
            let mut y = x.clone();
            fwht(&mut y);
            fwht(&mut y);
            for i in 0..d {
                assert!(
                    (y[i] - x[i]).abs() <= 1e-4 * x[i].abs().max(1.0),
                    "d={d} i={i}: {} vs {}",
                    y[i],
                    x[i]
                );
            }
        }
    }

    #[test]
    fn orthonormal_preserves_norm() {
        for d in [4usize, 32, 48, 129] {
            let x = gauss(d, 9 + d as u64);
            let n0: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
            let mut y = x.clone();
            fwht(&mut y);
            let n1: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
            assert!(
                (n1 - n0).abs() < 1e-3 * n0.max(1.0),
                "d={d}: ‖Hx‖²={n1} vs ‖x‖²={n0}"
            );
        }
    }

    #[test]
    fn chunks_cover_binary_expansion() {
        assert_eq!(pow2_chunks(8), vec![(0, 8)]);
        assert_eq!(pow2_chunks(12), vec![(0, 8), (8, 4)]);
        assert_eq!(pow2_chunks(100), vec![(0, 64), (64, 32), (96, 4)]);
        assert_eq!(pow2_chunks(1), vec![(0, 1)]);
        assert!(pow2_chunks(0).is_empty());
        for d in 1..300usize {
            let c = pow2_chunks(d);
            assert_eq!(c.iter().map(|(_, l)| l).sum::<usize>(), d);
            let mut off = 0;
            let mut prev = usize::MAX;
            for (o, l) in c {
                assert_eq!(o, off);
                assert!(l.is_power_of_two() && l < prev);
                off += l;
                prev = l;
            }
        }
    }

    #[test]
    fn rows_and_cols_are_transposes() {
        let (k, n) = (24, 5);
        let w = gauss(k * n, 3);
        let rotated = fwht_cols(&w, k, n);
        // transpose → fwht_rows → transpose back must agree bit for bit
        let mut wt = vec![0.0f32; k * n];
        for i in 0..k {
            for j in 0..n {
                wt[j * k + i] = w[i * n + j];
            }
        }
        fwht_rows_transposed(&mut wt, k);
        for i in 0..k {
            for j in 0..n {
                assert_eq!(
                    rotated[i * n + j].to_bits(),
                    wt[j * k + i].to_bits(),
                    "({i},{j})"
                );
            }
        }
    }

    #[test]
    fn rotation_commutes_with_matmul() {
        // (xH)(HW) ≈ xW — the folding identity the packed path relies on.
        let (m, k, n) = (3, 32, 7);
        let x = gauss(m * k, 11);
        let w = gauss(k * n, 13);
        let mut xr = x.clone();
        fwht_rows(&mut xr, k);
        let wr = fwht_cols(&w, k, n);
        for i in 0..m {
            for j in 0..n {
                let plain: f64 = (0..k)
                    .map(|t| x[i * k + t] as f64 * w[t * n + j] as f64)
                    .sum();
                let rot: f64 = (0..k)
                    .map(|t| xr[i * k + t] as f64 * wr[t * n + j] as f64)
                    .sum();
                assert!(
                    (plain - rot).abs() < 1e-3 * plain.abs().max(1.0),
                    "({i},{j}): {plain} vs {rot}"
                );
            }
        }
    }

    #[test]
    fn rotation_is_deterministic() {
        let x = gauss(96, 5);
        let mut a = x.clone();
        let mut b = x;
        fwht(&mut a);
        fwht(&mut b);
        for (u, v) in a.iter().zip(&b) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }
}
