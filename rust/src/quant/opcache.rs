//! Shared prepacked weight-operand cache.
//!
//! Packing a weight matrix into a [`GemmOperand`] (transpose + absmax +
//! scale cast + element cast per block) costs as much as several
//! multiplies against it, and both serving sessions and experiment
//! sweeps multiply the *same* (tensor, qconfig) pairs over and over.
//! [`OperandCache`] encodes each pair once and hands out `Arc` clones of
//! that one operand afterwards — which also makes the hit path
//! bit-identical to the miss path by construction (there is exactly one
//! encode; [`GemmOperand::bits_digest`] lets tests assert it).
//!
//! The cache lives in the quant layer (it is keyed on [`QuantScheme`]
//! and stores [`GemmOperand`]s — nothing serve-specific) so the layer
//! dependency stays one-directional; the serve subsystem re-exports it
//! as `serve::cache`.
//!
//! Keying is by **content**: two independent 64-bit FNV-1a word digests
//! over the raw f32 bit patterns (computed in one fused pass), plus
//! shape and the full scheme id. A collision would need both digests to
//! agree on different data (~2⁻¹²⁸ per pair) — far below any
//! hardware-error floor. Eviction is insertion-order FIFO with a
//! configurable entry cap, so a sweep over hundreds of distinct tensors
//! cannot grow the cache without bound.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, OnceLock};

use super::gemm::GemmOperand;
use super::QuantScheme;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Key {
    h1: u64,
    h2: u64,
    k: usize,
    n: usize,
    scheme: String,
    /// Column-shard slot `(index, count)` of the split this entry
    /// holds — `(0, 1)` for the whole operand. The content digests
    /// cover the *full* weight either way, so without this field a
    /// shard encode and an unsharded encode of the same bytes would
    /// alias to one entry and serve the wrong operand to one of them.
    shard: (usize, usize),
    /// Whether the weight passed through the Hadamard pre-rotation
    /// ([`crate::quant::rotate`]) before packing. The digests cover the
    /// *unrotated* bytes (rotation happens inside the pack closure, so
    /// callers never re-rotate per lookup) — without this field a
    /// rotated and an unrotated encode of the same weight would alias
    /// and one caller would multiply against the wrong basis.
    rotate: bool,
}

/// Monotonic cache counters (snapshot via [`OperandCache::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Current resident entry count.
    pub entries: usize,
    /// Current resident working-set bytes
    /// ([`GemmOperand::resident_bytes`] summed over entries).
    pub resident_bytes: usize,
}

struct Inner {
    map: HashMap<Key, Arc<GemmOperand>>,
    order: VecDeque<Key>,
    resident_bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// A bounded, thread-safe (tensor, qconfig) → prepacked-operand cache.
/// Residency is capped both by entry count and by working-set bytes
/// (FIFO eviction on whichever bound is hit first), so neither many
/// small operands nor a few huge ones can grow the cache without
/// bound.
pub struct OperandCache {
    cap: usize,
    byte_cap: usize,
    inner: Mutex<Inner>,
}

impl OperandCache {
    /// Default working-set byte budget (see [`OperandCache::new`]).
    pub const DEFAULT_BYTE_CAP: usize = 256 << 20;

    /// Cache holding at most `cap` operands and at most
    /// [`OperandCache::DEFAULT_BYTE_CAP`] resident bytes.
    pub fn new(cap: usize) -> OperandCache {
        OperandCache::with_byte_cap(cap, Self::DEFAULT_BYTE_CAP)
    }

    /// Cache bounded by `cap` entries and `byte_cap` resident bytes.
    pub fn with_byte_cap(cap: usize, byte_cap: usize) -> OperandCache {
        OperandCache {
            cap: cap.max(1),
            byte_cap: byte_cap.max(1),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
                resident_bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// The prepacked transposed operand for a row-major `k × n` weight
    /// matrix under `scheme` (the [`GemmOperand::quantize_transposed`]
    /// layout): encoded on first use, shared afterwards.
    pub fn get_or_pack_transposed(
        &self,
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
    ) -> crate::Result<Arc<GemmOperand>> {
        self.lookup_or_pack(scheme, w, k, n, (0, 1), false, || {
            GemmOperand::quantize_transposed(scheme, w, k, n)
        })
    }

    /// Like [`OperandCache::get_or_pack_transposed`], but the weight's
    /// contraction dimension is Hadamard-rotated (`W → HW`, i.e.
    /// [`super::rotate::fwht_cols`]) before packing — the folded
    /// weight-side half of the `Q(xH)·Q(HW)` rotated GEMM. Keyed by the
    /// unrotated content digest plus a rotation flag, so rotated and
    /// unrotated encodes of the same bytes never alias.
    pub fn get_or_pack_transposed_rotated(
        &self,
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
    ) -> crate::Result<Arc<GemmOperand>> {
        self.lookup_or_pack(scheme, w, k, n, (0, 1), true, || {
            let wr = super::rotate::fwht_cols(w, k, n);
            GemmOperand::quantize_transposed(scheme, &wr, k, n)
        })
    }

    /// The prepacked transposed operand for output columns `c0..c1`
    /// (shard `index` of `count`) of a row-major `k × n` weight
    /// matrix. Keyed by the *full* weight's content digest plus the
    /// shard slot, so shards of one tensor share the cheap one-pass
    /// digest while sharded and unsharded entries never alias (shard
    /// slot `(0, 1)` is the whole operand, i.e.
    /// [`OperandCache::get_or_pack_transposed`]).
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_pack_transposed_shard(
        &self,
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
        index: usize,
        count: usize,
        c0: usize,
        c1: usize,
    ) -> crate::Result<Arc<GemmOperand>> {
        anyhow::ensure!(
            index < count && c0 < c1 && c1 <= n,
            "shard {index}/{count} columns {c0}..{c1} invalid for n={n}"
        );
        if count == 1 {
            anyhow::ensure!(
                c0 == 0 && c1 == n,
                "a 1-count shard must cover all {n} columns"
            );
            return self.get_or_pack_transposed(scheme, w, k, n);
        }
        self.lookup_or_pack(scheme, w, k, n, (index, count), false, || {
            let sub = shard_slice(w, k, n, c0, c1)?;
            GemmOperand::quantize_transposed(scheme, &sub, k, c1 - c0)
        })
    }

    /// The rotated form of [`OperandCache::get_or_pack_transposed_shard`].
    /// The FWHT acts on each output column independently over the
    /// contraction dimension, so rotating the column slice equals
    /// slicing the rotated full weight bit for bit — shards of a
    /// rotated operand still reassemble to the unsharded rotated
    /// encode.
    #[allow(clippy::too_many_arguments)]
    pub fn get_or_pack_transposed_shard_rotated(
        &self,
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
        index: usize,
        count: usize,
        c0: usize,
        c1: usize,
    ) -> crate::Result<Arc<GemmOperand>> {
        anyhow::ensure!(
            index < count && c0 < c1 && c1 <= n,
            "shard {index}/{count} columns {c0}..{c1} invalid for n={n}"
        );
        if count == 1 {
            anyhow::ensure!(
                c0 == 0 && c1 == n,
                "a 1-count shard must cover all {n} columns"
            );
            return self.get_or_pack_transposed_rotated(scheme, w, k, n);
        }
        self.lookup_or_pack(scheme, w, k, n, (index, count), true, || {
            let sub = shard_slice(w, k, n, c0, c1)?;
            let width = c1 - c0;
            let sub = super::rotate::fwht_cols(&sub, k, width);
            GemmOperand::quantize_transposed(scheme, &sub, k, width)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn lookup_or_pack(
        &self,
        scheme: &QuantScheme,
        w: &[f32],
        k: usize,
        n: usize,
        shard: (usize, usize),
        rotate: bool,
        pack: impl FnOnce() -> crate::Result<GemmOperand>,
    ) -> crate::Result<Arc<GemmOperand>> {
        let (h1, h2) = content_digests(w);
        let key = Key { h1, h2, k, n, scheme: scheme.id(), shard, rotate };
        {
            let mut g = self.inner.lock().unwrap();
            let found = g.map.get(&key).cloned();
            if let Some(op) = found {
                g.hits += 1;
                return Ok(op);
            }
        }
        // pack outside the lock: two threads missing the same key may
        // both encode, but encoding is deterministic and the first
        // insert wins, so every caller still sees one canonical operand
        let op = Arc::new(pack()?);
        let mut g = self.inner.lock().unwrap();
        g.misses += 1;
        if let Some(existing) = g.map.get(&key).cloned() {
            return Ok(existing);
        }
        g.resident_bytes += op.resident_bytes();
        g.map.insert(key.clone(), op.clone());
        g.order.push_back(key);
        while g.map.len() > self.cap || g.resident_bytes > self.byte_cap {
            match g.order.pop_front() {
                Some(old) => {
                    if let Some(gone) = g.map.remove(&old) {
                        g.resident_bytes =
                            g.resident_bytes.saturating_sub(gone.resident_bytes());
                    }
                    g.evictions += 1;
                }
                None => break,
            }
        }
        Ok(op)
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            entries: g.map.len(),
            resident_bytes: g.resident_bytes,
        }
    }

    /// Drop every resident operand (counters are kept).
    pub fn clear(&self) {
        let mut g = self.inner.lock().unwrap();
        g.map.clear();
        g.order.clear();
        g.resident_bytes = 0;
    }
}

/// The process-wide cache shared by every serve session and by
/// [`crate::quant::matmul::quantized_matmul`] sweeps: up to 128
/// operands / [`OperandCache::DEFAULT_BYTE_CAP`] resident bytes, so a
/// sweep over large weight tensors (a 4096×4096 operand is ~17 MiB)
/// hits the byte bound long before the entry bound.
pub fn operand_cache() -> &'static OperandCache {
    static CACHE: OnceLock<OperandCache> = OnceLock::new();
    CACHE.get_or_init(|| OperandCache::new(128))
}

/// Materialize the `k × (c1-c0)` column slice of a row-major `k × n`
/// weight: per-row quantization makes packing this byte-equal to
/// slicing rows `c0..c1` of the full transposed operand.
fn shard_slice(
    w: &[f32],
    k: usize,
    n: usize,
    c0: usize,
    c1: usize,
) -> crate::Result<Vec<f32>> {
    anyhow::ensure!(w.len() == k * n, "weight len != {k}x{n}");
    let width = c1 - c0;
    let mut sub = vec![0.0f32; k * width];
    for r in 0..k {
        sub[r * width..(r + 1) * width]
            .copy_from_slice(&w[r * n + c0..r * n + c1]);
    }
    Ok(sub)
}

/// Two independent FNV-1a word digests over the f32 bit patterns in
/// **one** pass (the fused form of two [`crate::util::fnv1a_words`]
/// runs — hashing is on the `quantized_matmul` hot path, so one memory
/// sweep matters): different bases, second stream bit-rotated so the
/// digests never degenerate into each other.
fn content_digests(w: &[f32]) -> (u64, u64) {
    const SECOND_BASIS: u64 = 0x6c62_272e_07bb_0142;
    let mut h1 = crate::util::FNV_OFFSET_BASIS;
    let mut h2 = SECOND_BASIS;
    for &v in w {
        let b = v.to_bits() as u64;
        h1 = (h1 ^ b).wrapping_mul(crate::util::FNV_PRIME);
        h2 = (h2 ^ b.rotate_left(17)).wrapping_mul(crate::util::FNV_PRIME);
    }
    (h1, h2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, UE5M3};

    #[test]
    fn hit_returns_the_same_operand() {
        let cache = OperandCache::new(4);
        let mut rng = Pcg64::new(5);
        let (k, n) = (16usize, 6);
        let w = rng.normal_vec_f32(k * n, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        let a = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
        let b = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        // same bytes under a different scheme is a different entry
        let scheme16 = QuantScheme::new(ElemFormat::FP4, UE5M3, 16);
        let c = cache.get_or_pack_transposed(&scheme16, &w, k, n).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn shard_slots_never_alias_the_unsharded_entry() {
        let cache = OperandCache::new(8);
        let mut rng = Pcg64::new(9);
        let (k, n) = (16usize, 16usize);
        let w = rng.normal_vec_f32(k * n, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        let full = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
        // shard 0 of 2 covers columns 0..8 of the same bytes/shape key
        let s0 = cache
            .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 2, 0, 8)
            .unwrap();
        let s1 = cache
            .get_or_pack_transposed_shard(&scheme, &w, k, n, 1, 2, 8, 16)
            .unwrap();
        assert!(!Arc::ptr_eq(&full, &s0));
        assert_eq!(cache.stats().entries, 3);
        // repeat lookups hit the same Arcs
        let s0b = cache
            .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 2, 0, 8)
            .unwrap();
        assert!(Arc::ptr_eq(&s0, &s0b));
        // the shard encode equals slicing the full operand's rows
        assert_eq!(
            s0.bits_digest(),
            full.slice_rows(0, 8).unwrap().bits_digest()
        );
        assert_eq!(
            s1.bits_digest(),
            full.slice_rows(8, 16).unwrap().bits_digest()
        );
        // a 1-count shard IS the unsharded entry (intentional sharing)
        let whole = cache
            .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 1, 0, 16)
            .unwrap();
        assert!(Arc::ptr_eq(&full, &whole));
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn rotation_flag_never_aliases() {
        // ISSUE-10 regression: rotation must be part of cache identity —
        // rotated and unrotated encodes of the same weight bytes are
        // distinct entries with distinct packed bits (mirror of the
        // shard-slot aliasing tests above).
        let cache = OperandCache::new(16);
        let mut rng = Pcg64::new(21);
        let (k, n) = (32usize, 16usize);
        let w = rng.normal_vec_f32(k * n, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        let plain = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
        let rot = cache
            .get_or_pack_transposed_rotated(&scheme, &w, k, n)
            .unwrap();
        assert!(!Arc::ptr_eq(&plain, &rot));
        assert_ne!(plain.bits_digest(), rot.bits_digest());
        assert_eq!(cache.stats().entries, 2);
        // repeat lookups hit their own entries, in both orders
        let rot2 = cache
            .get_or_pack_transposed_rotated(&scheme, &w, k, n)
            .unwrap();
        let plain2 = cache.get_or_pack_transposed(&scheme, &w, k, n).unwrap();
        assert!(Arc::ptr_eq(&rot, &rot2));
        assert!(Arc::ptr_eq(&plain, &plain2));
        assert_eq!(cache.stats().entries, 2);
        // the rotated encode equals packing the pre-rotated bytes
        let wr = crate::quant::rotate::fwht_cols(&w, k, n);
        let direct = GemmOperand::quantize_transposed(&scheme, &wr, k, n).unwrap();
        assert_eq!(rot.bits_digest(), direct.bits_digest());
    }

    #[test]
    fn rotated_shards_slice_the_rotated_full_operand() {
        let cache = OperandCache::new(16);
        let mut rng = Pcg64::new(22);
        let (k, n) = (16usize, 16usize);
        let w = rng.normal_vec_f32(k * n, 0.02);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        let full = cache
            .get_or_pack_transposed_rotated(&scheme, &w, k, n)
            .unwrap();
        let s0 = cache
            .get_or_pack_transposed_shard_rotated(&scheme, &w, k, n, 0, 2, 0, 8)
            .unwrap();
        let s1 = cache
            .get_or_pack_transposed_shard_rotated(&scheme, &w, k, n, 1, 2, 8, 16)
            .unwrap();
        assert!(!Arc::ptr_eq(&full, &s0));
        assert_eq!(
            s0.bits_digest(),
            full.slice_rows(0, 8).unwrap().bits_digest()
        );
        assert_eq!(
            s1.bits_digest(),
            full.slice_rows(8, 16).unwrap().bits_digest()
        );
        // rotated shard never aliases the unrotated shard of same slot
        let u0 = cache
            .get_or_pack_transposed_shard(&scheme, &w, k, n, 0, 2, 0, 8)
            .unwrap();
        assert!(!Arc::ptr_eq(&s0, &u0));
        assert_ne!(s0.bits_digest(), u0.bits_digest());
        // a 1-count rotated shard IS the unsharded rotated entry
        let whole = cache
            .get_or_pack_transposed_shard_rotated(&scheme, &w, k, n, 0, 1, 0, 16)
            .unwrap();
        assert!(Arc::ptr_eq(&full, &whole));
    }

    #[test]
    fn eviction_caps_residency() {
        let cache = OperandCache::new(2);
        let mut rng = Pcg64::new(6);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        for _ in 0..5 {
            let w = rng.normal_vec_f32(8 * 3, 0.02);
            cache.get_or_pack_transposed(&scheme, &w, 8, 3).unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 3);
        assert!(s.resident_bytes > 0);
        cache.clear();
        let s = cache.stats();
        assert_eq!((s.entries, s.resident_bytes), (0, 0));
    }

    #[test]
    fn byte_budget_caps_residency() {
        // each 8x3 FP4/bs8 operand resides at 3*8 codes + 3 scales*4 =
        // 36 bytes; a 100-byte budget holds at most two
        let cache = OperandCache::with_byte_cap(64, 100);
        let mut rng = Pcg64::new(7);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        for _ in 0..5 {
            let w = rng.normal_vec_f32(8 * 3, 0.02);
            let op = cache.get_or_pack_transposed(&scheme, &w, 8, 3).unwrap();
            assert_eq!(op.resident_bytes(), 36);
        }
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert!(s.resident_bytes <= 100, "{} bytes resident", s.resident_bytes);
        assert_eq!(s.evictions, 3);
    }
}
