//! Column-wise tensor-parallel sharding of prepacked GEMM operands.
//!
//! A transposed weight operand ([`GemmOperand::quantize_transposed`])
//! stores one block row per *output column*, so splitting it row-wise
//! partitions the GEMM's output columns — each shard computes
//! `x · wᵀ[c0..c1]` independently, with no partial sums crossing
//! shards. Two invariants make the split bit-exact:
//!
//! 1. **Block alignment.** Scale blocks run along the contraction
//!    dimension `k` *within* each row, so any row (= output column)
//!    boundary already keeps every per-block scale intact. We
//!    nevertheless align shard boundaries to whole column blocks of
//!    `block_size` output columns ([`shard_ranges`]) so that sharding
//!    composes with activation-side blocking and future fused layouts
//!    never see a scale group straddling a shard.
//! 2. **Fixed-order combine.** Each output element `out[r, c]` is
//!    produced by exactly one shard, accumulated in the same ascending
//!    contraction order as the unsharded kernel, and scattered into
//!    its final position in fixed shard order — no floating-point
//!    reduction is reordered, so sharded output bits equal unsharded
//!    output bits for every shard count (DESIGN.md §12).
//!
//! The `fusion_safe` range check (gemm.rs) is evaluated per shard: a
//! shard's scale range is a subset of the parent's, so a fusion-safe
//! parent yields only fusion-safe shards, while a fusion-*unsafe*
//! parent may produce a mix of packed and decode-fallback shards.
//! Either way the bits match the unsharded result, because both paths
//! are exact per output column (the packed path equals decode+matmul
//! whenever its intermediates stay in range, which is what
//! `fusion_safe` certifies).

use std::sync::Arc;

use crate::util::par::ShardPool;

use super::gemm::{GemmOperand, PackedGemm};

/// Split `n` output columns into at most `shards` contiguous ranges
/// whose boundaries fall on multiples of `block_size` (the last range
/// absorbs any trailing partial block). Whole column blocks are
/// distributed as evenly as possible — range sizes differ by at most
/// one block — and the effective shard count is capped at
/// `ceil(n / block_size)`, so no range is ever empty: asking for more
/// shards than there are column blocks degrades gracefully instead of
/// manufacturing empty workers.
pub fn shard_ranges(
    n: usize,
    block_size: usize,
    shards: usize,
) -> Vec<(usize, usize)> {
    assert!(block_size > 0, "block size must be positive");
    if n == 0 {
        return vec![(0, 0)];
    }
    let units = n.div_ceil(block_size);
    let count = shards.clamp(1, units);
    let base = units / count;
    let extra = units % count; // first `extra` shards take one more block
    let mut ranges = Vec::with_capacity(count);
    let mut unit = 0usize;
    for s in 0..count {
        let take = base + usize::from(s < extra);
        let c0 = unit * block_size;
        unit += take;
        let c1 = (unit * block_size).min(n);
        ranges.push((c0, c1));
    }
    ranges
}

/// A transposed weight operand split into block-aligned column shards,
/// plus the fan-out/combine logic that keeps the sharded matmul
/// bit-identical to the unsharded one.
///
/// Shard `s` holds output columns `ranges[s] = (c0, c1)` as an
/// independent [`GemmOperand`] (rows `c0..c1` of the transposed
/// parent). A one-shard instance stores the parent operand itself and
/// [`ShardedOperand::matmul`] routes straight through
/// [`PackedGemm::matmul`] — the unsharded path is the `shards = 1`
/// special case, not a separate code path.
pub struct ShardedOperand {
    ops: Vec<Arc<GemmOperand>>,
    ranges: Vec<(usize, usize)>,
    /// contraction length (the parent's logical columns).
    k: usize,
    /// total output columns (the parent's logical rows).
    n: usize,
}

impl ShardedOperand {
    /// Wrap a whole (unsharded) transposed operand.
    pub fn single(op: Arc<GemmOperand>) -> ShardedOperand {
        let (k, n) = (op.cols(), op.rows());
        ShardedOperand { ranges: vec![(0, n)], ops: vec![op], k, n }
    }

    /// Split a transposed operand into at most `shards` block-aligned
    /// column shards via [`GemmOperand::slice_rows`]. `shards <= 1`
    /// (or a single-block operand) shares the parent allocation
    /// through [`ShardedOperand::single`] instead of copying.
    pub fn split(
        op: &Arc<GemmOperand>,
        shards: usize,
    ) -> crate::Result<ShardedOperand> {
        let ranges = shard_ranges(op.rows(), op.scheme().block_size, shards);
        if ranges.len() <= 1 {
            return Ok(ShardedOperand::single(op.clone()));
        }
        let mut ops = Vec::with_capacity(ranges.len());
        for &(c0, c1) in &ranges {
            ops.push(Arc::new(op.slice_rows(c0, c1)?));
        }
        Ok(ShardedOperand { ops, ranges, k: op.cols(), n: op.rows() })
    }

    /// Assemble from pre-packed shard operands (e.g. per-shard
    /// [`crate::quant::opcache::OperandCache`] entries) and their
    /// column ranges. Validates that the ranges tile `0..n`
    /// contiguously and that every operand matches its range and
    /// shares one scheme and per-tensor factor.
    pub fn from_parts(
        ops: Vec<Arc<GemmOperand>>,
        ranges: Vec<(usize, usize)>,
    ) -> crate::Result<ShardedOperand> {
        anyhow::ensure!(
            !ops.is_empty() && ops.len() == ranges.len(),
            "{} operands vs {} ranges",
            ops.len(),
            ranges.len()
        );
        let k = ops[0].cols();
        let mut at = 0usize;
        for (op, &(c0, c1)) in ops.iter().zip(&ranges) {
            anyhow::ensure!(
                c0 == at && c1 > c0,
                "shard ranges must tile 0..n contiguously (got {c0}..{c1} \
                 at {at})"
            );
            anyhow::ensure!(
                op.rows() == c1 - c0,
                "shard operand has {} rows for range {c0}..{c1}",
                op.rows()
            );
            anyhow::ensure!(
                op.cols() == k,
                "shard contraction mismatch: {} vs {k}",
                op.cols()
            );
            anyhow::ensure!(
                op.scheme() == ops[0].scheme()
                    && op.per_tensor_factor().to_bits()
                        == ops[0].per_tensor_factor().to_bits(),
                "shards must share one scheme and per-tensor factor"
            );
            at = c1;
        }
        let n = at;
        Ok(ShardedOperand { ops, ranges, k, n })
    }

    /// Number of shards (1 for the unsharded wrapper).
    pub fn shards(&self) -> usize {
        self.ops.len()
    }

    /// The shard operands, in column order.
    pub fn parts(&self) -> &[Arc<GemmOperand>] {
        &self.ops
    }

    /// Output-column range `(c0, c1)` owned by each shard.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Contraction length `k`.
    pub fn contraction(&self) -> usize {
        self.k
    }

    /// Total output columns `n`.
    pub fn out_cols(&self) -> usize {
        self.n
    }

    /// Sum of the shards' in-RAM bytes (equals the parent's
    /// [`GemmOperand::resident_bytes`] exactly — slicing copies rows,
    /// it never pads).
    pub fn resident_bytes(&self) -> usize {
        self.ops.iter().map(|op| op.resident_bytes()).sum()
    }

    /// Sum of the shards' wire-format bytes. May exceed the parent's
    /// [`GemmOperand::payload_bytes`] by at most one byte per shard
    /// (sub-byte code fields are rounded up per operand).
    pub fn payload_bytes(&self) -> usize {
        self.ops.iter().map(|op| op.payload_bytes()).sum()
    }

    /// Stitch the shards back into one operand
    /// ([`GemmOperand::concat_rows`]); byte-for-byte equal to the
    /// parent, `bits_digest` included.
    pub fn reassemble(&self) -> crate::Result<GemmOperand> {
        let refs: Vec<&GemmOperand> =
            self.ops.iter().map(Arc::as_ref).collect();
        GemmOperand::concat_rows(&refs)
    }

    /// Sharded `x · wᵀ`: fan one packed matmul per shard out over
    /// `pool` (or run them serially in shard order when `pool` is
    /// `None`), then scatter each shard's `m × (c1-c0)` panel into its
    /// fixed column range of the `m × n` output. Bit-identical to
    /// `gemm.matmul(&x, &parent)` for every shard count and pool size
    /// — each output element is computed by the same kernel in the
    /// same accumulation order, and the combine only moves bytes.
    pub fn matmul(
        &self,
        x: GemmOperand,
        gemm: &PackedGemm,
        pool: Option<&ShardPool>,
    ) -> crate::Result<Vec<f32>> {
        if self.ops.len() == 1 {
            return gemm.matmul(&x, &self.ops[0]);
        }
        let m = x.rows();
        let x = Arc::new(x);
        let gemm = *gemm;
        let parts: Vec<crate::Result<Vec<f32>>> = match pool {
            Some(pool) => pool.run(
                self.ops
                    .iter()
                    .map(|op| {
                        let (x, op) = (Arc::clone(&x), Arc::clone(op));
                        move || gemm.matmul(&x, &op)
                    })
                    .collect(),
            ),
            None => self.ops.iter().map(|op| gemm.matmul(&x, op)).collect(),
        };
        let n = self.n;
        let mut out = vec![0.0f32; m * n];
        for (part, &(c0, c1)) in parts.into_iter().zip(&self.ranges) {
            let part = part?;
            let w = c1 - c0;
            debug_assert_eq!(part.len(), m * w);
            for r in 0..m {
                out[r * n + c0..r * n + c1]
                    .copy_from_slice(&part[r * w..(r + 1) * w]);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, UE4M3};
    use crate::quant::kernel::plan_threads;
    use crate::quant::QuantScheme;

    fn scheme(bs: usize) -> QuantScheme {
        QuantScheme { elem: ElemFormat::FP4, scale: UE4M3, block_size: bs, per_tensor: false }
    }

    #[test]
    fn ranges_tile_and_align() {
        for (n, bs, shards) in
            [(64, 8, 4), (13, 8, 2), (96, 32, 7), (8, 8, 5), (100, 16, 3)]
        {
            let r = shard_ranges(n, bs, shards);
            assert!(r.len() <= shards.max(1));
            assert!(r.len() <= n.div_ceil(bs));
            let mut at = 0;
            for (i, &(c0, c1)) in r.iter().enumerate() {
                assert_eq!(c0, at, "n={n} bs={bs} shards={shards}");
                assert!(c1 > c0);
                assert_eq!(c0 % bs, 0, "start must be block-aligned");
                if i + 1 < r.len() {
                    assert_eq!(c1 % bs, 0, "interior ends block-aligned");
                }
                at = c1;
            }
            assert_eq!(at, n, "ranges must cover every column");
        }
        // degenerate: one block or fewer -> one shard
        assert_eq!(shard_ranges(5, 8, 4), vec![(0, 5)]);
        assert_eq!(shard_ranges(0, 8, 4), vec![(0, 0)]);
    }

    #[test]
    fn single_shard_routes_through_parent() {
        let mut rng = Pcg64::new(11);
        let (k, n) = (16usize, 24usize);
        let w = rng.normal_vec_f32(k * n, 1.0);
        let op =
            Arc::new(GemmOperand::quantize_transposed(&scheme(8), &w, k, n).unwrap());
        let sh = ShardedOperand::split(&op, 1).unwrap();
        assert_eq!(sh.shards(), 1);
        // no copy: the single shard IS the parent allocation
        assert!(Arc::ptr_eq(&sh.parts()[0], &op));
        assert_eq!(sh.resident_bytes(), op.resident_bytes());
    }

    #[test]
    fn pool_workers_pin_inner_kernels_serial() {
        // plan_threads() must collapse to 1 on every shard slot (inline
        // job 0 and pool workers alike): the no-oversubscription pin.
        let pool = ShardPool::new(3);
        let plans = pool.run(
            (0..4)
                .map(|_| || plan_threads(usize::MAX / 4, 8, 0))
                .collect::<Vec<_>>(),
        );
        assert_eq!(plans, vec![1, 1, 1, 1]);
        // off-pool, the same request fans out
        assert!(plan_threads(usize::MAX / 4, 8, 0) > 1 || crate::util::par::max_threads() == 1);
    }
}
