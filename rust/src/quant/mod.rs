//! Block microscaling quantizer (Sec. 2.1) — the experiment-path
//! implementation, bit-identical to `ref.py` (see `rust/tests/golden.rs`).
//!
//! [`QuantScheme`] bundles (element format, scale format, block size,
//! per-tensor scaling); [`fake_quant`]/[`fake_quant_into`] are the
//! scalar *reference* quantizer (golden-pinned); [`kernel`] puts the hot
//! path behind the [`QuantKernel`] trait with a tiled multi-threaded
//! implementation the bulk callers use; [`packed`] stores quantized
//! tensors on real bit-packed bytes; [`error`] computes the per-block /
//! per-tensor MSE statistics behind Figs. 2, 3, 6, 7, 9; [`matmul`]
//! provides the quantized-GEMM semantics used by CPU-side checks;
//! [`gemm`] multiplies packed operands natively in the code domain
//! (decode LUTs + per-block-pair scale fusion), bit-identical to the
//! decode-then-multiply reference but without ever materializing the
//! dequantized tensors; [`opcache`] is the shared prepacked
//! weight-operand cache behind both [`matmul::quantized_matmul`] and
//! the serving stack ([`crate::serve`]).

pub mod error;
pub mod gemm;
pub mod kernel;
pub mod matmul;
pub mod opcache;
pub mod packed;
pub mod rotate;
pub mod shard;

pub use gemm::{packed_matmul, GemmOperand, PackedGemm};
pub use kernel::{default_kernel, ChunkedKernel, QuantKernel, ScalarKernel};
pub use opcache::{operand_cache, CacheStats, OperandCache};
pub use packed::PackedMxTensor;
pub use shard::{shard_ranges, ShardedOperand};

use crate::formats::{ElemFormat, MiniFloat};

/// A complete microscaling quantization configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantScheme {
    /// Element format the block values are cast to.
    pub elem: ElemFormat,
    /// Scale format the per-block scale is cast to.
    pub scale: MiniFloat,
    /// Elements sharing one scale (the paper's N).
    pub block_size: usize,
    /// eq. 11 per-tensor pre-scaling (the paper's "-S" variants).
    pub per_tensor: bool,
}

impl QuantScheme {
    /// Scheme with per-tensor scaling off (the common case).
    pub fn new(elem: ElemFormat, scale: MiniFloat, block_size: usize) -> Self {
        QuantScheme { elem, scale, block_size, per_tensor: false }
    }

    /// Builder-style toggle for eq. 11 per-tensor pre-scaling.
    pub fn with_per_tensor(mut self, on: bool) -> Self {
        self.per_tensor = on;
        self
    }

    /// Short id like `fp4_e2m1/ue4m3-S/bs8` (cache keys, reports, CLI).
    ///
    /// Naming convention: `<elem name>/<scale name>[-S]/bs<N>` where the
    /// element and scale names are the stable
    /// [`ElemFormat::name`]/[`MiniFloat::name`] strings, `-S` marks the
    /// per-tensor ("scaled") variant, and `N` is the block size. Ids are
    /// embedded in result-cache keys, so changing this format
    /// invalidates `results/cache.json`.
    pub fn id(&self) -> String {
        format!(
            "{}/{}{}/bs{}",
            self.elem.name(),
            self.scale.name,
            if self.per_tensor { "-S" } else { "" },
            self.block_size
        )
    }

    /// eq. 11: s_T = (max(elem) * max(scale)) / absmax(T).
    pub fn per_tensor_factor(&self, absmax: f32) -> f32 {
        if !self.per_tensor || !(absmax > 0.0) {
            return 1.0;
        }
        self.elem.max_val() * self.scale.max_val / absmax
    }

    /// Storage cost in bytes/element: 4-bit elems + scale bits shared by N
    /// (Sec. 3.1: 1/2 + 2/N bytes for 16-bit scales).
    pub fn bytes_per_element(&self, elem_bits: u32, scale_bits: u32) -> f64 {
        elem_bits as f64 / 8.0
            + scale_bits as f64 / 8.0 / self.block_size as f64
    }
}

/// Quantize one block in place: `block` holds the raw values and is
/// replaced by dequantized values. Returns the quantized scale.
#[inline]
pub fn fake_quant_block(scheme: &QuantScheme, block: &mut [f32]) -> f32 {
    let mut absmax = 0.0f32;
    for &v in block.iter() {
        let a = v.abs();
        if a > absmax {
            absmax = a;
        }
    }
    let s = scheme.scale.cast(absmax / scheme.elem.max_val());
    if s > 0.0 {
        // NOTE: true IEEE division (not multiply-by-reciprocal) — required
        // for bit-exactness with ref.py: q = cast(x / s); xhat = s * q.
        match scheme.elem {
            ElemFormat::Fp(f) => {
                for v in block.iter_mut() {
                    *v = s * f.cast_signed(*v / s);
                }
            }
            ElemFormat::Int(m) => {
                for v in block.iter_mut() {
                    *v = s * crate::formats::cast_int_symmetric(*v / s, m);
                }
            }
        }
    } else {
        // App. F.3: whole block rounds to zero
        block.fill(0.0);
    }
    s
}

/// Quantize-dequantize a full tensor (blocks along the flat axis).
/// `x.len()` must be a multiple of the block size.
///
/// This is the scalar *reference* path, pinned bit-for-bit to the python
/// oracle by the golden tests; bulk callers go through
/// [`default_kernel`] instead, which is bit-identical but tiled and
/// multi-threaded (see [`kernel`]).
pub fn fake_quant(scheme: &QuantScheme, x: &[f32]) -> Vec<f32> {
    let mut out = x.to_vec();
    fake_quant_into(scheme, &mut out);
    out
}

/// In-place variant of [`fake_quant`]; returns the per-block scales.
pub fn fake_quant_into(scheme: &QuantScheme, x: &mut [f32]) -> Vec<f32> {
    assert!(
        x.len() % scheme.block_size == 0,
        "len {} not divisible by block size {}",
        x.len(),
        scheme.block_size
    );
    let s_t = if scheme.per_tensor {
        let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        scheme.per_tensor_factor(absmax)
    } else {
        1.0
    };
    if s_t != 1.0 {
        for v in x.iter_mut() {
            *v *= s_t;
        }
    }
    let mut scales = Vec::with_capacity(x.len() / scheme.block_size);
    for block in x.chunks_mut(scheme.block_size) {
        scales.push(fake_quant_block(scheme, block));
    }
    if s_t != 1.0 {
        for v in x.iter_mut() {
            *v /= s_t;
        }
    }
    scales
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{BF16_SCALE, UE4M3, UE5M3};

    fn mse(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            / a.len() as f64
    }

    #[test]
    fn zero_tensor_is_fixed_point() {
        let s = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let x = vec![0.0f32; 32];
        assert_eq!(fake_quant(&s, &x), x);
    }

    #[test]
    fn narrow_block_collapses_under_ue4m3_not_ue5m3() {
        // App. F.3 / Sec. 5.2: absmax/6 below s_min/2 rounds the whole
        // block to zero under UE4M3; UE5M3's extended range represents it.
        let x = vec![6.0 * 2.0f32.powi(-10) * 0.99; 8];
        let s4 = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let s5 = QuantScheme::new(ElemFormat::FP4, UE5M3, 8);
        assert!(fake_quant(&s4, &x).iter().all(|&v| v == 0.0));
        assert!(fake_quant(&s5, &x).iter().any(|&v| v != 0.0));
    }

    #[test]
    fn per_tensor_scaling_rescues_narrow_tensor() {
        let mut rng = Pcg64::new(0);
        let x = rng.normal_vec_f32(512, 1e-3);
        let plain = QuantScheme::new(ElemFormat::FP4, UE4M3, 8);
        let scaled = plain.with_per_tensor(true);
        assert!(
            mse(&fake_quant(&scaled, &x), &x) < mse(&fake_quant(&plain, &x), &x)
        );
    }

    #[test]
    fn ue5m3_close_to_per_tensor_on_narrow() {
        let mut rng = Pcg64::new(1);
        let x = rng.normal_vec_f32(4096, 5e-3);
        let m_s = mse(
            &fake_quant(
                &QuantScheme::new(ElemFormat::FP4, UE4M3, 8)
                    .with_per_tensor(true),
                &x,
            ),
            &x,
        );
        let m_5 = mse(
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, UE5M3, 8), &x),
            &x,
        );
        assert!(m_5 <= m_s * 1.1, "ue5m3 {m_5} vs ue4m3-S {m_s}");
    }

    #[test]
    fn bf16_scales_monotone_in_block_size() {
        // Fig. 2(c): with (quasi-)unquantized scales, smaller blocks are
        // never worse on aggregate.
        let mut rng = Pcg64::new(2);
        let x = rng.normal_vec_f32(1 << 14, 0.02);
        let m8 = mse(
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 8), &x),
            &x,
        );
        let m16 = mse(
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, BF16_SCALE, 16), &x),
            &x,
        );
        assert!(m8 < m16, "bs8 {m8} >= bs16 {m16}");
    }

    #[test]
    fn crossover_under_quantized_scales() {
        // Sec. 3.2 headline: at σ well below 2e-2, bs8 error EXCEEDS bs16
        // under UE4M3 scales — the anomaly this paper is about.
        let mut rng = Pcg64::new(3);
        let x = rng.normal_vec_f32(1 << 15, 4e-3);
        let m8 = mse(
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, UE4M3, 8), &x),
            &x,
        );
        let m16 = mse(
            &fake_quant(&QuantScheme::new(ElemFormat::FP4, UE4M3, 16), &x),
            &x,
        );
        assert!(m8 > m16, "expected inversion: bs8 {m8} <= bs16 {m16}");
    }

    #[test]
    fn storage_formula_matches_paper() {
        // Sec. 3.1: N 4-bit elements + 16-bit scale = 1/2 + 2/N bytes/elem
        for n in [8usize, 16, 32, 256] {
            let s = QuantScheme::new(ElemFormat::FP4, BF16_SCALE, n);
            assert!(
                (s.bytes_per_element(4, 16) - (0.5 + 2.0 / n as f64)).abs()
                    < 1e-12
            );
        }
    }

    #[test]
    fn fake_quant_is_odd() {
        // FQ(-x) == -FQ(x): absmax, scales, and the signed element cast
        // are all sign-symmetric
        crate::util::check::property("fake_quant odd", 40, |g| {
            let bs = *g.pick(&[4usize, 8, 16]);
            let sigma = g.log_uniform(1e-4, 1.0);
            let x = g.normal_vec_f32(bs * 4, sigma);
            let neg: Vec<f32> = x.iter().map(|v| -v).collect();
            let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, bs);
            let a = fake_quant(&scheme, &x);
            let b = fake_quant(&scheme, &neg);
            for (u, v) in a.iter().zip(&b) {
                if *u == 0.0 && *v == 0.0 {
                    continue; // collapsed blocks fill +0.0 for both signs
                }
                assert_eq!(u.to_bits(), (-v).to_bits());
            }
        });
    }

    #[test]
    fn per_tensor_factor_saturates_range() {
        // eq. 11: after scaling, the tensor absmax maps exactly onto
        // max(elem) * max(scale)
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 8)
            .with_per_tensor(true);
        for absmax in [1e-4f32, 0.02, 3.0] {
            let f = scheme.per_tensor_factor(absmax);
            assert!((absmax * f - 6.0 * 448.0).abs() / (6.0 * 448.0) < 1e-6);
        }
        assert_eq!(scheme.per_tensor_factor(0.0), 1.0);
    }

    #[test]
    fn ue5m3_never_worse_than_ue4m3_per_tensor() {
        // grid nesting lifts to whole-tensor MSE at equal block size
        crate::util::check::property("ue5m3 <= ue4m3 mse", 25, |g| {
            let bs = *g.pick(&[8usize, 16]);
            let sigma = g.log_uniform(1e-4, 0.5);
            let x = g.normal_vec_f32(512, sigma);
            let m43 = {
                let s = QuantScheme::new(ElemFormat::FP4, UE4M3, bs);
                let q = fake_quant(&s, &x);
                crate::stats::mse_f32(&x, &q)
            };
            let m53 = {
                let s = QuantScheme::new(ElemFormat::FP4, UE5M3, bs);
                let q = fake_quant(&s, &x);
                crate::stats::mse_f32(&x, &q)
            };
            // scale-grid nesting does NOT strictly dominate post-division
            // errors element-by-element, but aggregate MSE should never
            // regress beyond noise
            assert!(m53 <= m43 * 1.05 + 1e-20, "{m53} vs {m43}");
        });
    }

    #[test]
    fn property_block_quant_bounds() {
        // Per-block bound: |xhat| <= block absmax + one element quantum
        // (q <= y + ½·elem-quantum, and elem quanta never exceed 1·s for
        // FP4/INT4). In the subnormal-scale regime the scale itself can
        // round up by ~2x (the very pathology the paper studies), so a
        // purely relative bound does NOT hold — the additive one does.
        crate::util::check::property("block bounds", 60, |g| {
            let bs = *g.pick(&[2usize, 4, 8, 16, 32]);
            let sigma = g.log_uniform(1e-5, 10.0);
            let mut x = g.normal_vec_f32(bs * 8, sigma);
            let scheme = QuantScheme::new(
                if g.bool() { ElemFormat::FP4 } else { ElemFormat::INT4 },
                *g.pick(&[UE4M3, UE5M3]),
                bs,
            );
            let orig = x.clone();
            let scales = fake_quant_into(&scheme, &mut x);
            for (b, s) in scales.iter().enumerate() {
                let blk = b * bs..(b + 1) * bs;
                let absmax =
                    orig[blk.clone()].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let qmax =
                    x[blk].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                assert!(
                    qmax <= absmax + s + 1e-30,
                    "{}: qmax {qmax} absmax {absmax} s {s}",
                    scheme.id()
                );
            }
        });
    }
}
