//! Quantization execution kernels: the hot path behind every sweep.
//!
//! [`QuantKernel`] abstracts *how* a tensor is fake-quantized without
//! changing *what* is computed — every implementation must be bit-exact
//! with the scalar reference path ([`super::fake_quant_into`], which is
//! itself pinned to the python oracle by `rust/tests/golden.rs`). Two
//! implementations ship:
//!
//! * [`ScalarKernel`] — the reference: one block at a time, exactly the
//!   seed implementation.
//! * [`ChunkedKernel`] — the production path: processes row-major tiles
//!   sized for L1/L2 residency, computes all block absmaxes + encoded
//!   scales of a tile in one fused pass (unrolled 4-way max reduction),
//!   then dequantizes with the element-format dispatch hoisted out of
//!   the inner loop, and splits large tensors across scoped worker
//!   threads at block boundaries ([`crate::util::par`]).
//!
//! Bit-exactness argument for the chunked path: absmax is an
//! associative/commutative max over `|x|` (NaN-ignoring in both
//! orderings), the per-block scale cast depends only on that absmax, and
//! element casts are pointwise — so tiling, fusing and threading cannot
//! change any bit. The `chunked_matches_scalar_bitwise` property test
//! enforces this over random (σ, block size, format) draws.
//!
//! [`default_kernel`] is what the bulk call sites (GEMM, error sweeps,
//! experiment generators) use; set `MICROSCALE_KERNEL=scalar` to force
//! the reference path when bisecting a discrepancy.

use std::sync::OnceLock;

use crate::formats::ElemFormat;
use crate::util::par;

use super::QuantScheme;

/// A fake-quantization executor; all implementations are bit-identical.
pub trait QuantKernel: Sync {
    /// Implementation name (reports, benches, env selection).
    fn name(&self) -> &'static str;

    /// Quantize-dequantize `x` in place (blocks along the flat axis);
    /// returns the per-block quantized scales. `x.len()` must be a
    /// multiple of the scheme's block size.
    fn fake_quant_into(&self, scheme: &QuantScheme, x: &mut [f32]) -> Vec<f32>;

    /// Out-of-place convenience: returns the dequantized tensor.
    fn fake_quant(&self, scheme: &QuantScheme, x: &[f32]) -> Vec<f32> {
        let mut out = x.to_vec();
        self.fake_quant_into(scheme, &mut out);
        out
    }
}

/// The block-at-a-time reference implementation (golden-pinned).
#[derive(Debug, Clone, Copy, Default)]
pub struct ScalarKernel;

impl QuantKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn fake_quant_into(&self, scheme: &QuantScheme, x: &mut [f32]) -> Vec<f32> {
        super::fake_quant_into(scheme, x)
    }
}

/// Tiled, fused, optionally multi-threaded implementation.
#[derive(Debug, Clone, Copy)]
pub struct ChunkedKernel {
    /// Tile size in elements (rounded down to whole blocks); sized so a
    /// tile plus its scales stay L1/L2-resident.
    pub tile: usize,
    /// Worker-thread cap for large tensors (1 = stay on the caller).
    pub threads: usize,
    /// Minimum tensor size (elements) before threads are used; below
    /// this the spawn cost dominates the quantization itself.
    pub par_threshold: usize,
}

impl ChunkedKernel {
    /// Production configuration: 16 Ki-element tiles (64 KiB of f32),
    /// one worker per logical CPU, threading from 64 Ki elements up.
    pub fn auto() -> ChunkedKernel {
        ChunkedKernel {
            tile: 16 * 1024,
            threads: par::max_threads(),
            par_threshold: 64 * 1024,
        }
    }

    /// Single-threaded variant (tiling + fusion only) — what the benches
    /// compare against [`ScalarKernel`] to isolate the layout win from
    /// the threading win.
    pub fn serial() -> ChunkedKernel {
        ChunkedKernel { threads: 1, ..ChunkedKernel::auto() }
    }
}

impl Default for ChunkedKernel {
    fn default() -> Self {
        ChunkedKernel::auto()
    }
}

/// Effective worker count for a bulk data-parallel operation of `work`
/// units: honors the caller's thread cap, stays serial below the spawn
/// break-even threshold, and stays serial on coordinator-pool worker
/// threads (the sweep is already running one job per core; nesting
/// another fan-out would oversubscribe to ncpus² threads). Shared by
/// [`ChunkedKernel`] and the packed GEMM engine
/// ([`crate::quant::gemm::PackedGemm`]).
pub(crate) fn plan_threads(work: usize, threads: usize, par_threshold: usize) -> usize {
    if work >= par_threshold && !par::on_worker_thread() {
        threads.max(1)
    } else {
        1
    }
}

impl QuantKernel for ChunkedKernel {
    fn name(&self) -> &'static str {
        "chunked"
    }

    fn fake_quant_into(&self, scheme: &QuantScheme, x: &mut [f32]) -> Vec<f32> {
        let bs = scheme.block_size;
        assert!(
            bs > 0 && x.len() % bs == 0,
            "len {} not divisible by block size {}",
            x.len(),
            bs
        );
        let n_blocks = x.len() / bs;
        let threads = plan_threads(x.len(), self.threads, self.par_threshold);

        // eq. 11 per-tensor pre-scaling (same op order as the reference)
        let s_t = if scheme.per_tensor {
            let absmax = parallel_absmax(x, threads);
            scheme.per_tensor_factor(absmax)
        } else {
            1.0
        };
        if s_t != 1.0 {
            par::par_chunks_mut(x, bs, threads, |_, chunk| {
                for v in chunk.iter_mut() {
                    *v *= s_t;
                }
            });
        }

        let mut scales = vec![0.0f32; n_blocks];
        if threads <= 1 {
            quantize_range(scheme, self.tile, x, &mut scales);
        } else {
            // split both the tensor and its scale row at block boundaries
            let per_blocks = (n_blocks + threads - 1) / threads;
            let tile = self.tile;
            std::thread::scope(|scope| {
                // reborrow so `x`/`scales` stay usable after the scope
                let mut xs: &mut [f32] = &mut *x;
                let mut ss: &mut [f32] = &mut scales[..];
                while !ss.is_empty() {
                    let nb = per_blocks.min(ss.len());
                    let (xh, xt) = xs.split_at_mut(nb * bs);
                    let (sh, st) = ss.split_at_mut(nb);
                    scope.spawn(move || quantize_range(scheme, tile, xh, sh));
                    xs = xt;
                    ss = st;
                }
            });
        }

        if s_t != 1.0 {
            par::par_chunks_mut(x, bs, threads, |_, chunk| {
                for v in chunk.iter_mut() {
                    *v /= s_t;
                }
            });
        }
        scales
    }
}

/// Tensor absmax, reduced per worker chunk then across chunks (same
/// value as the serial fold: max is associative, commutative, and
/// NaN-ignoring under `f32::max` and the `>` fold alike).
fn parallel_absmax(x: &[f32], threads: usize) -> f32 {
    if threads <= 1 {
        return x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    }
    let per = (x.len() + threads - 1) / threads;
    let partials = std::sync::Mutex::new(Vec::with_capacity(threads));
    std::thread::scope(|scope| {
        for chunk in x.chunks(per.max(1)) {
            let partials = &partials;
            scope.spawn(move || {
                let m = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                partials.lock().unwrap().push(m);
            });
        }
    });
    partials
        .into_inner()
        .unwrap()
        .into_iter()
        .fold(0.0f32, f32::max)
}

/// Quantize a contiguous run of whole blocks, tile by tile: pass 1 fuses
/// the absmax reduction and the scale encode for every block of the
/// tile; pass 2 dequantizes with the element dispatch hoisted.
fn quantize_range(
    scheme: &QuantScheme,
    tile: usize,
    x: &mut [f32],
    scales: &mut [f32],
) {
    let bs = scheme.block_size;
    let tile = (tile / bs).max(1) * bs;
    let c = scheme.elem.max_val(); // divisor C in s = Q(absmax / C)
    let mut done_blocks = 0usize;
    for chunk in x.chunks_mut(tile) {
        let nb = chunk.len() / bs;
        let srow = &mut scales[done_blocks..done_blocks + nb];
        // pass 1: fused absmax + scale encode
        for (b, s) in srow.iter_mut().enumerate() {
            let absmax = block_absmax(&chunk[b * bs..(b + 1) * bs]);
            *s = scheme.scale.cast(absmax / c);
        }
        // pass 2: dequantize (element dispatch hoisted off the hot loop)
        match scheme.elem {
            ElemFormat::Fp(f) => {
                for (b, &s) in srow.iter().enumerate() {
                    let blk = &mut chunk[b * bs..(b + 1) * bs];
                    if s > 0.0 {
                        for v in blk.iter_mut() {
                            *v = s * f.cast_signed(*v / s);
                        }
                    } else {
                        blk.fill(0.0); // App. F.3 whole-block collapse
                    }
                }
            }
            ElemFormat::Int(m) => {
                for (b, &s) in srow.iter().enumerate() {
                    let blk = &mut chunk[b * bs..(b + 1) * bs];
                    if s > 0.0 {
                        for v in blk.iter_mut() {
                            *v = s * crate::formats::cast_int_symmetric(*v / s, m);
                        }
                    } else {
                        blk.fill(0.0);
                    }
                }
            }
        }
        done_blocks += nb;
    }
}

/// 4-accumulator unrolled |x| max over one block (bit-identical to the
/// serial fold; see module docs).
#[inline]
fn block_absmax(blk: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut it = blk.chunks_exact(4);
    for q in &mut it {
        acc[0] = acc[0].max(q[0].abs());
        acc[1] = acc[1].max(q[1].abs());
        acc[2] = acc[2].max(q[2].abs());
        acc[3] = acc[3].max(q[3].abs());
    }
    let mut m = acc[0].max(acc[1]).max(acc[2].max(acc[3]));
    for &v in it.remainder() {
        m = m.max(v.abs());
    }
    m
}

/// The kernel bulk call sites use: [`ChunkedKernel::auto`], unless the
/// `MICROSCALE_KERNEL=scalar` environment variable forces the reference.
/// The env is **latched**: read once per process on the first call and
/// cached in a `OnceLock` (this runs on dispatch hot paths), so set it
/// before the first quantization; later changes are ignored.
pub fn default_kernel() -> &'static dyn QuantKernel {
    static SCALAR: ScalarKernel = ScalarKernel;
    static CHUNKED: OnceLock<ChunkedKernel> = OnceLock::new();
    static CHOICE: OnceLock<bool> = OnceLock::new(); // true = scalar
    let scalar = *CHOICE.get_or_init(|| {
        matches!(
            std::env::var("MICROSCALE_KERNEL").as_deref(),
            Ok("scalar")
        )
    });
    if scalar {
        &SCALAR
    } else {
        CHUNKED.get_or_init(ChunkedKernel::auto)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{E8M0, FP6_E3M2, UE4M3, UE5M3};

    #[test]
    fn chunked_matches_scalar_bitwise() {
        crate::util::check::property("chunked == scalar", 60, |g| {
            let bs = *g.pick(&[2usize, 4, 8, 16, 32, 64]);
            let blocks = g.usize_in(1, 40);
            let sigma = g.log_uniform(1e-5, 10.0);
            let x = g.normal_vec_f32(bs * blocks, sigma);
            let scheme = QuantScheme::new(
                *g.pick(&[
                    ElemFormat::FP4,
                    ElemFormat::FP8,
                    ElemFormat::Fp(FP6_E3M2),
                    ElemFormat::INT4,
                ]),
                *g.pick(&[UE4M3, UE5M3, E8M0]),
                bs,
            )
            .with_per_tensor(g.bool());
            // tiny tile + forced threads to exercise every seam
            let chunked = ChunkedKernel {
                tile: bs * g.usize_in(1, 3),
                threads: g.usize_in(1, 4),
                par_threshold: 0,
            };
            let mut a = x.clone();
            let sa = ScalarKernel.fake_quant_into(&scheme, &mut a);
            let mut b = x.clone();
            let sb = chunked.fake_quant_into(&scheme, &mut b);
            assert_eq!(sa.len(), sb.len());
            for (u, v) in sa.iter().zip(&sb) {
                assert_eq!(u.to_bits(), v.to_bits(), "scale {}", scheme.id());
            }
            for (i, (u, v)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "{} elem {i}: {u} vs {v}",
                    scheme.id()
                );
            }
        });
    }

    #[test]
    fn default_kernel_matches_reference_on_a_sweep() {
        let mut rng = crate::dist::Pcg64::new(0xC0DE);
        let scheme = QuantScheme::new(ElemFormat::FP4, UE4M3, 16);
        let x = rng.normal_vec_f32(1 << 14, 4e-3);
        let a = ScalarKernel.fake_quant(&scheme, &x);
        let b = default_kernel().fake_quant(&scheme, &x);
        assert!(a.iter().zip(&b).all(|(u, v)| u.to_bits() == v.to_bits()));
    }

    #[test]
    fn block_absmax_matches_fold() {
        crate::util::check::property("absmax unroll", 40, |g| {
            let n = g.usize_in(1, 67);
            let x = g.normal_vec_f32(n, g.log_uniform(1e-6, 1e3));
            let want = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            assert_eq!(block_absmax(&x).to_bits(), want.to_bits());
        });
    }

    #[test]
    fn serial_and_auto_configs_agree() {
        let mut rng = crate::dist::Pcg64::new(7);
        let x = rng.normal_vec_f32(1 << 16, 0.02);
        let scheme =
            QuantScheme::new(ElemFormat::FP4, UE5M3, 8).with_per_tensor(true);
        let a = ChunkedKernel::serial().fake_quant(&scheme, &x);
        let b = ChunkedKernel::auto().fake_quant(&scheme, &x);
        assert!(a.iter().zip(&b).all(|(u, v)| u.to_bits() == v.to_bits()));
    }
}
