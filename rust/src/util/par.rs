//! Scoped-thread data parallelism (rayon is not vendored in the offline
//! build, so this module provides the two shapes the crate needs on top
//! of `std::thread::scope`).
//!
//! Design notes:
//!
//! * [`par_map`] mirrors `rayon`'s `par_iter().map().collect()` for owned
//!   inputs: order-preserving, work-stealing via a shared LIFO queue, and
//!   it degrades to a plain serial map for 1 thread / tiny inputs, so
//!   callers never pay thread spawn cost on small sweeps.
//! * [`par_chunks_mut`] mirrors `par_chunks_mut`: disjoint `&mut` chunks
//!   aligned to a caller-chosen boundary (quantization block size), which
//!   is what the [`crate::quant::kernel::ChunkedKernel`] builds on.
//!
//! Panics in worker closures propagate to the caller (std scoped threads
//! re-raise on scope exit), matching rayon semantics.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

/// Number of worker threads to use by default (logical CPUs).
pub fn max_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

thread_local! {
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// RAII marker: flags the current thread as a pool worker for the
/// guard's lifetime and restores the previous flag on drop. Inner
/// data-parallel helpers ([`crate::quant::kernel::ChunkedKernel`], the
/// packed GEMM) check [`on_worker_thread`] and stay serial, so N pool
/// workers don't each fan out N kernel threads (ncpus²
/// oversubscription); drop-restore means a thread that only
/// *sometimes* hosts nested data-parallel work (a serve-engine worker,
/// a test harness thread) unwinds cleanly. Shared by the coordinator
/// pool ([`crate::coordinator::pool`]) and the serve engine
/// ([`crate::serve::engine`]).
pub struct WorkerGuard {
    prev: bool,
}

impl WorkerGuard {
    pub fn enter() -> WorkerGuard {
        let prev = IN_POOL_WORKER.with(|f| f.replace(true));
        WorkerGuard { prev }
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_WORKER.with(|f| f.set(prev));
    }
}

/// Whether this thread is a marked pool worker (see [`WorkerGuard`]).
pub fn on_worker_thread() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// Order-preserving parallel map over an owned vector.
///
/// `threads` is a cap, not a demand: the effective worker count is
/// `min(threads, items.len())`, and `threads <= 1` (or a 0/1-element
/// input) runs serially with zero overhead.
pub fn par_map<I, O, F>(items: Vec<I>, threads: usize, f: F) -> Vec<O>
where
    I: Send,
    O: Send,
    F: Fn(I) -> O + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Index-tagged LIFO queue; workers pop until empty.
    let queue: Mutex<Vec<(usize, I)>> =
        Mutex::new(items.into_iter().enumerate().rev().collect());
    let out: Mutex<Vec<Option<O>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                match item {
                    Some((i, x)) => {
                        let y = f(x);
                        out.lock().unwrap()[i] = Some(y);
                    }
                    None => break,
                }
            });
        }
    });
    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker filled every slot"))
        .collect()
}

/// Run `f(offset, chunk)` over disjoint mutable chunks of `data`, split
/// at multiples of `align` elements, using up to `threads` workers.
///
/// The trailing `data.len() % align` remainder (if any) is attached to
/// the last chunk. `threads <= 1` processes the whole slice in one call.
pub fn par_chunks_mut<T, F>(data: &mut [T], align: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    let align = align.max(1);
    let units = n / align;
    let threads = threads.max(1).min(units.max(1));
    if threads <= 1 || units <= 1 {
        if n > 0 {
            f(0, data);
        }
        return;
    }
    let per = (units + threads - 1) / threads * align;
    let fref = &f;
    std::thread::scope(|scope| {
        let mut rest = data;
        let mut offset = 0usize;
        while !rest.is_empty() {
            let take = if rest.len() <= per + (n - units * align) {
                rest.len() // last chunk absorbs the unaligned remainder
            } else {
                per
            };
            let (head, tail) = rest.split_at_mut(take);
            let off = offset;
            scope.spawn(move || fref(off, head));
            offset += take;
            rest = tail;
        }
    });
}

type ShardJob = Box<dyn FnOnce() + Send + 'static>;

/// Persistent worker pool for tensor-parallel shard fan-out.
///
/// Unlike [`par_map`] (scoped threads, spawned per call), a
/// `ShardPool` keeps its workers alive for the lifetime of a sharded
/// model, so the per-token decode fast path (m == 1, microseconds per
/// linear) pays a channel send instead of a thread spawn. Every worker
/// holds a [`WorkerGuard`] for its whole life, and [`ShardPool::run`]
/// executes job 0 inline on the caller under a guard of its own, so
/// inner kernels ([`crate::quant::gemm::PackedGemm`],
/// `ChunkedKernel`) see [`on_worker_thread`] and stay serial on every
/// shard — the thread count of a sharded matmul is exactly
/// `1 + workers`, never `shards × ncpus`.
///
/// Jobs are dispatched round-robin (one queue per worker); `run` is
/// order-preserving and a pool with zero workers (or a single job)
/// degrades to an inline serial loop. A panicking job takes its worker
/// down and `run` re-panics on the caller, matching the
/// scoped-thread semantics of [`par_map`].
pub struct ShardPool {
    txs: Vec<mpsc::Sender<ShardJob>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    next: AtomicUsize,
}

impl ShardPool {
    /// Spawn `workers` persistent marked worker threads. `workers` is
    /// the *extra* parallelism: a model sharded N ways wants
    /// `ShardPool::new(N - 1)` because the caller runs one shard
    /// itself.
    pub fn new(workers: usize) -> ShardPool {
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = mpsc::channel::<ShardJob>();
            let handle = std::thread::Builder::new()
                .name(format!("shard-worker-{i}"))
                .spawn(move || {
                    let _guard = WorkerGuard::enter();
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                })
                .expect("spawn shard worker");
            txs.push(tx);
            handles.push(handle);
        }
        ShardPool { txs, handles, next: AtomicUsize::new(0) }
    }

    /// Number of pool worker threads (callers add one for themselves).
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Run every job, returning results in job order. Job 0 executes
    /// inline on the calling thread (under a [`WorkerGuard`]); the
    /// rest are dispatched round-robin to the pool workers.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        if self.txs.is_empty() || n == 1 {
            let _g = WorkerGuard::enter();
            return jobs.into_iter().map(|job| job()).collect();
        }
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        let mut jobs = jobs.into_iter();
        let first = jobs.next().expect("n >= 1");
        for (off, job) in jobs.enumerate() {
            let txc = tx.clone();
            let slot =
                self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
            let boxed: ShardJob = Box::new(move || {
                let _ = txc.send((off + 1, job()));
            });
            self.txs[slot].send(boxed).expect("shard worker alive");
        }
        // drop the caller's sender so a worker that dies mid-run (job
        // panic) surfaces as a channel disconnect below instead of a
        // deadlocked recv
        drop(tx);
        {
            let _g = WorkerGuard::enter();
            out[0] = Some(first());
        }
        for _ in 1..n {
            let (i, v) = rx.recv().expect("shard worker completed its job");
            out[i] = Some(v);
        }
        out.into_iter()
            .map(|o| o.expect("every job reported a result"))
            .collect()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        self.txs.clear(); // hang up: workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..257).collect();
        let serial: Vec<usize> = items.iter().map(|i| i * i).collect();
        for threads in [1, 2, 3, 8] {
            let got = par_map(items.clone(), threads, |i| i * i);
            assert_eq!(got, serial, "threads={threads}");
        }
    }

    #[test]
    fn par_map_empty_and_single() {
        let e: Vec<u32> = par_map(Vec::<u32>::new(), 4, |x| x + 1);
        assert!(e.is_empty());
        assert_eq!(par_map(vec![41u32], 4, |x| x + 1), vec![42]);
    }

    #[test]
    fn par_chunks_cover_exactly_once() {
        // mark every element with its visiting chunk's offset parity
        let n = 8 * 13 + 5; // unaligned remainder
        for threads in [1, 2, 4, 7] {
            let mut data = vec![0u32; n];
            par_chunks_mut(&mut data, 8, threads, |off, chunk| {
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v += (off + i) as u32 + 1;
                }
            });
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u32 + 1, "i={i} threads={threads}");
            }
        }
    }

    #[test]
    fn worker_guard_restores_previous_flag() {
        assert!(!on_worker_thread());
        {
            let _g = WorkerGuard::enter();
            assert!(on_worker_thread());
            {
                let _g2 = WorkerGuard::enter(); // nesting is idempotent
                assert!(on_worker_thread());
            }
            // inner drop restores the (still-marked) outer state
            assert!(on_worker_thread());
        }
        assert!(!on_worker_thread());
    }

    #[test]
    fn worker_thread_flag_is_per_thread() {
        assert!(!on_worker_thread());
        std::thread::scope(|s| {
            s.spawn(|| {
                let _g = WorkerGuard::enter();
                assert!(on_worker_thread());
            });
        });
        // marking another thread does not leak into this one
        assert!(!on_worker_thread());
    }

    #[test]
    fn shard_pool_is_order_preserving_and_reusable() {
        let pool = ShardPool::new(3);
        assert_eq!(pool.workers(), 3);
        for round in 0..4usize {
            let jobs: Vec<_> = (0..7usize)
                .map(|i| move || i * i + round)
                .collect();
            let got = pool.run(jobs);
            let want: Vec<usize> = (0..7).map(|i| i * i + round).collect();
            assert_eq!(got, want, "round={round}");
        }
    }

    #[test]
    fn shard_pool_marks_every_job_as_worker() {
        // Both the inline job-0 slot and the pool workers must report
        // on_worker_thread() == true, or inner kernels would fan out.
        for workers in [0usize, 1, 4] {
            let pool = ShardPool::new(workers);
            assert!(!on_worker_thread());
            let jobs: Vec<_> =
                (0..6).map(|_| on_worker_thread as fn() -> bool).collect();
            let marked = pool.run(jobs);
            assert!(
                marked.iter().all(|&m| m),
                "workers={workers} marked={marked:?}"
            );
            // the inline guard is released after the call
            assert!(!on_worker_thread());
        }
    }

    #[test]
    fn shard_pool_degenerate_shapes() {
        let pool = ShardPool::new(2);
        let empty: Vec<u32> = pool.run(Vec::<fn() -> u32>::new());
        assert!(empty.is_empty());
        assert_eq!(pool.run(vec![|| 7u32]), vec![7]);
        // more jobs than workers queue and still complete in order
        let jobs: Vec<_> = (0..23u32).map(|i| move || i).collect();
        assert_eq!(pool.run(jobs), (0..23).collect::<Vec<u32>>());
    }

    #[test]
    fn par_chunks_offsets_are_aligned() {
        let mut data = vec![0u8; 64];
        let offsets = Mutex::new(Vec::new());
        par_chunks_mut(&mut data, 16, 4, |off, chunk| {
            assert_eq!(off % 16, 0);
            assert_eq!(chunk.len() % 16, 0);
            offsets.lock().unwrap().push(off);
        });
        let mut offs = offsets.into_inner().unwrap();
        offs.sort();
        assert_eq!(offs, vec![0, 16, 32, 48]);
    }
}
