//! In-house property-testing helper (proptest is unavailable offline).
//!
//! [`Gen`] is a deterministic seeded generator; [`property`] runs a check
//! over many generated cases and reports the failing seed so cases can be
//! replayed exactly.

use crate::dist::rng::Pcg64;

/// Deterministic case generator handed to property bodies.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::new(seed) }
    }

    pub fn u64(&mut self, bound: u64) -> u64 {
        self.rng.next_u64() % bound.max(1)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.u64((hi - lo + 1) as u64) as usize)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.uniform()
    }

    /// Log-uniform positive value in [lo, hi].
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (self.f64_in(lo.ln(), hi.ln())).exp()
    }

    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.rng.standard_normal()
    }

    pub fn normal_vec_f32(&mut self, n: usize, sigma: f64) -> Vec<f32> {
        (0..n).map(|_| self.normal(0.0, sigma) as f32).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.u64(items.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.u64(2) == 1
    }
}

/// Run `body` over `cases` generated cases; panic with the failing seed.
pub fn property<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut body: F) {
    for case in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64
            .wrapping_mul(case as u64 + 1)
            .wrapping_add(0xD1B54A32D192ED03);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let mut g = Gen::new(seed);
                body(&mut g);
            },
        ));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case} (replay seed {seed:#x})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(1000), b.u64(1000));
            assert_eq!(a.f64_in(-1.0, 1.0), b.f64_in(-1.0, 1.0));
        }
    }

    #[test]
    fn property_runs_all_cases() {
        let mut n = 0;
        property("count", 25, |_| n += 1);
        assert_eq!(n, 25);
    }
}
