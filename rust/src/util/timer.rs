//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`bench`]: warm up, run timed batches until a
//! minimum wall budget is reached, and report min/median/mean — the median
//! is what EXPERIMENTS.md §Perf quotes.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f`, returning stats over timed batches (~`budget` total).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration: target ~20 batches within the budget
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let per_batch = budget.as_nanos() as u64 / 20;
    let batch_iters = (per_batch / once.as_nanos().max(1) as u64).clamp(1, 1 << 20);

    let mut samples = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0u64;
    while start.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        for _ in 0..batch_iters {
            f();
        }
        let ns = t.elapsed().as_nanos() as f64 / batch_iters as f64;
        samples.push(ns);
        total_iters += batch_iters;
        if samples.len() > 2000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        min_ns: min,
        median_ns: median,
        mean_ns: mean,
    };
    println!(
        "{:<48} median {:>12}  min {:>12}  mean {:>12}  ({} iters)",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.min_ns),
        fmt_ns(r.mean_ns),
        r.iters
    );
    r
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
