//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar we produce/consume: the AOT
//! `manifest.json`, the golden-vector files, coordinator result caches and
//! experiment outputs. Numbers are kept as f64 (sufficient: all our
//! payloads are f32-representable or small integers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (key {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers -> `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of numbers -> `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|v| v as f32).collect())
    }

    /// Array of numbers -> `Vec<usize>` (shapes etc.).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        Ok(self.as_f64_vec()?.into_iter().map(|v| v as usize).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building JSON to write out.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// [`obj`] with owned keys (dynamic labels, e.g. per-config bench maps).
pub fn obj_owned(pairs: Vec<(String, Json)>) -> Json {
    Json::Obj(pairs.into_iter().collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn f64s(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn write_escaped(out: &mut String, sv: &str) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut out = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                loop {
                    self.ws();
                    out.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(out));
                        }
                        c => bail!("bad array sep {:?}", c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut out = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    out.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(out));
                        }
                        c => bail!("bad object sep {:?}", c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // no surrogate-pair support needed for our data
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                        }
                        _ => bail!("bad escape"),
                    }
                }
                c => {
                    // collect the full utf-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(std::str::from_utf8(
                        &self.b[start..self.i],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number {txt:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let txt = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true},
                      "s": "he\"llo\nworld", "u": "é"}"#;
        let v = Json::parse(txt).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("d").unwrap().as_bool().unwrap(), true);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "he\"llo\nworld");
        assert_eq!(v.get("u").unwrap().as_str().unwrap(), "é");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"σ≈2·10⁻²\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "σ≈2·10⁻²");
    }
}
