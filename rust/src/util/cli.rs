//! Tiny CLI argument helper (clap is unavailable offline).
//!
//! Supports `command [subcommand] [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed() {
        let a = parse("figure 3c --out /tmp/x --fast --sigma=0.02 --n 64");
        assert_eq!(a.positional, vec!["figure", "3c"]);
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.has("fast"));
        assert_eq!(a.get_f64("sigma", 0.0).unwrap(), 0.02);
        assert_eq!(a.get_usize("n", 0).unwrap(), 64);
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
    }
}
