//! Runtime SIMD dispatch for the packed hot paths.
//!
//! Every vectorized kernel in the crate — the packed-GEMM inner loops
//! ([`crate::quant::gemm`]), the per-block absmax of the shared encode
//! pipeline ([`crate::quant::packed`]), and the KV page-codec row
//! decode ([`crate::serve::kvpool`]) — selects its instruction set
//! through this one module, so the whole process answers "which kernels
//! are we running?" with a single word ([`kernel_name`]).
//!
//! # Dispatch
//!
//! [`active`] picks the best [`SimdLevel`] the host supports, **once
//! per process** (latched in a `OnceLock`, like `MICROSCALE_KERNEL` and
//! `MICROSCALE_GEMM`): AVX2 on x86_64 when `is_x86_feature_detected!`
//! says so, NEON on aarch64 (baseline ISA there), scalar everywhere
//! else. `MICROSCALE_SIMD=scalar|avx2|neon|auto` overrides the choice
//! for bisection — the env is read at the *first* dispatch and latched,
//! so set it before the process starts, not mid-run. A forced level the
//! host cannot execute falls back to scalar with a `log` warning rather
//! than faulting.
//!
//! # Bit-exactness
//!
//! The vector kernels are **bit-identical** to the scalar reference by
//! construction, not by tolerance: they vectorize across *independent
//! outputs* (output columns in the GEMM, elements of a decoded row in
//! the codec) while keeping each output's own operation sequence —
//! operand values, rounding steps, accumulation order — exactly the
//! scalar kernel's. No FMA, no reassociation. DESIGN.md §13 states the
//! lane-group argument in full; `rust/tests/simd.rs` pins it
//! differentially across the format × block-size × shard grid.
//!
//! The primitives in this module (`*_with` variants) take an explicit
//! level so the differential suites can compare instruction sets inside
//! one process regardless of what [`active`] latched.

use std::sync::OnceLock;

/// An instruction-set level the dispatcher can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar loops — always available, the reference.
    Scalar,
    /// 256-bit AVX2 kernels (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON kernels (aarch64, where NEON is baseline).
    Neon,
}

impl SimdLevel {
    /// Stable lowercase kernel name — the vocabulary of
    /// `MICROSCALE_SIMD` and of the `simd` fields in the bench reports.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    /// Whether this host can actually execute the level's kernels.
    pub fn supported(self) -> bool {
        match self {
            SimdLevel::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            SimdLevel::Avx2 => false,
            SimdLevel::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// This level, or [`SimdLevel::Scalar`] when the host cannot run it
    /// — the guard every dispatch site applies before entering an
    /// `unsafe` vector kernel.
    pub fn clamped(self) -> SimdLevel {
        if self.supported() {
            self
        } else {
            SimdLevel::Scalar
        }
    }
}

static ACTIVE: OnceLock<SimdLevel> = OnceLock::new();

fn best_available() -> SimdLevel {
    if SimdLevel::Avx2.supported() {
        SimdLevel::Avx2
    } else if SimdLevel::Neon.supported() {
        SimdLevel::Neon
    } else {
        SimdLevel::Scalar
    }
}

fn detect() -> SimdLevel {
    let var = match std::env::var("MICROSCALE_SIMD") {
        Ok(v) => v,
        Err(_) => return best_available(),
    };
    match var.as_str() {
        "auto" => best_available(),
        "scalar" => SimdLevel::Scalar,
        "avx2" | "neon" => {
            let level = if var == "avx2" {
                SimdLevel::Avx2
            } else {
                SimdLevel::Neon
            };
            if level.supported() {
                level
            } else {
                log::warn!(
                    "MICROSCALE_SIMD={var} is not executable on this host; \
                     falling back to scalar kernels"
                );
                SimdLevel::Scalar
            }
        }
        other => {
            log::warn!(
                "unknown MICROSCALE_SIMD={other:?} (expected \
                 scalar|avx2|neon|auto); auto-detecting"
            );
            best_available()
        }
    }
}

/// The process-wide instruction-set level (see module docs). Latched on
/// first call; `MICROSCALE_SIMD` changes after that are ignored.
pub fn active() -> SimdLevel {
    *ACTIVE.get_or_init(detect)
}

/// [`active`]'s stable name — what the bench reports record per run.
pub fn kernel_name() -> &'static str {
    active().name()
}

// ---------------------------------------------------------------------
// Shared pointwise primitives.
//
// Each has a scalar body that *is* the semantics, and vector bodies
// that replay the same per-element operation sequence wider. The
// `*_with` form takes an explicit level (differential tests); the
// plain form dispatches on `active()`.
// ---------------------------------------------------------------------

/// The per-block absmax of the encode pipeline: `max |v · s_t|` with
/// NaN inputs ignored (a NaN never beats the running maximum — the
/// scalar `a > absmax` fold's exact semantics).
pub fn absmax_scaled(block: &[f32], s_t: f32) -> f32 {
    absmax_scaled_with(active(), block, s_t)
}

/// [`absmax_scaled`] at an explicit level (clamped to what the host
/// supports). Bit-identical across levels: every candidate is the same
/// rounded `v * s_t` then `abs`, and max is order-independent over the
/// non-NaN candidates.
pub fn absmax_scaled_with(level: SimdLevel, block: &[f32], s_t: f32) -> f32 {
    match level.clamped() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::absmax_scaled_avx2(block, s_t) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::absmax_scaled_neon(block, s_t) },
        _ => absmax_scaled_scalar(block, s_t),
    }
}

fn absmax_scaled_scalar(block: &[f32], s_t: f32) -> f32 {
    let mut absmax = 0.0f32;
    for &v in block {
        let a = (v * s_t).abs();
        if a > absmax {
            absmax = a;
        }
    }
    absmax
}

/// Pointwise decode of one block: `out[i] = s * lut[codes[i] & 15]`
/// over a 16-entry signed LUT (the FP4 code space). One rounded
/// multiply per element — any lane width computes identical bits.
pub fn scale_lut16(s: f32, codes: &[u8], lut: &[f32], out: &mut [f32]) {
    scale_lut16_with(active(), s, codes, lut, out)
}

/// [`scale_lut16`] at an explicit level (clamped to host support).
pub fn scale_lut16_with(
    level: SimdLevel,
    s: f32,
    codes: &[u8],
    lut: &[f32],
    out: &mut [f32],
) {
    assert!(lut.len() >= 16, "lut16 needs 16 entries");
    assert_eq!(codes.len(), out.len());
    match level.clamped() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe {
            x86::scale_lut16_avx2(s, codes, lut, out)
        },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe {
            neon::scale_lut16_neon(s, codes, lut, out)
        },
        _ => scale_lut16_scalar(s, codes, lut, out),
    }
}

fn scale_lut16_scalar(s: f32, codes: &[u8], lut: &[f32], out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = s * lut[(c & 15) as usize];
    }
}

/// Pointwise decode of one block over an arbitrary-size signed LUT
/// (64 entries for FP6, 256 for FP8): `out[i] = s * lut[codes[i]]`,
/// vectorized as a gather. Every code must index inside `lut` (the
/// bit-unpack masks codes to their field width, so it always does).
pub fn scale_lut(s: f32, codes: &[u8], lut: &[f32], out: &mut [f32]) {
    scale_lut_with(active(), s, codes, lut, out)
}

/// [`scale_lut`] at an explicit level (clamped to host support).
pub fn scale_lut_with(
    level: SimdLevel,
    s: f32,
    codes: &[u8],
    lut: &[f32],
    out: &mut [f32],
) {
    assert_eq!(codes.len(), out.len());
    debug_assert!(codes.iter().all(|&c| (c as usize) < lut.len()));
    match level.clamped() {
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe {
            x86::scale_lut_gather_avx2(s, codes, lut, out)
        },
        _ => scale_lut_scalar(s, codes, lut, out),
    }
}

fn scale_lut_scalar(s: f32, codes: &[u8], lut: &[f32], out: &mut [f32]) {
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = s * lut[c as usize];
    }
}

/// AVX2 bodies plus the in-register building blocks the GEMM kernels
/// share ([`crate::quant::gemm`] imports these rather than re-deriving
/// the shuffle sequences).
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86 {
    use core::arch::x86_64::*;

    /// Widen 8 code bytes at `p` to 8 i32 lanes.
    ///
    /// # Safety
    /// Caller guarantees AVX2 and 8 readable bytes at `p`.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn load8_u8_i32(p: *const u8) -> __m256i {
        _mm256_cvtepu8_epi32(_mm_loadl_epi64(p as *const __m128i))
    }

    /// 16-entry f32 table lookup: lane `l` reads `table[idx[l]]` for
    /// `idx[l] < 16`, the table given as its low/high 8-entry halves.
    /// `vpermps` consumes the low 3 index bits; bit 3, shifted into the
    /// lane sign position, blends between the halves — the in-register
    /// realization of the FP4 16-entry code space (SNIPPETS.md §2).
    ///
    /// # Safety
    /// Caller guarantees AVX2; every index lane must be < 16.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn lut16(lo: __m256, hi: __m256, idx: __m256i) -> __m256 {
        let a = _mm256_permutevar8x32_ps(lo, idx);
        let b = _mm256_permutevar8x32_ps(hi, idx);
        let pick_hi = _mm256_castsi256_ps(_mm256_slli_epi32(idx, 28));
        _mm256_blendv_ps(a, b, pick_hi)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn absmax_scaled_avx2(block: &[f32], s_t: f32) -> f32 {
        let sign = _mm256_set1_ps(-0.0);
        let vst = _mm256_set1_ps(s_t);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= block.len() {
            let v = _mm256_loadu_ps(block.as_ptr().add(i));
            let a = _mm256_andnot_ps(sign, _mm256_mul_ps(v, vst));
            // operand order matters: maxps returns its *second* operand
            // on unordered compares, so a NaN lane in `a` keeps `acc` —
            // the scalar fold's NaN-ignoring behavior
            acc = _mm256_max_ps(a, acc);
            i += 8;
        }
        // lanes hold non-NaN abs values now; reduce with plain max
        let hi4 = _mm256_extractf128_ps(acc, 1);
        let m4 = _mm_max_ps(_mm256_castps256_ps128(acc), hi4);
        let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
        let m1 = _mm_max_ss(m2, _mm_shuffle_ps(m2, m2, 1));
        let mut absmax = _mm_cvtss_f32(m1);
        for &v in &block[i..] {
            let a = (v * s_t).abs();
            if a > absmax {
                absmax = a;
            }
        }
        absmax
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_lut16_avx2(
        s: f32,
        codes: &[u8],
        lut: &[f32],
        out: &mut [f32],
    ) {
        let lo = _mm256_loadu_ps(lut.as_ptr());
        let hi = _mm256_loadu_ps(lut.as_ptr().add(8));
        let vs = _mm256_set1_ps(s);
        let mask = _mm256_set1_epi32(15);
        let mut i = 0usize;
        while i + 8 <= codes.len() {
            let idx =
                _mm256_and_si256(load8_u8_i32(codes.as_ptr().add(i)), mask);
            let v = lut16(lo, hi, idx);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(vs, v));
            i += 8;
        }
        super::scale_lut16_scalar(s, &codes[i..], lut, &mut out[i..]);
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn scale_lut_gather_avx2(
        s: f32,
        codes: &[u8],
        lut: &[f32],
        out: &mut [f32],
    ) {
        let vs = _mm256_set1_ps(s);
        let mut i = 0usize;
        while i + 8 <= codes.len() {
            let idx = load8_u8_i32(codes.as_ptr().add(i));
            let v = _mm256_i32gather_ps::<4>(lut.as_ptr(), idx);
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(vs, v));
            i += 8;
        }
        super::scale_lut_scalar(s, &codes[i..], lut, &mut out[i..]);
    }
}

/// NEON bodies plus the byte-index building block the GEMM FP4 kernel
/// shares (`vqtbl4q`-based 16-entry f32 table lookup).
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    use core::arch::aarch64::*;

    /// Load a 16-entry f32 table as the four byte-table registers
    /// `vqtbl4q_u8` consumes.
    ///
    /// # Safety
    /// Caller guarantees NEON and 16 readable f32 at `p`.
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn lut16_table(p: *const f32) -> uint8x16x4_t {
        uint8x16x4_t(
            vreinterpretq_u8_f32(vld1q_f32(p)),
            vreinterpretq_u8_f32(vld1q_f32(p.add(4))),
            vreinterpretq_u8_f32(vld1q_f32(p.add(8))),
            vreinterpretq_u8_f32(vld1q_f32(p.add(12))),
        )
    }

    /// Expand 4 code bytes at `p` (each < 16 after masking) into the
    /// byte-index vector selecting their f32 table entries: lane `l`
    /// holds bytes `4c..4c+4` little-endian, i.e. `c·0x04040404 +
    /// 0x03020100` per u32 lane.
    ///
    /// # Safety
    /// Caller guarantees NEON and 4 readable bytes at `p`.
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn lut16_indices(p: *const u8) -> uint8x16_t {
        let raw = (p as *const u32).read_unaligned();
        let c16 = vmovl_u8(vcreate_u8(raw as u64));
        let c32 = vandq_u32(vmovl_u16(vget_low_u16(c16)), vdupq_n_u32(15));
        let bi = vaddq_u32(
            vmulq_n_u32(c32, 0x0404_0404),
            vdupq_n_u32(0x0302_0100),
        );
        vreinterpretq_u8_u32(bi)
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn absmax_scaled_neon(block: &[f32], s_t: f32) -> f32 {
        let vst = vdupq_n_f32(s_t);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= block.len() {
            let a = vabsq_f32(vmulq_f32(vld1q_f32(block.as_ptr().add(i)), vst));
            // maxnm: a NaN lane in `a` yields the `acc` lane — the
            // scalar fold's NaN-ignoring behavior
            acc = vmaxnmq_f32(acc, a);
            i += 4;
        }
        let mut absmax = vmaxnmvq_f32(acc);
        for &v in &block[i..] {
            let a = (v * s_t).abs();
            if a > absmax {
                absmax = a;
            }
        }
        absmax
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn scale_lut16_neon(
        s: f32,
        codes: &[u8],
        lut: &[f32],
        out: &mut [f32],
    ) {
        let tbl = lut16_table(lut.as_ptr());
        let vs = vdupq_n_f32(s);
        let mut i = 0usize;
        while i + 4 <= codes.len() {
            let idx = lut16_indices(codes.as_ptr().add(i));
            let v = vreinterpretq_f32_u8(vqtbl4q_u8(tbl, idx));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(vs, v));
            i += 4;
        }
        super::scale_lut16_scalar(s, &codes[i..], lut, &mut out[i..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn levels_to_try() -> Vec<SimdLevel> {
        let mut ls = vec![SimdLevel::Scalar];
        for l in [SimdLevel::Avx2, SimdLevel::Neon] {
            if l.supported() {
                ls.push(l);
            }
        }
        ls
    }

    #[test]
    fn active_is_latched_and_named() {
        let a = active();
        assert_eq!(a, active());
        assert!(["scalar", "avx2", "neon"].contains(&kernel_name()));
        assert_eq!(a.name(), kernel_name());
        assert!(a.supported());
    }

    #[test]
    fn clamped_never_exceeds_host() {
        for l in [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Neon] {
            assert!(l.clamped().supported());
        }
    }

    #[test]
    fn absmax_levels_agree_including_nan_and_signed_zero() {
        let mut data: Vec<f32> = (0..67)
            .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.125)
            .collect();
        data[3] = f32::NAN;
        data[40] = -0.0;
        data[41] = f32::INFINITY * 0.0; // NaN via arithmetic
        let reference = absmax_scaled_scalar(&data, 1.0);
        for level in levels_to_try() {
            for s_t in [1.0f32, 0.5, 3.0] {
                let want = absmax_scaled_scalar(&data, s_t);
                let got = absmax_scaled_with(level, &data, s_t);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{} s_t={s_t}",
                    level.name()
                );
            }
        }
        // the NaN lanes really were ignored, not propagated
        assert!(reference.is_finite());
    }

    #[test]
    fn scale_lut_levels_agree() {
        let lut16v: Vec<f32> = (0..16).map(|i| (i as f32) * 0.25 - 2.0).collect();
        let lut256: Vec<f32> =
            (0..256).map(|i| ((i * 31 % 97) as f32) * 0.017 - 0.8).collect();
        let codes16: Vec<u8> = (0..53).map(|i| (i * 7 % 16) as u8).collect();
        let codes256: Vec<u8> = (0..53).map(|i| (i * 41 % 256) as u8).collect();
        for level in levels_to_try() {
            for s in [0.75f32, 1.0, 1.5e-3] {
                let mut want = vec![0.0f32; codes16.len()];
                scale_lut16_scalar(s, &codes16, &lut16v, &mut want);
                let mut got = vec![0.0f32; codes16.len()];
                scale_lut16_with(level, s, &codes16, &lut16v, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", level.name());
                }
                let mut want = vec![0.0f32; codes256.len()];
                scale_lut_scalar(s, &codes256, &lut256, &mut want);
                let mut got = vec![0.0f32; codes256.len()];
                scale_lut_with(level, s, &codes256, &lut256, &mut got);
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", level.name());
                }
            }
        }
    }
}
