//! Small in-house utilities.
//!
//! The sandbox builds fully offline from a fixed vendor set (see
//! `.cargo/config.toml`), so the usual ecosystem crates (serde, clap,
//! criterion, proptest, rand) are unavailable; this module provides the
//! minimal equivalents the rest of the crate needs.

pub mod check;
pub mod cli;
pub mod json;
pub mod par;
pub mod simd;
pub mod timer;

/// 64-bit FNV-1a offset basis (shared by every content digest in the
/// crate — the operand cache and `GemmOperand::bits_digest`).
pub const FNV_OFFSET_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
/// 64-bit FNV-1a prime.
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// FNV-1a folded over a stream of u64 words (one xor + multiply per
/// word), parameterized by basis so independent digests can back one
/// key. Word granularity trades the classic byte-at-a-time dispersion
/// for ~8× fewer multiplies — ample for content-addressed cache keys
/// verified by tests, not adversaries.
pub fn fnv1a_words<I: IntoIterator<Item = u64>>(words: I, basis: u64) -> u64 {
    let mut h = basis;
    for w in words {
        h = (h ^ w).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Exact `2^e` for `e ∈ [-126, 127]`, constructed by bit pattern.
///
/// Mirrors `_pow2` in `python/compile/kernels/ref.py` — both sides build
/// the IEEE-754 representation directly because `exp2` is approximate on
/// the XLA CPU backend.
#[inline(always)]
pub fn pow2(e: i32) -> f32 {
    debug_assert!((-126..=127).contains(&e), "pow2 exponent {e}");
    f32::from_bits(((e + 127) as u32) << 23)
}

/// Exact `x * 2^e` for integer `e` with `|e| <= 252` (two-step pow2).
///
/// Mirrors `_ldexp2` in `ref.py`: splitting keeps each factor a normal
/// f32 so the product is exact whenever the result is representable.
#[inline(always)]
pub fn ldexp2(x: f32, e: i32) -> f32 {
    let e1 = e.clamp(-126, 126);
    let e2 = e - e1;
    x * pow2(e1) * pow2(e2)
}

/// `floor(log2(x))` for normal positive f32 via exponent-field extraction.
#[inline(always)]
pub fn floor_log2(x: f32) -> i32 {
    (((x.to_bits() >> 23) & 0xFF) as i32) - 127
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_words_is_deterministic_and_basis_sensitive() {
        let data = [1u64, 2, 3, 0xFFFF_FFFF];
        let a = fnv1a_words(data, FNV_OFFSET_BASIS);
        let b = fnv1a_words(data, FNV_OFFSET_BASIS);
        assert_eq!(a, b);
        assert_ne!(a, fnv1a_words(data, FNV_OFFSET_BASIS ^ 1));
        // order- and value-sensitive
        assert_ne!(a, fnv1a_words([2u64, 1, 3, 0xFFFF_FFFF], FNV_OFFSET_BASIS));
        assert_ne!(a, fnv1a_words([1u64, 2, 3, 0xFFFF_FFFE], FNV_OFFSET_BASIS));
    }

    #[test]
    fn pow2_exact() {
        assert_eq!(pow2(0), 1.0);
        assert_eq!(pow2(10), 1024.0);
        assert_eq!(pow2(-126), f32::MIN_POSITIVE);
        assert_eq!(pow2(127), 2.0f32.powi(127));
    }

    #[test]
    fn ldexp2_wide_range() {
        assert_eq!(ldexp2(1.5, 130), 1.5 * 2.0f32.powi(100) * 2.0f32.powi(30));
        // 2^-140 is an f32 subnormal: check the exact bit pattern
        assert_eq!(ldexp2(1.0, -140), f32::from_bits(1 << (149 - 140)));
        assert_eq!(ldexp2(3.0, 0), 3.0);
    }

    #[test]
    fn floor_log2_matches() {
        for (x, want) in [(1.0, 0), (1.9, 0), (2.0, 1), (0.5, -1), (6.0, 2)] {
            assert_eq!(floor_log2(x), want, "x={x}");
        }
    }
}
