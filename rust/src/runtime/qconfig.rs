//! Runtime quantization configuration → the 11-scalar `qvec` consumed by
//! the lowered model graphs.
//!
//! Layout MUST match `python/compile/model.py` (`QV_*` constants); both
//! sides pin it with tests (`test_model.py::test_qvec_layout_stable` and
//! the tests below).

use anyhow::{bail, Result};

use crate::formats::{scale_format, ElemFormat, MiniFloat};
use crate::quant::QuantScheme;

pub const QV_LEN: usize = 11;

/// A named, runtime-selectable quantization configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QConfig {
    pub quant_on: bool,
    pub elem: ElemFormat,
    pub scale: MiniFloat,
    pub per_tensor: bool,
    pub act_quant: bool,
}

impl QConfig {
    /// The exact-baseline configuration (paper's "BF16" rows).
    pub fn baseline() -> QConfig {
        QConfig {
            quant_on: false,
            elem: ElemFormat::FP4,
            scale: crate::formats::UE4M3,
            per_tensor: false,
            act_quant: true,
        }
    }

    /// FP4 elements with the given scale format name
    /// (`ue4m3`/`ue5m3`/`ue4m4`/`ue5m1`/`ue4m2`/`e8m0`/`bf16`).
    pub fn fp4(scale_name: &str) -> Result<QConfig> {
        Self::named("fp4_e2m1", scale_name, false)
    }

    pub fn named(
        elem_name: &str,
        scale_name: &str,
        per_tensor: bool,
    ) -> Result<QConfig> {
        let Some(elem) = ElemFormat::from_name(elem_name) else {
            bail!("unknown element format {elem_name:?}");
        };
        let Some(scale) = scale_format(scale_name) else {
            bail!("unknown scale format {scale_name:?}");
        };
        Ok(QConfig {
            quant_on: true,
            elem,
            scale,
            per_tensor,
            act_quant: true,
        })
    }

    pub fn with_per_tensor(mut self, on: bool) -> QConfig {
        self.per_tensor = on;
        self
    }

    /// Equivalent CPU-side scheme (for cross-validation tests).
    pub fn scheme(&self, block_size: usize) -> QuantScheme {
        QuantScheme::new(self.elem, self.scale, block_size)
            .with_per_tensor(self.per_tensor)
    }

    /// Short display id, e.g. `fp4/ue4m3-S` or `bf16-exact`.
    pub fn id(&self) -> String {
        if !self.quant_on {
            return "bf16-exact".to_string();
        }
        format!(
            "{}/{}{}{}",
            match self.elem {
                ElemFormat::Int(m) if m == 7.0 => "int4".to_string(),
                e => e.name().to_string(),
            },
            self.scale.name,
            if self.per_tensor { "-S" } else { "" },
            if self.act_quant { "" } else { "-wonly" }
        )
    }

    /// Serialize to the runtime scalar vector (model.py QV_* layout).
    pub fn to_qvec(&self) -> [f32; QV_LEN] {
        let mut v = [0.0f32; QV_LEN];
        v[0] = if self.quant_on { 1.0 } else { 0.0 };
        match self.elem {
            ElemFormat::Int(m) => {
                v[1] = 1.0;
                v[2] = 0.0;
                v[3] = 0.0;
                v[4] = m;
            }
            ElemFormat::Fp(f) => {
                v[1] = 0.0;
                v[2] = f.m_bits as f32;
                v[3] = f.e_min as f32;
                v[4] = f.max_val;
            }
        }
        v[5] = self.scale.m_bits as f32;
        v[6] = self.scale.e_min as f32;
        v[7] = self.scale.max_val;
        v[8] = if self.per_tensor { 1.0 } else { 0.0 };
        v[9] = self.scale.max_val;
        v[10] = if self.act_quant { 1.0 } else { 0.0 };
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qvec_layout_locked() {
        let v = QConfig::named("fp4_e2m1", "ue4m3", true).unwrap().to_qvec();
        assert_eq!(v[0], 1.0);
        assert_eq!(v[2], 1.0); // elem m_bits
        assert_eq!(v[4], 6.0); // elem max
        assert_eq!(v[5], 3.0); // scale m_bits
        assert_eq!(v[6], -6.0); // scale e_min
        assert_eq!(v[7], 448.0);
        assert_eq!(v[8], 1.0); // per-tensor
        assert_eq!(v[10], 1.0); // act quant

        let v5 = QConfig::fp4("ue5m3").unwrap().to_qvec();
        assert_eq!(v5[6], -14.0);
        assert_eq!(v5[7], 122880.0);

        let vi = QConfig::named("int4", "ue4m3", false).unwrap().to_qvec();
        assert_eq!(vi[1], 1.0);
        assert_eq!(vi[4], 7.0);

        let vb = QConfig::baseline().to_qvec();
        assert_eq!(vb[0], 0.0);
    }

    #[test]
    fn ids_are_stable() {
        assert_eq!(QConfig::baseline().id(), "bf16-exact");
        assert_eq!(QConfig::fp4("ue5m3").unwrap().id(), "fp4_e2m1/ue5m3");
        assert_eq!(
            QConfig::named("int4", "ue4m3", true).unwrap().id(),
            "int4/ue4m3-S"
        );
    }
}
