//! Runtime quantization configuration → the 11-scalar `qvec` consumed by
//! the lowered model graphs.
//!
//! Layout MUST match `python/compile/model.py` (`QV_*` constants); both
//! sides pin it with tests (`test_model.py::test_qvec_layout_stable` and
//! the tests below).

use anyhow::{bail, Result};

use crate::formats::{scale_format, ElemFormat, MiniFloat};
use crate::quant::QuantScheme;

pub const QV_LEN: usize = 11;

/// A named, runtime-selectable quantization configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QConfig {
    pub quant_on: bool,
    pub elem: ElemFormat,
    pub scale: MiniFloat,
    pub per_tensor: bool,
    pub act_quant: bool,
    /// Hadamard pre-rotation on the contraction dimension: activations
    /// pass through [`crate::quant::rotate::fwht_rows`] and weights
    /// through the matching column rotation before quantization, so the
    /// GEMM computes `Q(xH)·Q(HᵀW)` (H self-inverse ⇒ same product in
    /// exact arithmetic). On an exact (quant-off) linear the rotation is
    /// elided entirely — `xHHᵀW = xW` — which keeps the exact path
    /// bit-identical to the unrotated one (f32 FWHT round-trips are not
    /// bit-exact, so the identity must be taken in the algebra, not
    /// computed).
    pub rotate: bool,
    /// Per-layer block-size override: when set, [`QConfig::scheme`]
    /// ignores the model-global block size. How the tuner assigns
    /// different block sizes to different layers without rebuilding the
    /// whole model around a new global.
    pub bs_override: Option<usize>,
}

impl QConfig {
    /// The exact-baseline configuration (paper's "BF16" rows).
    pub fn baseline() -> QConfig {
        QConfig {
            quant_on: false,
            elem: ElemFormat::FP4,
            scale: crate::formats::UE4M3,
            per_tensor: false,
            act_quant: true,
            rotate: false,
            bs_override: None,
        }
    }

    /// FP4 elements with the given scale format name
    /// (`ue4m3`/`ue5m3`/`ue4m4`/`ue5m1`/`ue4m2`/`e8m0`/`bf16`).
    pub fn fp4(scale_name: &str) -> Result<QConfig> {
        Self::named("fp4_e2m1", scale_name, false)
    }

    pub fn named(
        elem_name: &str,
        scale_name: &str,
        per_tensor: bool,
    ) -> Result<QConfig> {
        let Some(elem) = ElemFormat::from_name(elem_name) else {
            bail!("unknown element format {elem_name:?}");
        };
        let Some(scale) = scale_format(scale_name) else {
            bail!("unknown scale format {scale_name:?}");
        };
        Ok(QConfig {
            quant_on: true,
            elem,
            scale,
            per_tensor,
            act_quant: true,
            rotate: false,
            bs_override: None,
        })
    }

    pub fn with_per_tensor(mut self, on: bool) -> QConfig {
        self.per_tensor = on;
        self
    }

    /// Builder-style Hadamard pre-rotation toggle.
    pub fn with_rotate(mut self, on: bool) -> QConfig {
        self.rotate = on;
        self
    }

    /// Builder-style per-layer block-size override.
    pub fn with_block_size(mut self, bs: usize) -> QConfig {
        self.bs_override = Some(bs);
        self
    }

    /// Parse the short display id produced by [`QConfig::id`]:
    /// `bf16-exact` (or `none`) for the quantization-off baseline,
    /// otherwise `<elem>/<scale>[-S][-wonly][@bs<N>][-rot]` — e.g.
    /// `fp4_e2m1/ue5m3`, `int4/ue4m3-S`, `fp8_e4m3/ue4m3-wonly`,
    /// `fp4_e2m1/ue4m3@bs8-rot`.
    pub fn parse(s: &str) -> Result<QConfig> {
        let s = s.trim();
        if s == "bf16-exact" || s == "none" {
            return Ok(QConfig::baseline());
        }
        let Some((elem, rest)) = s.split_once('/') else {
            bail!(
                "bad qconfig {s:?}: expected \
                 <elem>/<scale>[-S][-wonly][@bs<N>][-rot] or bf16-exact"
            );
        };
        // id() appends suffixes in the order -S, -wonly, @bsN, -rot —
        // strip them in reverse order
        let mut rest = rest;
        let mut rotate = false;
        if let Some(r) = rest.strip_suffix("-rot") {
            rotate = true;
            rest = r;
        }
        let mut bs_override = None;
        if let Some((r, bs)) = rest.rsplit_once("@bs") {
            bs_override = Some(bs.parse::<usize>().map_err(|e| {
                anyhow::anyhow!("bad block-size override {bs:?}: {e}")
            })?);
            rest = r;
        }
        let mut act_quant = true;
        if let Some(r) = rest.strip_suffix("-wonly") {
            act_quant = false;
            rest = r;
        }
        let mut per_tensor = false;
        if let Some(r) = rest.strip_suffix("-S") {
            per_tensor = true;
            rest = r;
        }
        let mut cfg = QConfig::named(elem, rest, per_tensor)?;
        cfg.act_quant = act_quant;
        cfg.rotate = rotate;
        cfg.bs_override = bs_override;
        Ok(cfg)
    }

    /// Equivalent CPU-side scheme (for cross-validation tests). A
    /// [`QConfig::bs_override`] wins over the model-global `block_size`.
    pub fn scheme(&self, block_size: usize) -> QuantScheme {
        QuantScheme::new(
            self.elem,
            self.scale,
            self.bs_override.unwrap_or(block_size),
        )
        .with_per_tensor(self.per_tensor)
    }

    /// The block size this config quantizes with, given the
    /// model-global default.
    pub fn effective_block_size(&self, block_size: usize) -> usize {
        self.bs_override.unwrap_or(block_size)
    }

    /// Short display id, e.g. `fp4/ue4m3-S`, `fp4_e2m1/ue4m3@bs8-rot`,
    /// or `bf16-exact`.
    pub fn id(&self) -> String {
        if !self.quant_on {
            return "bf16-exact".to_string();
        }
        format!(
            "{}/{}{}{}{}{}",
            match self.elem {
                ElemFormat::Int(m) if m == 7.0 => "int4".to_string(),
                e => e.name().to_string(),
            },
            self.scale.name,
            if self.per_tensor { "-S" } else { "" },
            if self.act_quant { "" } else { "-wonly" },
            match self.bs_override {
                Some(bs) => format!("@bs{bs}"),
                None => String::new(),
            },
            if self.rotate { "-rot" } else { "" }
        )
    }

    /// Serialize to the runtime scalar vector (model.py QV_* layout).
    pub fn to_qvec(&self) -> [f32; QV_LEN] {
        let mut v = [0.0f32; QV_LEN];
        v[0] = if self.quant_on { 1.0 } else { 0.0 };
        match self.elem {
            ElemFormat::Int(m) => {
                v[1] = 1.0;
                v[2] = 0.0;
                v[3] = 0.0;
                v[4] = m;
            }
            ElemFormat::Fp(f) => {
                v[1] = 0.0;
                v[2] = f.m_bits as f32;
                v[3] = f.e_min as f32;
                v[4] = f.max_val;
            }
        }
        v[5] = self.scale.m_bits as f32;
        v[6] = self.scale.e_min as f32;
        v[7] = self.scale.max_val;
        v[8] = if self.per_tensor { 1.0 } else { 0.0 };
        v[9] = self.scale.max_val;
        v[10] = if self.act_quant { 1.0 } else { 0.0 };
        v
    }
}

/// A per-layer quantization assignment: one base [`QConfig`] plus
/// sparse layer-index overrides — the mixed-precision serving scenarios
/// of *Scaling Laws For Mixed Quantization* (keep sensitive layers at
/// FP8 while the bulk runs FP4). Model-global configs are the
/// [`PerLayerQConfig::uniform`] special case; both the serve subsystem
/// ([`crate::serve`]) and the CLI consume this type.
#[derive(Debug, Clone, PartialEq)]
pub struct PerLayerQConfig {
    base: QConfig,
    /// `(layer index, config)`, sorted by layer, at most one per layer.
    overrides: Vec<(usize, QConfig)>,
}

impl PerLayerQConfig {
    /// The same config on every layer.
    pub fn uniform(base: QConfig) -> PerLayerQConfig {
        PerLayerQConfig { base, overrides: Vec::new() }
    }

    /// Builder-style override for one layer (replaces an existing
    /// override for the same layer).
    pub fn with_override(mut self, layer: usize, cfg: QConfig) -> PerLayerQConfig {
        match self.overrides.binary_search_by_key(&layer, |(l, _)| *l) {
            Ok(i) => self.overrides[i].1 = cfg,
            Err(i) => self.overrides.insert(i, (layer, cfg)),
        }
        self
    }

    pub fn base(&self) -> &QConfig {
        &self.base
    }

    pub fn overrides(&self) -> &[(usize, QConfig)] {
        &self.overrides
    }

    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty()
    }

    /// The effective config for layer `l`.
    pub fn layer(&self, l: usize) -> QConfig {
        match self.overrides.binary_search_by_key(&l, |(i, _)| *i) {
            Ok(i) => self.overrides[i].1,
            Err(_) => self.base,
        }
    }

    /// Stable display id (also the parse syntax): the base
    /// [`QConfig::id`], then `;<layer>=<id>` per override — e.g.
    /// `fp4_e2m1/ue5m3;0=fp8_e4m3/ue5m3;3=bf16-exact`. Used in cache
    /// keys and `BENCH_serve.json`, so the format is load-bearing.
    pub fn id(&self) -> String {
        let mut s = self.base.id();
        for (l, c) in &self.overrides {
            s.push(';');
            s.push_str(&format!("{l}={}", c.id()));
        }
        s
    }

    /// Inverse of [`PerLayerQConfig::id`].
    pub fn parse(s: &str) -> Result<PerLayerQConfig> {
        let mut parts = s.split(';');
        let base = QConfig::parse(parts.next().unwrap_or(""))?;
        let mut out = PerLayerQConfig::uniform(base);
        for p in parts {
            let Some((l, c)) = p.split_once('=') else {
                bail!("bad per-layer override {p:?}: expected <layer>=<config>");
            };
            let layer: usize = l
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad layer index {l:?}: {e}"))?;
            out = out.with_override(layer, QConfig::parse(c)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qvec_layout_locked() {
        let v = QConfig::named("fp4_e2m1", "ue4m3", true).unwrap().to_qvec();
        assert_eq!(v[0], 1.0);
        assert_eq!(v[2], 1.0); // elem m_bits
        assert_eq!(v[4], 6.0); // elem max
        assert_eq!(v[5], 3.0); // scale m_bits
        assert_eq!(v[6], -6.0); // scale e_min
        assert_eq!(v[7], 448.0);
        assert_eq!(v[8], 1.0); // per-tensor
        assert_eq!(v[10], 1.0); // act quant

        let v5 = QConfig::fp4("ue5m3").unwrap().to_qvec();
        assert_eq!(v5[6], -14.0);
        assert_eq!(v5[7], 122880.0);

        let vi = QConfig::named("int4", "ue4m3", false).unwrap().to_qvec();
        assert_eq!(vi[1], 1.0);
        assert_eq!(vi[4], 7.0);

        let vb = QConfig::baseline().to_qvec();
        assert_eq!(vb[0], 0.0);
    }

    #[test]
    fn ids_are_stable() {
        assert_eq!(QConfig::baseline().id(), "bf16-exact");
        assert_eq!(QConfig::fp4("ue5m3").unwrap().id(), "fp4_e2m1/ue5m3");
        assert_eq!(
            QConfig::named("int4", "ue4m3", true).unwrap().id(),
            "int4/ue4m3-S"
        );
    }

    #[test]
    fn parse_inverts_id() {
        let mut wonly = QConfig::fp4("ue4m3").unwrap();
        wonly.act_quant = false;
        for cfg in [
            QConfig::baseline(),
            QConfig::fp4("ue5m3").unwrap(),
            QConfig::named("int4", "ue4m3", true).unwrap(),
            QConfig::named("fp8_e4m3", "ue4m3", false).unwrap(),
            wonly,
        ] {
            let back = QConfig::parse(&cfg.id()).unwrap();
            assert_eq!(back, cfg, "round-trip of {}", cfg.id());
        }
        assert_eq!(QConfig::parse("none").unwrap(), QConfig::baseline());
        assert!(QConfig::parse("fp4_e2m1").is_err());
        assert!(QConfig::parse("fp4_e2m1/nope").is_err());
    }

    #[test]
    fn rotation_and_block_override_round_trip() {
        let r = QConfig::fp4("ue4m3").unwrap().with_rotate(true);
        assert_eq!(r.id(), "fp4_e2m1/ue4m3-rot");
        assert_eq!(QConfig::parse(&r.id()).unwrap(), r);

        let b = QConfig::fp4("ue5m3").unwrap().with_block_size(8);
        assert_eq!(b.id(), "fp4_e2m1/ue5m3@bs8");
        assert_eq!(QConfig::parse(&b.id()).unwrap(), b);
        assert_eq!(b.scheme(32).block_size, 8);
        assert_eq!(b.effective_block_size(32), 8);

        let both = QConfig::named("fp8_e4m3", "ue4m3", true)
            .unwrap()
            .with_rotate(true)
            .with_block_size(16);
        assert_eq!(both.id(), "fp8_e4m3/ue4m3-S@bs16-rot");
        assert_eq!(QConfig::parse(&both.id()).unwrap(), both);

        let mut wonly = QConfig::fp4("ue4m3").unwrap().with_rotate(true);
        wonly.act_quant = false;
        assert_eq!(wonly.id(), "fp4_e2m1/ue4m3-wonly-rot");
        assert_eq!(QConfig::parse(&wonly.id()).unwrap(), wonly);

        // no override: the model-global block size flows through
        let plain = QConfig::fp4("ue4m3").unwrap();
        assert_eq!(plain.scheme(32).block_size, 32);
        assert!(QConfig::parse("fp4_e2m1/ue4m3@bsx").is_err());

        // per-layer ids with the new suffixes round-trip too
        let q = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
            .with_override(1, r)
            .with_override(2, both);
        assert_eq!(PerLayerQConfig::parse(&q.id()).unwrap(), q);
    }

    #[test]
    fn per_layer_overrides_resolve_and_round_trip() {
        let base = QConfig::fp4("ue5m3").unwrap();
        let hi = QConfig::named("fp8_e4m3", "ue5m3", false).unwrap();
        let q = PerLayerQConfig::uniform(base)
            .with_override(3, QConfig::baseline())
            .with_override(0, hi);
        assert_eq!(q.layer(0), hi);
        assert_eq!(q.layer(1), base);
        assert_eq!(q.layer(3), QConfig::baseline());
        assert!(!q.is_uniform());
        assert_eq!(
            q.id(),
            "fp4_e2m1/ue5m3;0=fp8_e4m3/ue5m3;3=bf16-exact"
        );
        let back = PerLayerQConfig::parse(&q.id()).unwrap();
        assert_eq!(back, q);
        // replacing an existing override keeps one entry per layer
        let q2 = q.clone().with_override(0, base);
        assert_eq!(q2.layer(0), base);
        assert_eq!(q2.overrides().len(), 2);
        assert!(PerLayerQConfig::parse("fp4_e2m1/ue4m3;x=fp8").is_err());
    }
}
