//! Artifact manifest: the contract emitted by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model dimensions as lowered (fixed per artifact set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

/// How a parameter tensor is initialized (mirrors `model.init_specs`).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub shape: Vec<usize>,
    pub init: String, // "normal" | "ones" | "zeros"
    pub std: f64,
    pub decay: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One tensor slot of an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub eval_batch: usize,
    pub train_batch: usize,
    pub block_sizes: Vec<usize>,
    pub qvec_len: usize,
    pub params: BTreeMap<String, ParamSpec>,
    pub param_order: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text)?;
        let m = j.get("model")?;
        let model = ModelDims {
            vocab: m.get("vocab")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            n_layers: m.get("n_layers")?.as_usize()?,
            d_ff: m.get("d_ff")?.as_usize()?,
            seq_len: m.get("seq_len")?.as_usize()?,
        };
        let mut params = BTreeMap::new();
        for (k, v) in j.get("params")?.as_obj()? {
            params.insert(
                k.clone(),
                ParamSpec {
                    shape: v.get("shape")?.as_usize_vec()?,
                    init: v.get("init")?.as_str()?.to_string(),
                    std: v.get("std")?.as_f64()?,
                    decay: v.get("decay")?.as_bool()?,
                },
            );
        }
        let param_order: Vec<String> = j
            .get("param_order")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        if param_order.len() != params.len() {
            bail!("param_order / params mismatch");
        }
        let tensor_specs = |arr: &Json| -> Result<Vec<TensorSpec>> {
            arr.as_arr()?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        name: t
                            .opt("name")
                            .map(|n| n.as_str().map(|s| s.to_string()))
                            .transpose()?
                            .unwrap_or_default(),
                        shape: t.get("shape")?.as_usize_vec()?,
                        dtype: t.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect()
        };
        let mut artifacts = BTreeMap::new();
        for (k, v) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(
                k.clone(),
                ArtifactSpec {
                    name: k.clone(),
                    file: v.get("file")?.as_str()?.to_string(),
                    inputs: tensor_specs(v.get("inputs")?)?,
                    outputs: tensor_specs(v.get("outputs")?)?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            block_sizes: j.get("block_sizes")?.as_usize_vec()?,
            qvec_len: j.get("qvec_len")?.as_usize()?,
            params,
            param_order,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    /// Total parameter count of the lowered model.
    pub fn param_count(&self) -> usize {
        self.params.values().map(|p| p.numel()).sum()
    }

    pub fn loss_artifact(&self, block_size: usize) -> String {
        format!("loss_bs{block_size}")
    }

    pub fn logits_artifact(&self, block_size: usize) -> String {
        format!("logits_bs{block_size}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        // unit-level smoke; full coverage lives in rust/tests/integration.rs
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(m.param_count() > 100_000);
        assert!(m.artifacts.contains_key("train_step"));
        assert!(m.artifacts.contains_key("loss_bs8"));
        assert_eq!(m.qvec_len, 11);
        // every artifact file exists
        for a in m.artifacts.values() {
            assert!(m.dir.join(&a.file).exists(), "{}", a.file);
        }
    }
}
