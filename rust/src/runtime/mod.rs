//! L3 runtime: load and execute the AOT-lowered HLO artifacts on the PJRT
//! CPU client. Python never runs here — `make artifacts` produced HLO text
//! once at build time (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for the text-vs-proto rationale).
//!
//! * [`artifacts`] — parse `artifacts/manifest.json` (the shape/init
//!   contract between the python compile path and this runtime);
//! * [`session`] — PJRT client + compiled-executable cache + marshalling;
//! * [`qconfig`] — the runtime quantization-config vector (must match
//!   `model.py`'s `QV_*` layout, locked by tests on both sides);
//! * [`eval`] — perplexity/logit evaluation drivers;
//! * [`train`] — the AdamW training loop driver.

pub mod artifacts;
pub mod eval;
pub mod qconfig;
pub mod session;
pub mod train;

pub use artifacts::Manifest;
pub use qconfig::QConfig;
pub use session::Session;
