//! Evaluation drivers: perplexity and probe metrics over the AOT loss /
//! logits artifacts.
//!
//! Parameters are uploaded to device-resident buffers once per model and
//! reused across every (format, block size) configuration in a sweep —
//! the host→device traffic per evaluation is then just the token batch
//! and the 11-scalar qvec.

use anyhow::{Context, Result};

use super::qconfig::QConfig;
use super::session::{literal_scalar_f32, literal_vec_f32, HostTensor, Session};
use crate::model::probes::{ProbeAccum, ProbeResult};
use crate::model::weights::Params;
use crate::model::Corpus;

/// Device-resident parameter set (manifest order).
pub struct DeviceParams {
    bufs: Vec<xla::PjRtBuffer>,
}

impl DeviceParams {
    pub fn upload(session: &Session, params: &Params) -> Result<DeviceParams> {
        let order = &session.manifest().param_order;
        let mut bufs = Vec::with_capacity(order.len());
        for name in order {
            let (shape, data) = params.get(name)?;
            bufs.push(
                session
                    .upload(&HostTensor::F32(shape.to_vec(), data.to_vec()))
                    .with_context(|| format!("uploading {name}"))?,
            );
        }
        Ok(DeviceParams { bufs })
    }
}

/// Mean NLL (nats/token) over token batches; each batch is a flattened
/// (eval_batch, seq_len+1) i32 tensor.
pub fn mean_nll(
    session: &Session,
    params: &DeviceParams,
    qcfg: &QConfig,
    block_size: usize,
    batches: &[Vec<i32>],
) -> Result<f64> {
    let m = session.manifest();
    let artifact = m.loss_artifact(block_size);
    let tok_shape = vec![m.eval_batch, m.model.seq_len + 1];
    let qv = qcfg.to_qvec();
    let qv_buf = session
        .upload(&HostTensor::F32(vec![qv.len()], qv.to_vec()))?;
    let mut total = 0.0f64;
    for b in batches {
        let tok = session.upload(&HostTensor::I32(tok_shape.clone(), b.clone()))?;
        let mut args: Vec<&xla::PjRtBuffer> =
            params.bufs.iter().collect();
        args.push(&tok);
        args.push(&qv_buf);
        let out = session.run_buffers(&artifact, &args)?;
        total += literal_scalar_f32(&out[0])? as f64;
    }
    Ok(total / batches.len().max(1) as f64)
}

/// Perplexity = exp(mean NLL).
pub fn perplexity(
    session: &Session,
    params: &DeviceParams,
    qcfg: &QConfig,
    block_size: usize,
    batches: &[Vec<i32>],
) -> Result<f64> {
    Ok(mean_nll(session, params, qcfg, block_size, batches)?.exp())
}

/// Logits for one (eval_batch, seq_len) token batch; returns a
/// (batch*seq, vocab) row-major tensor.
pub fn logits(
    session: &Session,
    params: &DeviceParams,
    qcfg: &QConfig,
    block_size: usize,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let m = session.manifest();
    let artifact = m.logits_artifact(block_size);
    let qv = qcfg.to_qvec();
    let qv_buf =
        session.upload(&HostTensor::F32(vec![qv.len()], qv.to_vec()))?;
    let tok = session.upload(&HostTensor::I32(
        vec![m.eval_batch, m.model.seq_len],
        tokens.to_vec(),
    ))?;
    let mut args: Vec<&xla::PjRtBuffer> = params.bufs.iter().collect();
    args.push(&tok);
    args.push(&qv_buf);
    let out = session.run_buffers(&artifact, &args)?;
    literal_vec_f32(&out[0])
}

/// Run the downstream probes (Table 1/3 substitute) for one config.
///
/// Uses `n_batches` held-out batches; the BF16 baseline logits are
/// recomputed per batch (callers comparing many configs should hoist
/// them — `probe_many` does).
pub fn probes_for_config(
    session: &Session,
    params: &DeviceParams,
    corpus: &Corpus,
    qcfg: &QConfig,
    block_size: usize,
    n_batches: usize,
    seed: u64,
) -> Result<ProbeResult> {
    let m = session.manifest();
    let (b, s, v) = (m.eval_batch, m.model.seq_len, m.model.vocab);
    let baseline = QConfig::baseline();
    let mut acc = ProbeAccum::default();
    // batches of (b, s+1): inputs [:, :-1], targets [:, 1:]
    let batches = corpus.batches(seed, n_batches, b, s + 1);
    for batch in &batches {
        let (inputs, targets, is_pref) = split_probe_batch(corpus, batch, b, s);
        let ql = logits(session, params, qcfg, block_size, &inputs)?;
        let bl = logits(session, params, &baseline, block_size, &inputs)?;
        acc.add_batch(&ql, &bl, &targets, &is_pref, v);
    }
    Ok(acc.finish())
}

/// Shared probe evaluation across many configs (baseline hoisted).
pub fn probe_many(
    session: &Session,
    params: &DeviceParams,
    corpus: &Corpus,
    configs: &[(QConfig, usize)],
    n_batches: usize,
    seed: u64,
) -> Result<Vec<ProbeResult>> {
    let m = session.manifest();
    let (b, s, v) = (m.eval_batch, m.model.seq_len, m.model.vocab);
    let batches = corpus.batches(seed, n_batches, b, s + 1);
    let mut prepared = Vec::new();
    for batch in &batches {
        let (inputs, targets, is_pref) = split_probe_batch(corpus, batch, b, s);
        // baseline at any block size is identical (quant bypassed); use
        // the first config's block size artifact
        let bl = logits(
            session,
            params,
            &QConfig::baseline(),
            configs.first().map(|c| c.1).unwrap_or(8),
            &inputs,
        )?;
        prepared.push((inputs, targets, is_pref, bl));
    }
    let mut out = Vec::with_capacity(configs.len());
    for (qcfg, bs) in configs {
        let mut acc = ProbeAccum::default();
        for (inputs, targets, is_pref, bl) in &prepared {
            let ql = logits(session, params, qcfg, *bs, inputs)?;
            acc.add_batch(&ql, bl, targets, is_pref, v);
        }
        out.push(acc.finish());
    }
    Ok(out)
}

fn split_probe_batch(
    corpus: &Corpus,
    batch: &[i32],
    b: usize,
    s: usize,
) -> (Vec<i32>, Vec<i32>, Vec<bool>) {
    let mut inputs = Vec::with_capacity(b * s);
    let mut targets = Vec::with_capacity(b * s);
    let mut is_pref = Vec::with_capacity(b * s);
    for row in 0..b {
        let r = &batch[row * (s + 1)..(row + 1) * (s + 1)];
        inputs.extend_from_slice(&r[..s]);
        targets.extend_from_slice(&r[1..]);
        for i in 0..s {
            let (a_ctx, b_ctx) = if i == 0 {
                (r[0], r[0]) // degenerate first-position context
            } else {
                (r[i - 1], r[i])
            };
            is_pref.push(
                corpus.top_continuation(a_ctx as u32, b_ctx as u32)
                    == r[i + 1],
            );
        }
    }
    (inputs, targets, is_pref)
}
