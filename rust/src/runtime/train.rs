//! Training-loop driver over the AOT `train_step` artifact (AdamW, full
//! precision — the paper studies post-training quantization).
//!
//! The loop is pure Rust: batches come from the synthetic corpus, the
//! step itself is one PJRT execution, and the returned parameter /
//! optimizer-state literals are fed to the next step.

use anyhow::{Context, Result};

use super::session::{HostTensor, Session};
use crate::model::weights::Params;
use crate::model::Corpus;

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub weight_decay: f64,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            lr: 1e-3,
            warmup: 30,
            weight_decay: 0.01,
            seed: 1,
            log_every: 20,
        }
    }
}

/// Cosine schedule with linear warmup.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f64 {
    if step < cfg.warmup {
        return cfg.lr * (step + 1) as f64 / cfg.warmup as f64;
    }
    let t = (step - cfg.warmup) as f64
        / (cfg.steps.saturating_sub(cfg.warmup)).max(1) as f64;
    cfg.lr * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()).max(0.02)
}

/// One recorded point of the loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
}

/// Train from `init` for `cfg.steps` steps; returns the trained
/// parameters and the loss curve.
pub fn train(
    session: &Session,
    corpus: &Corpus,
    init: &Params,
    cfg: &TrainConfig,
) -> Result<(Params, Vec<LossPoint>)> {
    let m = session.manifest();
    let order = m.param_order.clone();
    let n_tensors = order.len();
    let tok_shape = vec![m.train_batch, m.model.seq_len + 1];

    let mut params = init.clone();
    let mut mstate = init.zeros_like();
    let mut vstate = init.zeros_like();
    let mut curve = Vec::new();

    let batches = corpus.batches(
        cfg.seed.wrapping_mul(0x7261_696E), // "rain"
        cfg.steps,
        m.train_batch,
        m.model.seq_len + 1,
    );

    for (step, batch) in batches.iter().enumerate() {
        let lr = lr_at(cfg, step);
        let mut args: Vec<HostTensor> = Vec::with_capacity(3 * n_tensors + 4);
        for src in [&params, &mstate, &vstate] {
            for name in &order {
                let (shape, data) = src.get(name)?;
                args.push(HostTensor::F32(shape.to_vec(), data.to_vec()));
            }
        }
        args.push(HostTensor::scalar_f32((step + 1) as f32));
        args.push(HostTensor::I32(tok_shape.clone(), batch.clone()));
        args.push(HostTensor::scalar_f32(lr as f32));
        args.push(HostTensor::scalar_f32(cfg.weight_decay as f32));

        let outs = session
            .run("train_step", &args)
            .with_context(|| format!("train step {step}"))?;
        anyhow::ensure!(outs.len() == 3 * n_tensors + 1);
        for (slot, dst) in
            [&mut params, &mut mstate, &mut vstate].into_iter().enumerate()
        {
            for (i, name) in order.iter().enumerate() {
                let lit = &outs[slot * n_tensors + i];
                let data = lit.to_vec::<f32>()?;
                let buf = dst.get_mut(name)?;
                anyhow::ensure!(buf.len() == data.len(), "{name} size");
                *buf = data;
            }
        }
        let loss = outs[3 * n_tensors].get_first_element::<f32>()? as f64;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            log::info!("step {step:>5}  loss {loss:.4}  lr {lr:.2e}");
            curve.push(LossPoint { step, loss, lr });
        }
    }
    Ok((params, curve))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { steps: 100, warmup: 10, lr: 1e-3, ..Default::default() };
        assert!(lr_at(&cfg, 0) < lr_at(&cfg, 9));
        assert!((lr_at(&cfg, 9) - 1e-3).abs() < 1.1e-4);
        assert!(lr_at(&cfg, 99) < 1e-4);
        // monotone decay after warmup
        let mut prev = lr_at(&cfg, 10);
        for s in 11..100 {
            let cur = lr_at(&cfg, s);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }
}
