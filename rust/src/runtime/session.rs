//! PJRT session: client, compiled-executable cache, and marshalling.
//!
//! One [`Session`] owns the PJRT CPU client. HLO-text artifacts are
//! compiled on first use and cached for the lifetime of the session (one
//! compiled executable per model variant, as the architecture prescribes).
//! Parameters can be kept device-resident ([`Session::upload`]) so a
//! perplexity sweep pays the host→device copy once per model, not once
//! per batch — see EXPERIMENTS.md §Perf.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::artifacts::Manifest;

/// Host-side tensor (f32 or i32), row-major.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![], vec![v])
    }

    pub fn numel(&self) -> usize {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => {
                s.iter().product()
            }
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) => s,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, d) => Ok(d),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }
}

/// A PJRT session with an executable cache.
pub struct Session {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// executions performed (metrics surface for the coordinator)
    pub exec_count: RefCell<u64>,
}

impl Session {
    /// Open a session over an artifact directory (compiles lazily).
    pub fn open(manifest: Manifest) -> Result<Session> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Session {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact (cached).
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("path utf8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match t {
            HostTensor::F32(shape, data) => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .context("upload f32"),
            HostTensor::I32(shape, data) => self
                .client
                .buffer_from_host_buffer(data, shape, None)
                .context("upload i32"),
        }
    }

    /// Execute an artifact on device-resident buffers; returns the output
    /// tuple decomposed into literals.
    pub fn run_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let spec = self.manifest.artifact(name)?;
        anyhow::ensure!(
            args.len() == spec.inputs.len(),
            "{name}: {} args, expected {}",
            args.len(),
            spec.inputs.len()
        );
        *self.exec_count.borrow_mut() += 1;
        let out = exe.execute_b(args).with_context(|| format!("execute {name}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        // artifacts are lowered with return_tuple=True
        let mut lit = lit;
        let parts = lit.decompose_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{name}: {} outputs, expected {}",
            parts.len(),
            spec.outputs.len()
        );
        Ok(parts)
    }

    /// Convenience: execute with host tensors (uploads everything).
    pub fn run(
        &self,
        name: &str,
        args: &[HostTensor],
    ) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|t| self.upload(t))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(name, &refs)
    }
}

/// Extract a scalar f32 from an output literal.
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Extract a `Vec<f32>` from an output literal.
pub fn literal_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
