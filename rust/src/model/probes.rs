//! Downstream-capability probes: the Table 1/3 substitute (DESIGN.md §1).
//!
//! The paper's benchmark battery (PIQA/HellaSwag/Winogrande/GSM8K/MMLU)
//! asks one question: does the quantized model preserve the capabilities
//! of the BF16 baseline? For the in-repo trained models we measure
//! capabilities they actually have:
//!
//! * `top1` / `top5` — held-out next-token accuracy (greedy / @5);
//! * `pref_acc` — accuracy restricted to positions whose context has a
//!   dominant preferred continuation in the generating chain (the
//!   "knowledge recall" analog: these are the learnable facts);
//! * `kl_to_baseline` — mean KL(baseline ‖ quantized) of the next-token
//!   distributions (how much the quantized model drifts from BF16).
//!
//! All are computed from the `logits_bs{N}` artifacts.

/// Aggregated probe metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProbeResult {
    pub top1: f64,
    pub top5: f64,
    pub pref_acc: f64,
    pub kl_to_baseline: f64,
}

/// Streaming accumulator over batches of logits.
#[derive(Debug, Default)]
pub struct ProbeAccum {
    n: u64,
    top1: u64,
    top5: u64,
    pref_n: u64,
    pref_hit: u64,
    kl_sum: f64,
    kl_n: u64,
}

impl ProbeAccum {
    /// `logits`: (batch*seq, vocab) for the quantized model;
    /// `baseline_logits`: same shape from the BF16 run (or empty to skip
    /// the KL probe); `targets`: the true next tokens; `is_pref`: marks
    /// positions with a dominant continuation.
    pub fn add_batch(
        &mut self,
        logits: &[f32],
        baseline_logits: &[f32],
        targets: &[i32],
        is_pref: &[bool],
        vocab: usize,
    ) {
        assert_eq!(logits.len(), targets.len() * vocab);
        let do_kl = !baseline_logits.is_empty();
        for (i, &t) in targets.iter().enumerate() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let t = t as usize;
            let tv = row[t];
            let mut greater = 0usize;
            let mut max = f32::NEG_INFINITY;
            for &v in row {
                if v > tv {
                    greater += 1;
                }
                if v > max {
                    max = v;
                }
            }
            self.n += 1;
            if greater == 0 {
                self.top1 += 1;
            }
            if greater < 5 {
                self.top5 += 1;
            }
            if is_pref[i] {
                self.pref_n += 1;
                if greater == 0 {
                    self.pref_hit += 1;
                }
            }
            if do_kl {
                let brow = &baseline_logits[i * vocab..(i + 1) * vocab];
                self.kl_sum += kl_softmax(brow, row);
                self.kl_n += 1;
            }
        }
    }

    pub fn finish(&self) -> ProbeResult {
        ProbeResult {
            top1: self.top1 as f64 / self.n.max(1) as f64 * 100.0,
            top5: self.top5 as f64 / self.n.max(1) as f64 * 100.0,
            pref_acc: self.pref_hit as f64 / self.pref_n.max(1) as f64
                * 100.0,
            kl_to_baseline: self.kl_sum / self.kl_n.max(1) as f64,
        }
    }
}

/// KL(softmax(p) ‖ softmax(q)) in nats.
pub fn kl_softmax(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    let lse = |x: &[f32]| -> f64 {
        let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        m + x.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln()
    };
    let lp = lse(p_logits);
    let lq = lse(q_logits);
    let mut kl = 0.0;
    for (&a, &b) in p_logits.iter().zip(q_logits) {
        let pa = ((a as f64) - lp).exp();
        if pa > 0.0 {
            kl += pa * (((a as f64) - lp) - ((b as f64) - lq));
        }
    }
    kl.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_zero_for_identical() {
        let p = [1.0f32, 2.0, 0.5];
        assert!(kl_softmax(&p, &p).abs() < 1e-12);
        let q = [1.0f32, 1.0, 1.0];
        assert!(kl_softmax(&p, &q) > 0.0);
    }

    #[test]
    fn probe_accounting() {
        // vocab 4; two positions; logits favour token 0 then token 2
        let logits = [
            5.0f32, 1.0, 0.0, 0.0, // argmax 0
            0.0, 1.0, 5.0, 0.0, // argmax 2
        ];
        let mut acc = ProbeAccum::default();
        acc.add_batch(&logits, &logits, &[0, 1], &[true, false], 4);
        let r = acc.finish();
        assert!((r.top1 - 50.0).abs() < 1e-9); // first hit, second miss
        assert!((r.top5 - 100.0).abs() < 1e-9); // vocab 4 < 5: all hit @5
        assert!((r.pref_acc - 100.0).abs() < 1e-9); // the pref position hit
        assert!(r.kl_to_baseline.abs() < 1e-12);
    }
}
