//! σ-calibrated model zoo: stand-ins for the paper's model suite.
//!
//! Two mechanisms (DESIGN.md §1):
//!
//! 1. **σ-transform** of a trained model: each quantized weight tensor is
//!    stored as w̃ = w/γ with the per-tensor gain γ folded into the
//!    forward pass (`model.py` gains), preserving the learned function
//!    *exactly* while dialing the stored-tensor σ spectrum to match a
//!    target profile. This isolates precisely the statistic the paper
//!    shows drives perplexity inversion.
//! 2. **Weight-tensor ensembles** for the no-runtime experiments
//!    (Figs. 2, 3(a), 6, 7): synthetic tensors whose per-tensor σ values
//!    follow each profile, drawn Normal per Fig. 3(a)'s finding.
//!
//! Profiles are calibrated to the paper's descriptions: granite-3.3-8b
//! (most tensors below the σ ≈ 2e-2 crossover → pronounced inversion),
//! llama-2-7b (most above → no inversion down to bs 8), llama-3.1-8b /
//! mixtral (intermediate → inversion at bs 8), mamba-codestral-7b
//! (ultra-narrow tail), nemotron/bamba (hybrid SSM, wide spread).

use crate::dist::{Ideal, IdealKind, Pcg64};
use crate::model::weights::Params;
use crate::stats;

/// A named σ profile: log10-σ range that per-tensor σ values span.
#[derive(Debug, Clone, Copy)]
pub struct SigmaProfile {
    pub name: &'static str,
    /// log10 bounds of the bulk of the per-tensor σ spectrum
    pub log10_lo: f64,
    pub log10_hi: f64,
    /// fraction of tensors in an extra narrow tail below log10_lo
    pub narrow_tail: f64,
}

/// The model suite of the paper, as σ profiles.
///
/// Calibrated against the theory's UE4M3 block-size crossovers
/// (bs8/16 at σ≈1.8e-2, bs16/32 at 1.6e-2, bs32/64 at 1.2e-2,
/// bs64/128 at 8.8e-3, bs4/8 at 2.1e-2, bs2/4 at 2.8e-2) so each
/// stand-in reproduces the paper's phenomenology: granite sits just
/// below the bs8/16 crossover ("most weights below σ≈2e-2" → clear
/// upswing), llama-2 sits above (monotone down to bs 8, inversion only
/// at bs 2–4 per Fig. 5(b)), llama-3/mixtral straddle it (upswing at
/// bs 8), mamba-codestral carries a genuinely narrow tail (log-scale
/// gaps, Fig. 5(a)) without annihilating the tiny 4-layer model.
pub const PROFILES: [SigmaProfile; 6] = [
    SigmaProfile { name: "granite-like", log10_lo: -2.20, log10_hi: -1.85, narrow_tail: 0.08 },
    SigmaProfile { name: "llama2-like", log10_lo: -1.68, log10_hi: -1.42, narrow_tail: 0.0 },
    SigmaProfile { name: "llama3-like", log10_lo: -2.0, log10_hi: -1.65, narrow_tail: 0.04 },
    SigmaProfile { name: "mixtral-like", log10_lo: -1.88, log10_hi: -1.62, narrow_tail: 0.03 },
    SigmaProfile { name: "mamba-codestral-like", log10_lo: -2.65, log10_hi: -2.0, narrow_tail: 0.12 },
    SigmaProfile { name: "bamba-like", log10_lo: -2.2, log10_hi: -1.5, narrow_tail: 0.08 },
];

pub fn profile(name: &str) -> Option<SigmaProfile> {
    PROFILES.iter().copied().find(|p| p.name == name)
}

impl SigmaProfile {
    /// Sample a per-tensor σ from the profile.
    pub fn sample_sigma(&self, rng: &mut Pcg64) -> f64 {
        let (lo, hi) = if rng.uniform() < self.narrow_tail {
            (self.log10_lo - 1.0, self.log10_lo)
        } else {
            (self.log10_lo, self.log10_hi)
        };
        10f64.powf(lo + (hi - lo) * rng.uniform())
    }

    /// Synthetic weight-tensor ensemble: `count` tensors of `numel`
    /// elements each, Normal with profile-sampled σ (for the
    /// runtime-free MSE experiments).
    pub fn tensor_ensemble(
        &self,
        rng: &mut Pcg64,
        count: usize,
        numel: usize,
    ) -> Vec<Vec<f32>> {
        let normal = Ideal::new(IdealKind::Normal);
        (0..count)
            .map(|_| {
                let sigma = self.sample_sigma(rng);
                normal.tensor_f32(rng, numel, sigma)
            })
            .collect()
    }
}

/// Apply the σ-transform to a trained model: rescale each quantized
/// weight tensor (per layer) so its stored σ matches a profile sample,
/// folding the inverse into the `gains` tensor. Function-preserving up
/// to f32 rounding (~1e-7 relative — orders of magnitude below any
/// quantization effect under study; the integration suite pins the
/// baseline-ppl drift). Exact γ is used rather than a power of two
/// because the crossover-calibrated profile windows are only ~1.8x wide
/// (zoo.rs PROFILES docs), tighter than pow2's ±41% granularity.
pub fn apply_sigma_profile(
    params: &mut Params,
    n_layers: usize,
    prof: &SigmaProfile,
    seed: u64,
) -> Vec<(String, f64, f64)> {
    let mut rng = Pcg64::new(seed ^ 0x5A00_5A00);
    let mut log = Vec::new();
    for (col, name) in Params::QUANTIZED.iter().enumerate() {
        let (_, data) = params.tensors[*name].clone();
        let per_layer = data.len() / n_layers;
        for l in 0..n_layers {
            let t = l * per_layer..(l + 1) * per_layer;
            let cur = stats::std_dev_f32(&data[t.clone()]);
            let target = prof.sample_sigma(&mut rng);
            let gamma = if cur > 0.0 { (cur / target) as f32 } else { 1.0 };
            let w = params.get_mut(name).unwrap();
            for v in &mut w[t] {
                *v /= gamma;
            }
            let gains = params.get_mut("gains").unwrap();
            gains[l * Params::QUANTIZED.len() + col] *= gamma;
            log.push((format!("{name}[{l}]"), cur, cur / gamma as f64));
        }
    }
    log
}

#[allow(dead_code)]
fn pow2_near(x: f64) -> f32 {
    if !(x > 0.0) {
        return 1.0;
    }
    let e = x.log2().round() as i32;
    crate::util::ldexp2(1.0, e.clamp(-60, 60))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_span_the_paper_ranges() {
        let mut rng = Pcg64::new(1);
        let g = profile("granite-like").unwrap();
        let l2 = profile("llama2-like").unwrap();
        let crossover = 2e-2;
        let frac_below = |p: &SigmaProfile, rng: &mut Pcg64| {
            let n = 2000;
            (0..n).filter(|_| p.sample_sigma(rng) < crossover).count()
                as f64
                / n as f64
        };
        assert!(frac_below(&g, &mut rng) > 0.8, "granite mostly below");
        assert!(frac_below(&l2, &mut rng) < 0.2, "llama2 mostly above");
    }

    #[test]
    fn pow2_near_is_power_of_two() {
        for x in [0.1, 0.5, 1.0, 3.7, 100.0] {
            let g = pow2_near(x);
            assert_eq!(g.to_bits() & 0x007F_FFFF, 0);
            let g = g as f64;
            assert!(g / x < 1.5 && x / g < 1.5, "{x} {g}");
        }
    }
}
