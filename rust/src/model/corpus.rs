//! Synthetic Zipf–Markov corpus: the Wikitext2 substitute (DESIGN.md §1).
//!
//! A deterministic order-1.5 Markov language over a 256-token
//! vocabulary: the context is (a mod 8, b) — 2048 states — and each
//! context has a hash-derived preferred-continuation set with sharp
//! geometric weights, mixed with a global Zipf unigram distribution.
//! The state count is sized so the ~0.9 M-parameter in-repo transformer
//! learns the language within a few hundred steps yet has to use real
//! capacity (distributed representations) to do so — which is what
//! makes held-out perplexity *sensitive* to quantization noise, like
//! the paper's near-capacity 8 B models. Train/eval streams come from
//! the same chain with disjoint sampling seeds.

use crate::dist::Pcg64;

/// Corpus generator (the "language" itself is fixed by `lang_seed`).
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    lang_seed: u64,
    /// Zipf unigram CDF over the vocabulary.
    zipf_cdf: Vec<f64>,
    /// mixture weight of the context-preferred continuations
    pref_mass: f64,
    n_pref: usize,
}

fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^ (x >> 33)
}

impl Corpus {
    pub fn new(vocab: usize, lang_seed: u64) -> Corpus {
        // Zipf(s=1.1) unigram marginal over a seed-permuted vocabulary
        let mut weights: Vec<f64> = (0..vocab)
            .map(|r| 1.0 / ((r + 1) as f64).powf(1.1))
            .collect();
        // permute ranks deterministically
        let mut rng = Pcg64::new(lang_seed ^ 0x5EED);
        for i in (1..vocab).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            weights.swap(i, j);
        }
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let zipf_cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Corpus {
            vocab,
            lang_seed,
            zipf_cdf,
            pref_mass: 0.9,
            n_pref: 4,
        }
    }

    /// Default language used across the repo.
    pub fn default_language(vocab: usize) -> Corpus {
        Corpus::new(vocab, 20260710)
    }

    fn zipf_sample(&self, u: f64) -> u32 {
        match self
            .zipf_cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) | Err(i) => (i.min(self.vocab - 1)) as u32,
        }
    }

    /// Hash of a context: order-1.5 — the last token plus 3 bits of the
    /// one before (2048 states).
    fn ctx_hash(&self, a: u32, b: u32) -> u64 {
        let state = ((a & 7) as u64) << 32 | b as u64;
        mix64(self.lang_seed ^ (state + 1).wrapping_mul(0x9E37_79B9))
    }

    /// Next-token sampling given context (a, b).
    fn next(&self, a: u32, b: u32, rng: &mut Pcg64) -> u32 {
        let u = rng.uniform();
        if u < self.pref_mass {
            // geometric over the context's preferred continuations
            let h = self.ctx_hash(a, b);
            // geometric index: P(k) ∝ 0.5^k
            let mut v = u / self.pref_mass;
            let mut k = 0usize;
            let mut p = 0.5;
            while v > p && k + 1 < self.n_pref {
                v -= p;
                p *= 0.5;
                k += 1;
            }
            (mix64(h.wrapping_add(k as u64 * 0x1234_5677)) % self.vocab as u64)
                as u32
        } else {
            self.zipf_sample((u - self.pref_mass) / (1.0 - self.pref_mass))
        }
    }

    /// The chain's most likely continuation of context (a, b) — the k=0
    /// preferred token (probability mass pref_mass/2 = 0.45). Probe
    /// positions where the realized target equals this token measure
    /// "fact recall" (Table 1/3 substitute).
    pub fn top_continuation(&self, a: u32, b: u32) -> i32 {
        let h = self.ctx_hash(a, b);
        (mix64(h) % self.vocab as u64) as i32
    }

    /// Generate a token stream of length `n` from sampling seed `seed`
    /// (train and eval use disjoint seeds over the same language).
    pub fn stream(&self, seed: u64, n: usize) -> Vec<i32> {
        let mut rng = Pcg64::new(self.lang_seed ^ mix64(seed));
        let mut out = Vec::with_capacity(n);
        let mut a = (rng.next_u64() % self.vocab as u64) as u32;
        let mut b = (rng.next_u64() % self.vocab as u64) as u32;
        for _ in 0..n {
            let c = self.next(a, b, &mut rng);
            out.push(c as i32);
            a = b;
            b = c;
        }
        out
    }

    /// Batches of shape (batch, seq+1) flattened row-major, for the loss /
    /// train_step artifacts (input = [:, :-1], target = [:, 1:]).
    pub fn batches(
        &self,
        seed: u64,
        n_batches: usize,
        batch: usize,
        seq_plus_1: usize,
    ) -> Vec<Vec<i32>> {
        let total = n_batches * batch * seq_plus_1;
        let stream = self.stream(seed, total);
        stream
            .chunks(batch * seq_plus_1)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Empirical entropy rate (nats/token) of the chain, estimated by
    /// enumerating next-token distributions over sampled contexts — the
    /// floor a perfect model could reach; useful to sanity-check training.
    pub fn entropy_estimate(&self, contexts: usize) -> f64 {
        let mut rng = Pcg64::new(77);
        let mut h = 0.0;
        for _ in 0..contexts {
            let a = (rng.next_u64() % self.vocab as u64) as u32;
            let b = (rng.next_u64() % self.vocab as u64) as u32;
            // distribution: pref tokens (geometric) + zipf tail
            let mut probs = vec![0.0f64; self.vocab];
            let h64 = self.ctx_hash(a, b);
            let mut p = 0.5;
            for k in 0..self.n_pref {
                let tok = (mix64(h64.wrapping_add(k as u64 * 0x1234_5677))
                    % self.vocab as u64) as usize;
                let w = if k + 1 < self.n_pref {
                    p
                } else {
                    2.0 * p // geometric tail collapses onto the last slot
                };
                probs[tok] += self.pref_mass * w;
                p *= 0.5;
            }
            let mut prev = 0.0;
            for (t, c) in self.zipf_cdf.iter().enumerate() {
                probs[t] += (1.0 - self.pref_mass) * (c - prev);
                prev = *c;
            }
            let total: f64 = probs.iter().sum();
            for q in probs {
                if q > 0.0 {
                    let q = q / total;
                    h -= q * q.ln();
                }
            }
        }
        h / contexts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let c = Corpus::default_language(256);
        assert_eq!(c.stream(1, 100), c.stream(1, 100));
        assert_ne!(c.stream(1, 100), c.stream(2, 100));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::default_language(256);
        assert!(c.stream(3, 5000).iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn language_has_structure() {
        // entropy rate must sit well below uniform ln(256) ≈ 5.55 nats
        let c = Corpus::default_language(256);
        let h = c.entropy_estimate(400);
        assert!(h < 4.0, "entropy {h}");
        assert!(h > 1.0, "entropy {h} suspiciously low");
    }

    #[test]
    fn batch_shapes() {
        let c = Corpus::default_language(256);
        let b = c.batches(5, 3, 4, 129);
        assert_eq!(b.len(), 3);
        assert!(b.iter().all(|x| x.len() == 4 * 129));
    }
}
