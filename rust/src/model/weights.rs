//! Parameter store: ordered tensors matching the AOT manifest, with
//! deterministic initialization, binary (de)serialization, and σ
//! statistics (the per-tensor spectra of Figs. 2(b)/7).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::dist::Pcg64;
use crate::runtime::artifacts::{Manifest, ModelDims, ParamSpec};
use crate::stats;

const MAGIC: &[u8; 8] = b"MSCALE01";

/// Ordered parameter set (order = manifest `param_order`, which is the
/// flattening order of the lowered HLO signature).
#[derive(Debug, Clone)]
pub struct Params {
    pub order: Vec<String>,
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl Params {
    /// Deterministic initialization per the manifest init specs.
    pub fn init(manifest: &Manifest, seed: u64) -> Params {
        Self::init_from_specs(&manifest.param_order, &manifest.params, seed)
    }

    /// Deterministic initialization from an explicit (order, specs) set
    /// — shared by the manifest path and the artifact-free surrogate
    /// path ([`Params::init_surrogate`]).
    pub fn init_from_specs(
        order: &[String],
        specs: &BTreeMap<String, ParamSpec>,
        seed: u64,
    ) -> Params {
        let mut rng = Pcg64::new(seed);
        let mut tensors = BTreeMap::new();
        // iterate in a fixed order so seeds are reproducible
        for name in order {
            let spec = &specs[name];
            let n = spec.numel();
            let data = match spec.init.as_str() {
                "normal" => rng.normal_vec_f32(n, spec.std),
                "ones" => vec![1.0; n],
                _ => vec![0.0; n],
            };
            tensors.insert(name.clone(), (spec.shape.clone(), data));
        }
        Params { order: order.to_vec(), tensors }
    }

    /// The `model.py::init_specs` shape/init table for a dimension set,
    /// built host-side so the serve path needs no AOT artifacts on
    /// disk. Order is the sorted-name `PARAM_ORDER` convention the
    /// manifest uses, so [`Params::init_surrogate`] draws exactly the
    /// same tensors as `Params::init(&manifest, seed)` for matching
    /// dims.
    pub fn surrogate_specs(
        d: &ModelDims,
    ) -> (Vec<String>, BTreeMap<String, ParamSpec>) {
        let (l, dm, f, v, s) =
            (d.n_layers, d.d_model, d.d_ff, d.vocab, d.seq_len);
        let std = 0.02;
        // GPT-2-style residual-out scaling, as in model.py
        let out_std = std / (2.0 * l as f64).sqrt();
        let spec = |shape: Vec<usize>, init: &str, std: f64, decay: bool| {
            ParamSpec { shape, init: init.to_string(), std, decay }
        };
        let mut specs = BTreeMap::new();
        specs.insert("embed".into(), spec(vec![v, dm], "normal", std, true));
        specs.insert("pos".into(), spec(vec![s, dm], "normal", std, true));
        specs.insert("ln1_g".into(), spec(vec![l, dm], "ones", 0.0, false));
        specs.insert("ln1_b".into(), spec(vec![l, dm], "zeros", 0.0, false));
        specs.insert("wq".into(), spec(vec![l, dm, dm], "normal", std, true));
        specs.insert("wk".into(), spec(vec![l, dm, dm], "normal", std, true));
        specs.insert("wv".into(), spec(vec![l, dm, dm], "normal", std, true));
        specs
            .insert("wo".into(), spec(vec![l, dm, dm], "normal", out_std, true));
        specs.insert("ln2_g".into(), spec(vec![l, dm], "ones", 0.0, false));
        specs.insert("ln2_b".into(), spec(vec![l, dm], "zeros", 0.0, false));
        specs.insert("w1".into(), spec(vec![l, dm, f], "normal", std, true));
        specs.insert("w2".into(), spec(vec![l, f, dm], "normal", out_std, true));
        specs.insert("gains".into(), spec(vec![l, 6], "ones", 0.0, false));
        specs.insert("lnf_g".into(), spec(vec![dm], "ones", 0.0, false));
        specs.insert("lnf_b".into(), spec(vec![dm], "zeros", 0.0, false));
        specs.insert("head".into(), spec(vec![dm, v], "normal", std, true));
        // BTreeMap keys iterate sorted — exactly PARAM_ORDER
        let order: Vec<String> = specs.keys().cloned().collect();
        (order, specs)
    }

    /// Initialize a surrogate-transformer parameter set directly from
    /// dimensions (no artifacts needed) — the serve-path entry point.
    pub fn init_surrogate(dims: &ModelDims, seed: u64) -> Params {
        let (order, specs) = Self::surrogate_specs(dims);
        Self::init_from_specs(&order, &specs, seed)
    }

    /// Zero-filled clone with the same shapes (optimizer state).
    pub fn zeros_like(&self) -> Params {
        let tensors = self
            .tensors
            .iter()
            .map(|(k, (s, d))| (k.clone(), (s.clone(), vec![0.0; d.len()])))
            .collect();
        Params { order: self.order.clone(), tensors }
    }

    pub fn get(&self, name: &str) -> Result<(&[usize], &[f32])> {
        self.tensors
            .get(name)
            .map(|(s, d)| (s.as_slice(), d.as_slice()))
            .with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Vec<f32>> {
        self.tensors
            .get_mut(name)
            .map(|(_, d)| d)
            .with_context(|| format!("missing tensor {name:?}"))
    }

    pub fn numel(&self) -> usize {
        self.tensors.values().map(|(_, d)| d.len()).sum()
    }

    /// The weight tensors the model quantizes (per layer), in the gain
    /// vector's column order — matches `model.py::layer` g[0..6].
    pub const QUANTIZED: [&'static str; 6] =
        ["wq", "wk", "wv", "wo", "w1", "w2"];

    /// Highest absolute position these parameters can embed, i.e. the
    /// learned positional table's row count. Autoregressive decode
    /// assigns step `t` of a sequence with an `L`-token prompt the
    /// absolute position `L + t`, so `prompt_len + fed_tokens` must
    /// stay ≤ this bound. The decode layers validate against
    /// `ModelDims::seq_len` (which `PackedModel::build` pins to this
    /// table's size by checking the `pos` element count); this
    /// accessor is the weights-level view, used by the decode bench
    /// and tests to assert the two bounds agree.
    pub fn max_positions(&self) -> Result<usize> {
        let (shape, _) = self.get("pos")?;
        shape
            .first()
            .copied()
            .with_context(|| format!("pos tensor has rank-0 shape {shape:?}"))
    }

    /// Per-(layer, tensor) σ of the stored quantized weight tensors:
    /// the model's σ spectrum (x-axis population of Fig. 2(b)).
    pub fn sigma_spectrum(&self, n_layers: usize) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for name in Self::QUANTIZED {
            if let Some((shape, data)) = self.tensors.get(name) {
                let per_layer = data.len() / n_layers;
                for l in 0..n_layers {
                    let t = &data[l * per_layer..(l + 1) * per_layer];
                    out.push((
                        format!("{name}[{l}] {:?}", &shape[1..]),
                        stats::std_dev_f32(t),
                    ));
                }
            }
        }
        out
    }

    /// Save in a simple self-describing binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("create {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        f.write_all(&(self.order.len() as u32).to_le_bytes())?;
        for name in &self.order {
            let (shape, data) = &self.tensors[name];
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for d in shape {
                f.write_all(&(*d as u32).to_le_bytes())?;
            }
            for v in data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Params> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a microscale params file");
        }
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let count = u32::from_le_bytes(u32buf) as usize;
        let mut order = Vec::with_capacity(count);
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            f.read_exact(&mut u32buf)?;
            let name_len = u32::from_le_bytes(u32buf) as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            f.read_exact(&mut u32buf)?;
            let rank = u32::from_le_bytes(u32buf) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                f.read_exact(&mut u32buf)?;
                shape.push(u32::from_le_bytes(u32buf) as usize);
            }
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            let mut fbuf = [0u8; 4];
            for v in data.iter_mut() {
                f.read_exact(&mut fbuf)?;
                *v = f32::from_le_bytes(fbuf);
            }
            order.push(name.clone());
            tensors.insert(name, (shape, data));
        }
        Ok(Params { order, tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Params {
        let mut tensors = BTreeMap::new();
        tensors.insert("a".to_string(), (vec![2, 3], vec![1.0; 6]));
        tensors
            .insert("b".to_string(), (vec![4], vec![0.5, -0.5, 2.0, 0.0]));
        Params { order: vec!["a".into(), "b".into()], tensors }
    }

    #[test]
    fn save_load_roundtrip() {
        let p = toy();
        let path = std::env::temp_dir().join("microscale_params_test.bin");
        p.save(&path).unwrap();
        let q = Params::load(&path).unwrap();
        assert_eq!(p.order, q.order);
        for k in &p.order {
            assert_eq!(p.tensors[k], q.tensors[k]);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn zeros_like_preserves_shapes() {
        let z = toy().zeros_like();
        assert_eq!(z.numel(), 10);
        assert!(z.tensors.values().all(|(_, d)| d.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn surrogate_init_matches_model_py_table() {
        let dims = ModelDims {
            vocab: 16,
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            seq_len: 4,
        };
        let p = Params::init_surrogate(&dims, 3);
        assert_eq!(p.order.len(), 16);
        // sorted names = the PARAM_ORDER convention
        assert!(p.order.windows(2).all(|w| w[0] < w[1]));
        let (shape, data) = p.get("w2").unwrap();
        assert_eq!(shape, &[2, 16, 8]);
        assert_eq!(data.len(), 2 * 16 * 8);
        assert!(p.get("gains").unwrap().1.iter().all(|&v| v == 1.0));
        assert!(p.get("lnf_b").unwrap().1.iter().all(|&v| v == 0.0));
        // deterministic per seed
        let q = Params::init_surrogate(&dims, 3);
        assert_eq!(p.tensors, q.tensors);
        // residual-out tensors draw at the narrower GPT-2 std
        let wo = stats::std_dev_f32(p.get("wo").unwrap().1);
        let wq = stats::std_dev_f32(p.get("wq").unwrap().1);
        assert!(wo < wq, "wo σ {wo} vs wq σ {wq}");
        // the decode position bound comes from the pos table itself
        assert_eq!(p.max_positions().unwrap(), dims.seq_len);
        assert!(toy().max_positions().is_err()); // no pos tensor
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("microscale_bad_magic.bin");
        std::fs::write(&path, b"NOTMAGIC____").unwrap();
        assert!(Params::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
