//! Model substrate: parameter store, synthetic corpus, σ-calibrated model
//! zoo, and downstream probes.
//!
//! The paper evaluates on 7–9 B-parameter pretrained LLMs that are not
//! available in this sandbox (repro band 0/5); DESIGN.md §1 documents the
//! substitution: small transformers trained in-repo on a synthetic corpus
//! plus a zoo of σ-transformed variants whose *stored-tensor* σ spectra
//! mimic the paper's models (the paper itself shows σ is the driving
//! statistic — Fig. 3(a), App. C).

pub mod corpus;
pub mod probes;
pub mod weights;
pub mod zoo;

pub use corpus::Corpus;
pub use weights::Params;
