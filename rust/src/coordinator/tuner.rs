//! The mixed-precision auto-tuner behind `microscale tune` (DESIGN.md
//! §16): an offline per-layer search over {element format × scale
//! format × block size × Hadamard rotation} against a weight-byte
//! budget, scored by **measured** per-layer quantization error on
//! calibration activations and cross-checked against the
//! [`crate::theory`] Gaussian predictions.
//!
//! # Objective and search
//!
//! Every layer family (the 6 linears sharing one [`QConfig`] per
//! layer) gets a candidate table: exact wire bytes
//! ([`crate::quant::GemmOperand::payload_bytes`] summed over the
//! layer's weights) and measured error (`‖X·(Q(W) − W)‖²`, the
//! GPTQ-style weight-reconstruction proxy, on exact activations
//! captured from an exact forward —
//! [`crate::serve::packed_model::capture_linear_inputs`]; see
//! [`measure_tables`] for why the activations stay exact). The search
//! minimizes total error subject to `Σ bytes ≤ budget` by a Lagrangian
//! sweep: for a multiplier λ every layer independently picks
//! `argmin err + λ·bytes` (ties → fewer bytes, then lower candidate
//! index); λ runs over every pairwise error/byte slope in ascending
//! order and the first feasible λ wins. Per-layer bytes are
//! non-increasing and per-layer error non-decreasing in λ (the
//! classic exchange argument), so the result is **deterministic**,
//! always within budget, and **monotone**: a larger budget never
//! yields higher total predicted error — the properties
//! `rust/tests/tuner.rs` pins.
//!
//! # Why rotation moves the block-size optimum
//!
//! Under quantized scales a block whose absmax falls below
//! `elem_max · s_min / 2` collapses to zero (the paper's `s_zero`
//! term), and smaller blocks have smaller absmaxes — the block-size
//! anomaly. The FWHT pre-rotation ([`crate::quant::rotate`]) replaces
//! each channel's σ with the tensor RMS, lifting narrow channels out
//! of the collapse region; once no block collapses, finer blocks are
//! strictly better again, so the tuner's chosen block size drops. The
//! [`demo_model`] weights make this observable in vivo: contraction
//! channels mix a narrow anomaly-regime σ with a sparse wide
//! population, per layer.

use std::path::PathBuf;

use anyhow::{bail, ensure, Context};

use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::quant::error::tensor_mse;
use crate::quant::gemm::GemmOperand;
use crate::quant::matmul::{matmul_t, transpose};
use crate::quant::rotate::{fwht_rows, fwht_rows_transposed};
use crate::quant::{QuantKernel, ScalarKernel};
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::serve::cache::OperandCache;
use crate::serve::packed_model::{capture_linear_inputs, PackedModel};
use crate::stats;
use crate::theory;
use crate::util::json::{self, Json};

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct TuneOpts {
    /// CI-sized run: tiny model, `pass: null`.
    pub smoke: bool,
    /// Report path (`BENCH_tune.json` in the working directory).
    pub out: PathBuf,
    /// Emitted per-layer config consumed by the benches'
    /// `--qconfig-file` flag.
    pub emit: PathBuf,
    /// Seed for the demo weights, calibration tokens, and the theory
    /// cross-check tensors.
    pub seed: u64,
    /// Byte budget as a fraction interpolating the cheapest → most
    /// expensive uniform candidate (ignored when `budget_bytes` set).
    pub budget_frac: f64,
    /// Absolute weight-byte budget.
    pub budget_bytes: Option<usize>,
    /// Element-format axis (names for [`QConfig::named`]).
    pub elems: Vec<String>,
    /// Scale-format axis.
    pub scales: Vec<String>,
    /// Block-size axis (sizes not dividing both d_model and d_ff are
    /// dropped).
    pub block_sizes: Vec<usize>,
    /// Include rotated variants of every candidate.
    pub rotate: bool,
    /// Calibration sequences (each `dims.seq_len` tokens).
    pub calib_batch: usize,
    /// Cap on calibration rows per linear when measuring error.
    pub max_calib_rows: usize,
    /// Relative-MSE tolerance for the KV codec choice.
    pub kv_tol: f64,
}

impl TuneOpts {
    pub fn new(smoke: bool) -> TuneOpts {
        TuneOpts {
            smoke,
            out: PathBuf::from("BENCH_tune.json"),
            emit: PathBuf::from("tuned_qconfig.json"),
            seed: 7,
            budget_frac: 0.5,
            budget_bytes: None,
            elems: vec!["fp4_e2m1".into(), "fp8_e4m3".into()],
            scales: vec!["ue4m3".into(), "ue5m3".into(), "e8m0".into()],
            block_sizes: vec![8, 16, 32],
            rotate: true,
            calib_batch: 2,
            max_calib_rows: if smoke { 64 } else { 128 },
            kv_tol: 2e-3,
        }
    }
}

/// Tuning model shapes (the serve/decode bench shapes, so emitted
/// configs drop straight into those drivers).
pub fn demo_dims(smoke: bool) -> ModelDims {
    if smoke {
        ModelDims {
            vocab: 64,
            d_model: 64,
            n_heads: 2,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        }
    } else {
        ModelDims {
            vocab: 256,
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            d_ff: 512,
            seq_len: 128,
        }
    }
}

/// Per-contraction-channel σ profile of the demo weights for `layer`:
/// `(narrow σ, wide σ, wide-channel count out of k)`. Even layers sit
/// in the anomaly regime (σ ≈ 2.8e-3: a fine block's expected absmax
/// `≈1.43σ ≈ 4e-3` sits well under the UE4M3 collapse threshold
/// `6·2⁻⁹/2 ≈ 5.9e-3`, so most fine narrow blocks collapse, while a
/// 32-wide block's `≈2.1σ ≈ 5.9e-3` straddles it — coarse blocks keep
/// roughly half the narrow mass alive); odd layers are
/// benign — the layer heterogeneity that makes a *mixed* assignment
/// beat every uniform one.
pub fn demo_sigma_profile(layer: usize, k: usize) -> (f64, f64, usize) {
    let narrow = if layer % 2 == 0 { 2.8e-3 } else { 1.5e-2 };
    (narrow, 6.0e-2, (k / 8).max(1))
}

/// The tuning surrogate: [`Params::init_surrogate`] with every
/// quantized weight regenerated under [`demo_sigma_profile`] — the
/// first `wide` contraction channels (rows of the row-major `k × n`
/// slice) at the wide σ, the rest at the layer's narrow σ. Rotating
/// the contraction dimension mixes the two populations into a uniform
/// effective σ ≈ RMS, which is what moves the block-size optimum.
pub fn demo_model(dims: &ModelDims, seed: u64) -> crate::Result<Params> {
    let mut params = Params::init_surrogate(dims, seed);
    for (which, name) in Params::QUANTIZED.iter().enumerate() {
        for layer in 0..dims.n_layers {
            let (k, n) = linear_dims(dims, which);
            let (narrow, wide, wide_rows) = demo_sigma_profile(layer, k);
            let mut rng = Pcg64::new(
                seed ^ (0x9e37_79b9_7f4a_7c15u64
                    .wrapping_mul((layer * 6 + which) as u64 + 1)),
            );
            let fresh = rng.normal_vec_f32(k * n, 1.0);
            let data = params.get_mut(name)?;
            let base = layer * k * n;
            for r in 0..k {
                let s = if r < wide_rows { wide } else { narrow } as f32;
                for c in 0..n {
                    data[base + r * n + c] = fresh[r * n + c] * s;
                }
            }
        }
    }
    Ok(params)
}

/// Contraction/output dims of quantized linear `which`
/// ([`Params::QUANTIZED`] order — mirrors the serve layer's map).
fn linear_dims(dims: &ModelDims, which: usize) -> (usize, usize) {
    let (d, f) = (dims.d_model, dims.d_ff);
    match which {
        4 => (d, f),
        5 => (f, d),
        _ => (d, d),
    }
}

/// The candidate grid: every element × scale × block size (filtered to
/// sizes dividing both model dims), optionally doubled with rotated
/// variants. Every candidate carries its block size as a
/// [`QConfig::bs_override`], so one [`PerLayerQConfig`] can mix them.
pub fn candidate_space(
    dims: &ModelDims,
    elems: &[String],
    scales: &[String],
    block_sizes: &[usize],
    rotate: bool,
) -> crate::Result<Vec<QConfig>> {
    let mut out = Vec::new();
    for e in elems {
        for s in scales {
            for &bs in block_sizes {
                if bs == 0
                    || dims.d_model % bs != 0
                    || dims.d_ff % bs != 0
                {
                    continue;
                }
                let cfg = QConfig::named(e, s, false)?.with_block_size(bs);
                out.push(cfg);
                if rotate {
                    out.push(cfg.with_rotate(true));
                }
            }
        }
    }
    ensure!(!out.is_empty(), "empty candidate space");
    Ok(out)
}

/// Deterministic calibration set: seeded uniform tokens through an
/// exact forward, captured at every quantized linear's input.
pub fn calibration(
    params: &Params,
    dims: &ModelDims,
    seed: u64,
    batch: usize,
) -> crate::Result<Vec<Vec<f32>>> {
    let mut rng = Pcg64::new(seed ^ 0xca11);
    let seq = dims.seq_len;
    let tokens: Vec<i32> = (0..batch * seq)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect();
    capture_linear_inputs(params, dims, &tokens, batch, seq)
}

/// Per-layer candidate tables: `bytes[l][c]` is the exact packed wire
/// cost of layer `l` under candidate `c`, `err[l][c]` the measured sum
/// of squared output error over the layer's 6 linears.
pub struct LayerTables {
    pub cands: Vec<QConfig>,
    pub bytes: Vec<Vec<usize>>,
    pub err: Vec<Vec<f64>>,
}

impl LayerTables {
    /// Total bytes of candidate `c` applied uniformly to every layer.
    pub fn uniform_bytes(&self, c: usize) -> usize {
        self.bytes.iter().map(|row| row[c]).sum()
    }

    /// `(min, max)` over candidates of [`LayerTables::uniform_bytes`].
    pub fn uniform_bytes_range(&self) -> (usize, usize) {
        let totals: Vec<usize> =
            (0..self.cands.len()).map(|c| self.uniform_bytes(c)).collect();
        (
            totals.iter().copied().min().unwrap_or(0),
            totals.iter().copied().max().unwrap_or(0),
        )
    }
}

/// Measure every (layer, candidate) cell on the calibration captures.
///
/// The score is the classic PTQ proxy (GPTQ/AWQ lineage):
/// `‖X·(Q(W) − W)‖²` summed over the layer's 6 linears, with `X` the
/// **exact** calibration activations (first `max_rows` rows) — in the
/// rotated basis (`‖XH·(Q(HW) − HW)‖²`) when the candidate rotates.
/// Holding the activations exact matters: activation quantization
/// error is borne by every candidate at runtime and mostly cancels in
/// the comparison, but its per-sample noise is large enough to swamp
/// the weight-side block-size signal the search exists to resolve —
/// scoring the weight reconstruction alone is what makes the choice
/// (and the pinned rotation-flip property) deterministic at
/// calibration sizes a test can afford.
pub fn measure_tables(
    params: &Params,
    dims: &ModelDims,
    calib: &[Vec<f32>],
    cands: &[QConfig],
    block_size: usize,
    max_rows: usize,
) -> crate::Result<LayerTables> {
    ensure!(
        calib.len() == dims.n_layers * 6,
        "{} captures for {} linears",
        calib.len(),
        dims.n_layers * 6
    );
    let kernel = ScalarKernel;
    let mut bytes = vec![vec![0usize; cands.len()]; dims.n_layers];
    let mut err = vec![vec![0f64; cands.len()]; dims.n_layers];
    for layer in 0..dims.n_layers {
        for (which, name) in Params::QUANTIZED.iter().enumerate() {
            let (k, n) = linear_dims(dims, which);
            let data = params.get(name)?.1;
            let w = &data[layer * k * n..(layer + 1) * k * n];
            let x_all = &calib[layer * 6 + which];
            let total_rows = x_all.len() / k;
            ensure!(total_rows > 0, "empty calibration for {name} L{layer}");
            let rows = total_rows.min(max_rows.max(1));
            let x = &x_all[..rows * k];
            let wt = transpose(w, k, n);
            // rotated operands shared by every rotated candidate
            let mut xr = x.to_vec();
            fwht_rows(&mut xr, k);
            let mut wtr = wt.clone();
            fwht_rows_transposed(&mut wtr, k);
            for (c, cand) in cands.iter().enumerate() {
                let scheme = cand.scheme(block_size);
                ensure!(
                    k % scheme.block_size == 0,
                    "candidate bs {} does not divide k {k}",
                    scheme.block_size
                );
                let (xs, ws): (&[f32], &[f32]) = if cand.rotate {
                    (&xr, &wtr)
                } else {
                    (x, &wt)
                };
                // ΔW in the candidate's basis, then ‖X·ΔW‖² — exact
                // activations, see the function docs
                let mut dwt = kernel.fake_quant(&scheme, ws);
                for (d, orig) in dwt.iter_mut().zip(ws) {
                    *d -= orig;
                }
                let dy = matmul_t(xs, &dwt, rows, k, n);
                err[layer][c] +=
                    dy.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>();
                bytes[layer][c] +=
                    GemmOperand::quantize_transposed(&scheme, w, k, n)?
                        .payload_bytes();
            }
        }
    }
    Ok(LayerTables { cands: cands.to_vec(), bytes, err })
}

/// One search outcome: the assembled per-layer config plus its exact
/// byte/error accounting.
#[derive(Debug, Clone)]
pub struct Chosen {
    pub qcfg: PerLayerQConfig,
    /// Candidate index per layer.
    pub picks: Vec<usize>,
    pub total_bytes: usize,
    pub total_err: f64,
    /// The winning Lagrange multiplier.
    pub lambda: f64,
}

/// The Lagrangian budget search (module docs): smallest λ whose
/// per-layer `argmin err + λ·bytes` selection fits the budget.
/// Deterministic, and monotone in `budget` by the exchange argument.
pub fn search(t: &LayerTables, budget: usize) -> crate::Result<Chosen> {
    let nl = t.err.len();
    ensure!(nl > 0 && !t.cands.is_empty(), "empty tables");
    let pick = |lam: f64| -> (Vec<usize>, usize, f64) {
        let mut picks = Vec::with_capacity(nl);
        let (mut tb, mut te) = (0usize, 0f64);
        for l in 0..nl {
            let mut best = 0usize;
            for c in 1..t.cands.len() {
                let sc = t.err[l][c] + lam * t.bytes[l][c] as f64;
                let sb = t.err[l][best] + lam * t.bytes[l][best] as f64;
                if sc < sb || (sc == sb && t.bytes[l][c] < t.bytes[l][best]) {
                    best = c;
                }
            }
            picks.push(best);
            tb += t.bytes[l][best];
            te += t.err[l][best];
        }
        (picks, tb, te)
    };
    // λ breakpoints: every pairwise positive error/byte trade slope
    let mut lams = vec![0.0f64];
    for l in 0..nl {
        for i in 0..t.cands.len() {
            for j in 0..t.cands.len() {
                let (bi, bj) = (t.bytes[l][i], t.bytes[l][j]);
                let (ei, ej) = (t.err[l][i], t.err[l][j]);
                if bi > bj && ej > ei {
                    lams.push((ej - ei) / (bi - bj) as f64);
                }
            }
        }
    }
    lams.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lams.dedup();
    for &lam in &lams {
        let (picks, tb, te) = pick(lam);
        if tb <= budget {
            return Ok(assemble(t, picks, tb, te, lam));
        }
    }
    // λ → ∞: the per-layer minimum-byte selection (ties → lower error)
    let mut picks = Vec::with_capacity(nl);
    let (mut tb, mut te) = (0usize, 0f64);
    for l in 0..nl {
        let mut best = 0usize;
        for c in 1..t.cands.len() {
            if t.bytes[l][c] < t.bytes[l][best]
                || (t.bytes[l][c] == t.bytes[l][best]
                    && t.err[l][c] < t.err[l][best])
            {
                best = c;
            }
        }
        picks.push(best);
        tb += t.bytes[l][best];
        te += t.err[l][best];
    }
    if tb <= budget {
        return Ok(assemble(t, picks, tb, te, f64::INFINITY));
    }
    bail!(
        "budget {budget} bytes infeasible: the cheapest per-layer \
         assignment needs {tb} bytes"
    )
}

fn assemble(
    t: &LayerTables,
    picks: Vec<usize>,
    total_bytes: usize,
    total_err: f64,
    lambda: f64,
) -> Chosen {
    let mut qcfg = PerLayerQConfig::uniform(t.cands[picks[0]]);
    for (l, &p) in picks.iter().enumerate().skip(1) {
        if t.cands[p] != t.cands[picks[0]] {
            qcfg = qcfg.with_override(l, t.cands[p]);
        }
    }
    Chosen { qcfg, picks, total_bytes, total_err, lambda }
}

/// Measured-vs-predicted agreement for one chosen cell: a seeded
/// Gaussian at the layer's (rotated, when the candidate rotates)
/// weight σ, fake-quantized under the candidate's scheme, against
/// [`theory::mse_quantized_scales`].
#[derive(Debug, Clone)]
pub struct AgreementRow {
    pub layer: usize,
    pub id: String,
    pub sigma: f64,
    pub measured: f64,
    pub predicted: f64,
    pub ratio: f64,
}

/// Cross-check every chosen per-layer config against the paper's
/// closed-form Gaussian MSE. The check runs on seeded Gaussians at the
/// matched σ — host-independent and distribution-matched to the theory
/// (the demo weights themselves are deliberately *non*-Gaussian; their
/// deviation is the rotation story, not a regression signal).
pub fn theory_agreement(
    params: &Params,
    dims: &ModelDims,
    chosen: &Chosen,
    block_size: usize,
    seed: u64,
) -> crate::Result<Vec<AgreementRow>> {
    let mut rows = Vec::new();
    for layer in 0..chosen.picks.len() {
        let cfg = chosen.qcfg.layer(layer);
        let (k, n) = linear_dims(dims, 4); // w1: the widest d_model fan-out
        let data = params.get("w1")?.1;
        let w = &data[layer * k * n..(layer + 1) * k * n];
        let sigma = if cfg.rotate {
            let mut wt = transpose(w, k, n);
            fwht_rows_transposed(&mut wt, k);
            stats::std_dev_f32(&wt)
        } else {
            stats::std_dev_f32(w)
        };
        let scheme = cfg.scheme(block_size);
        let mut rng = Pcg64::new(seed ^ 0x7e0 ^ ((layer as u64) << 8));
        let gauss = rng.normal_vec_f32(1 << 16, sigma);
        let measured = tensor_mse(&scheme, &gauss);
        let predicted = theory::mse_quantized_scales(
            &cfg.elem,
            &cfg.scale,
            sigma,
            scheme.block_size,
        )
        .total();
        let ratio = if predicted > 0.0 { measured / predicted } else { f64::NAN };
        rows.push(AgreementRow {
            layer,
            id: cfg.id(),
            sigma,
            measured,
            predicted,
            ratio,
        });
    }
    Ok(rows)
}

/// End-to-end mean squared logits error of `qcfg` against the exact
/// (quantization-off) forward, on seeded tokens.
pub fn e2e_logits_mse(
    params: &Params,
    dims: &ModelDims,
    qcfg: &PerLayerQConfig,
    block_size: usize,
    exact_logits: &[f32],
    tokens: &[i32],
    batch: usize,
    cache: &OperandCache,
) -> crate::Result<f64> {
    let model = PackedModel::build(dims, params, qcfg, block_size, cache)?;
    let got = model.forward(tokens, batch, dims.seq_len)?;
    ensure!(got.len() == exact_logits.len(), "logits shape mismatch");
    Ok(stats::mse_f32(exact_logits, &got))
}

/// The KV-codec leg of the search: relative MSE of each page codec on
/// the calibration K/V rows (the wk/wv linear outputs), cheapest codec
/// within `tol` wins. Returns `(chosen id or "none", per-codec rel
/// MSE)`.
pub fn choose_kv_codec(
    params: &Params,
    dims: &ModelDims,
    calib: &[Vec<f32>],
    block_size: usize,
    max_rows: usize,
    tol: f64,
) -> crate::Result<(String, Vec<(String, f64)>)> {
    // K/V rows for every layer: exact outputs of wk (which=1), wv (=2)
    let mut rows_all: Vec<f32> = Vec::new();
    for layer in 0..dims.n_layers {
        for which in [1usize, 2] {
            let (k, n) = linear_dims(dims, which);
            let data = params.get(Params::QUANTIZED[which])?.1;
            let w = &data[layer * k * n..(layer + 1) * k * n];
            let x_all = &calib[layer * 6 + which];
            let rows = (x_all.len() / k).min(max_rows.max(1));
            let wt = transpose(w, k, n);
            rows_all.extend(matmul_t(&x_all[..rows * k], &wt, rows, k, n));
        }
    }
    let energy: f64 =
        rows_all.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>()
            / rows_all.len() as f64;
    ensure!(energy > 0.0, "degenerate calibration K/V rows");
    // cheapest-first candidate order; "none" (exact f32) is the backstop
    let codecs = ["fp4_e2m1/ue5m3", "fp8_e4m3/ue5m3"];
    let kernel = ScalarKernel;
    let mut scored = Vec::new();
    let mut chosen = "none".to_string();
    for id in codecs {
        let cfg = QConfig::parse(id)?;
        let scheme = cfg.scheme(block_size);
        let q = kernel.fake_quant(&scheme, &rows_all);
        let rel = stats::mse_f32(&rows_all, &q) / energy;
        scored.push((id.to_string(), rel));
        if chosen == "none" && rel <= tol {
            chosen = id.to_string();
        }
    }
    scored.push(("none".to_string(), 0.0));
    Ok((chosen, scored))
}

/// Run the full tuning loop and write `BENCH_tune.json` + the emitted
/// config file. Returns the report.
pub fn run(opts: &TuneOpts) -> crate::Result<Json> {
    let dims = demo_dims(opts.smoke);
    let block_size = if opts.smoke { 16 } else { 32 };
    println!(
        "== microscale tune: {} layers, d_model {}, d_ff {}, seed {} ==",
        dims.n_layers, dims.d_model, dims.d_ff, opts.seed
    );
    let params = demo_model(&dims, opts.seed)?;
    let calib = calibration(&params, &dims, opts.seed, opts.calib_batch)?;
    let cands = candidate_space(
        &dims,
        &opts.elems,
        &opts.scales,
        &opts.block_sizes,
        opts.rotate,
    )?;
    println!(
        "   {} candidates/layer ({} with rotation axis)",
        cands.len(),
        if opts.rotate { "doubled" } else { "not doubled" }
    );
    let tables = measure_tables(
        &params,
        &dims,
        &calib,
        &cands,
        block_size,
        opts.max_calib_rows,
    )?;
    let (min_b, max_b) = tables.uniform_bytes_range();
    let budget = opts.budget_bytes.unwrap_or_else(|| {
        let f = opts.budget_frac.clamp(0.0, 1.0);
        min_b + ((max_b - min_b) as f64 * f) as usize
    });
    println!(
        "   uniform bytes span {min_b}..{max_b}; budget {budget} bytes"
    );
    let chosen = search(&tables, budget)?;
    ensure!(
        chosen.total_bytes <= budget,
        "search exceeded its own budget: {} > {budget}",
        chosen.total_bytes
    );
    println!(
        "   chosen {} ({} bytes, predicted err {:.3e})",
        chosen.qcfg.id(),
        chosen.total_bytes,
        chosen.total_err
    );

    // The rotation-flip diagnostic, on the UE4M3 sub-axis where the
    // block-size anomaly lives (UE5M3/E8M0 scales rescue narrow
    // channels without rotation — the paper's Sec. 5.2 finding — so
    // the full axis would mask the effect the diagnostic pins): with
    // an unconstrained budget, does making rotation available move
    // some layer's chosen block size strictly downward?
    let diag_scales = vec!["ue4m3".to_string()];
    let diag_elems = vec!["fp4_e2m1".to_string()];
    let diag_rot = candidate_space(
        &dims,
        &diag_elems,
        &diag_scales,
        &opts.block_sizes,
        true,
    )?;
    let diag_norot: Vec<QConfig> =
        diag_rot.iter().copied().filter(|c| !c.rotate).collect();
    let t_diag_rot = measure_tables(
        &params,
        &dims,
        &calib,
        &diag_rot,
        block_size,
        opts.max_calib_rows,
    )?;
    let t_diag_norot = measure_tables(
        &params,
        &dims,
        &calib,
        &diag_norot,
        block_size,
        opts.max_calib_rows,
    )?;
    let open_budget = usize::MAX / 2;
    let diag_chosen = search(&t_diag_rot, open_budget)?;
    let diag_chosen_norot = search(&t_diag_norot, open_budget)?;
    let mut flip_layers = Vec::new();
    for l in 0..dims.n_layers {
        let b_rot =
            diag_chosen.qcfg.layer(l).effective_block_size(block_size);
        let b_no =
            diag_chosen_norot.qcfg.layer(l).effective_block_size(block_size);
        if b_rot < b_no {
            flip_layers.push(l);
        }
    }
    let rotation_flips = !flip_layers.is_empty();
    println!(
        "   ue4m3 diagnostic: rotation off {} / on {} — rotation shrinks \
         block size on layers {flip_layers:?}",
        diag_chosen_norot.qcfg.id(),
        diag_chosen.qcfg.id()
    );

    // theory cross-check on the chosen cells
    let agreement =
        theory_agreement(&params, &dims, &chosen, block_size, opts.seed)?;
    let band = (0.5, 2.0);
    let agreement_ok = agreement
        .iter()
        .all(|r| r.ratio.is_finite() && r.ratio >= band.0 && r.ratio <= band.1);

    // end-to-end logits error vs the best uniform config at equal bytes
    let cache = OperandCache::new(64);
    let mut rng = Pcg64::new(opts.seed ^ 0xe2e);
    let batch = opts.calib_batch.max(1);
    let tokens: Vec<i32> = (0..batch * dims.seq_len)
        .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
        .collect();
    let exact_cfg = PerLayerQConfig::uniform(QConfig::baseline());
    let exact_model =
        PackedModel::build(&dims, &params, &exact_cfg, block_size, &cache)?;
    let exact_logits = exact_model.forward(&tokens, batch, dims.seq_len)?;
    let tuned_mse = e2e_logits_mse(
        &params,
        &dims,
        &chosen.qcfg,
        block_size,
        &exact_logits,
        &tokens,
        batch,
        &cache,
    )?;
    let mut best_uniform: Option<(String, usize, f64)> = None;
    for (c, cand) in tables.cands.iter().enumerate() {
        let ub = tables.uniform_bytes(c);
        if ub > budget {
            continue;
        }
        let mse = e2e_logits_mse(
            &params,
            &dims,
            &PerLayerQConfig::uniform(*cand),
            block_size,
            &exact_logits,
            &tokens,
            batch,
            &cache,
        )?;
        let better = match &best_uniform {
            None => true,
            Some((_, _, m)) => mse < *m,
        };
        if better {
            best_uniform = Some((cand.id(), ub, mse));
        }
    }
    let Some((uni_id, uni_bytes, uni_mse)) = best_uniform else {
        bail!("no uniform candidate fits the {budget}-byte budget");
    };
    let beats_uniform = tuned_mse < uni_mse;
    println!(
        "   e2e logits MSE: tuned {tuned_mse:.3e} vs best uniform \
         {uni_id} {uni_mse:.3e} ({} bytes)",
        uni_bytes
    );

    // KV codec leg
    let (kv_chosen, kv_scored) = choose_kv_codec(
        &params,
        &dims,
        &calib,
        block_size,
        opts.max_calib_rows,
        opts.kv_tol,
    )?;
    println!("   kv codec: {kv_chosen}");

    // the emitted config file the benches consume via --qconfig-file
    let emitted = json::obj(vec![
        ("qconfig", json::s(&chosen.qcfg.id())),
        ("block_size", json::num(block_size as f64)),
        ("kv", json::s(&kv_chosen)),
        ("budget_bytes", json::num(budget as f64)),
        ("payload_bytes", json::num(chosen.total_bytes as f64)),
        ("seed", json::num(opts.seed as f64)),
    ]);
    std::fs::write(&opts.emit, emitted.to_string())
        .with_context(|| format!("writing {}", opts.emit.display()))?;
    println!("   wrote {}", opts.emit.display());

    let per_layer = json::arr((0..dims.n_layers).map(|l| {
        let cfg = chosen.qcfg.layer(l);
        let p = chosen.picks[l];
        let ar = &agreement[l];
        json::obj(vec![
            ("layer", json::num(l as f64)),
            ("id", json::s(&cfg.id())),
            (
                "block_size",
                json::num(cfg.effective_block_size(block_size) as f64),
            ),
            ("rotate", Json::Bool(cfg.rotate)),
            ("bytes", json::num(tables.bytes[l][p] as f64)),
            ("measured_err", json::num(tables.err[l][p])),
            ("sigma", json::num(ar.sigma)),
            ("gauss_measured_mse", json::num(ar.measured)),
            ("theory_predicted_mse", json::num(ar.predicted)),
            ("agreement_ratio", json::num(ar.ratio)),
            (
                "diag_block_size_rot",
                json::num(
                    diag_chosen.qcfg.layer(l).effective_block_size(block_size)
                        as f64,
                ),
            ),
            (
                "diag_block_size_norot",
                json::num(
                    diag_chosen_norot
                        .qcfg
                        .layer(l)
                        .effective_block_size(block_size)
                        as f64,
                ),
            ),
        ])
    }));
    let budget_ok = chosen.total_bytes <= budget;
    let pass = budget_ok
        && agreement_ok
        && rotation_flips
        && beats_uniform;
    let report = json::obj(vec![
        ("bench", json::s("tune")),
        (
            "dims",
            json::obj(vec![
                ("d_model", json::num(dims.d_model as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("vocab", json::num(dims.vocab as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
            ]),
        ),
        ("smoke", Json::Bool(opts.smoke)),
        ("seed", json::num(opts.seed as f64)),
        ("block_size", json::num(block_size as f64)),
        (
            "axis",
            json::obj(vec![
                ("elems", json::arr(opts.elems.iter().map(|e| json::s(e)))),
                ("scales", json::arr(opts.scales.iter().map(|e| json::s(e)))),
                (
                    "block_sizes",
                    json::arr(
                        opts.block_sizes.iter().map(|&b| json::num(b as f64)),
                    ),
                ),
                ("rotate", Json::Bool(opts.rotate)),
                ("candidates_per_layer", json::num(cands.len() as f64)),
            ]),
        ),
        ("budget_bytes", json::num(budget as f64)),
        ("uniform_bytes_min", json::num(min_b as f64)),
        ("uniform_bytes_max", json::num(max_b as f64)),
        ("payload_bytes", json::num(chosen.total_bytes as f64)),
        ("budget_respected", Json::Bool(budget_ok)),
        ("qconfig", json::s(&chosen.qcfg.id())),
        ("total_predicted_err", json::num(chosen.total_err)),
        (
            "lambda",
            if chosen.lambda.is_finite() {
                json::num(chosen.lambda)
            } else {
                Json::Null
            },
        ),
        ("per_layer", per_layer),
        (
            "agreement",
            json::obj(vec![
                ("band_lo", json::num(band.0)),
                ("band_hi", json::num(band.1)),
                ("ok", Json::Bool(agreement_ok)),
            ]),
        ),
        (
            "rotation_diagnostic",
            json::obj(vec![
                ("axis", json::s("fp4_e2m1 x ue4m3, open budget")),
                ("with_rotation", json::s(&diag_chosen.qcfg.id())),
                ("without_rotation", json::s(&diag_chosen_norot.qcfg.id())),
                ("err_with", json::num(diag_chosen.total_err)),
                ("err_without", json::num(diag_chosen_norot.total_err)),
            ]),
        ),
        ("rotation_flips_block_size", Json::Bool(rotation_flips)),
        (
            "flip_layers",
            json::arr(flip_layers.iter().map(|&l| json::num(l as f64))),
        ),
        (
            "e2e",
            json::obj(vec![
                ("tuned_logits_mse", json::num(tuned_mse)),
                ("best_uniform", json::s(&uni_id)),
                ("best_uniform_bytes", json::num(uni_bytes as f64)),
                ("best_uniform_logits_mse", json::num(uni_mse)),
                ("beats_uniform", Json::Bool(beats_uniform)),
                (
                    "improvement",
                    if tuned_mse > 0.0 {
                        json::num(uni_mse / tuned_mse)
                    } else {
                        Json::Null
                    },
                ),
            ]),
        ),
        (
            "kv",
            json::obj(vec![
                ("chosen", json::s(&kv_chosen)),
                ("tol", json::num(opts.kv_tol)),
                (
                    "rel_mse",
                    json::obj_owned(
                        kv_scored
                            .iter()
                            .map(|(id, r)| (id.clone(), json::num(*r)))
                            .collect(),
                    ),
                ),
            ]),
        ),
        // smoke shapes are too small for the flip/improvement physics
        // to be a stable verdict; the deterministic budget and
        // agreement gates are still enforced by CI on smoke
        (
            "pass",
            if opts.smoke { Json::Null } else { Json::Bool(pass) },
        ),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}

/// Parse an emitted config file (`--qconfig-file`): returns
/// `(label, per-layer config, global block size, kv codec id)`.
pub fn load_qconfig_file(
    path: &std::path::Path,
) -> crate::Result<(String, PerLayerQConfig, usize, String)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text)?;
    let qcfg = PerLayerQConfig::parse(j.get("qconfig")?.as_str()?)?;
    let block_size = j.get("block_size")?.as_usize()?;
    ensure!(block_size > 0, "block_size must be positive");
    let kv = match j.opt("kv") {
        Some(v) => v.as_str()?.to_string(),
        None => "none".to_string(),
    };
    Ok(("tuned".to_string(), qcfg, block_size, kv))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_tables() -> LayerTables {
        // two layers, three candidates: cheap/bad, mid, expensive/good
        let cands = vec![
            QConfig::fp4("ue4m3").unwrap().with_block_size(32),
            QConfig::fp4("ue4m3").unwrap().with_block_size(16),
            QConfig::fp4("ue4m3").unwrap().with_block_size(8),
        ];
        LayerTables {
            cands,
            bytes: vec![vec![100, 110, 130], vec![200, 220, 260]],
            err: vec![vec![9.0, 4.0, 1.0], vec![30.0, 12.0, 2.0]],
        }
    }

    #[test]
    fn search_respects_budget_and_is_monotone() {
        let t = synth_tables();
        let mut last_err = f64::INFINITY;
        for budget in [300usize, 320, 340, 360, 390, 500] {
            let c = search(&t, budget).unwrap();
            assert!(c.total_bytes <= budget, "budget {budget}");
            assert!(
                c.total_err <= last_err + 1e-12,
                "budget {budget}: err {} after {last_err}",
                c.total_err
            );
            last_err = c.total_err;
        }
        // infeasible budgets error instead of overshooting
        assert!(search(&t, 299).is_err());
        // an unconstrained budget takes the per-layer error minimum
        let c = search(&t, 10_000).unwrap();
        assert_eq!(c.picks, vec![2, 2]);
        assert_eq!(c.total_bytes, 390);
    }

    #[test]
    fn search_is_deterministic() {
        let t = synth_tables();
        let a = search(&t, 350).unwrap();
        let b = search(&t, 350).unwrap();
        assert_eq!(a.picks, b.picks);
        assert_eq!(a.qcfg.id(), b.qcfg.id());
    }

    #[test]
    fn demo_model_has_the_split_sigma_profile() {
        let dims = demo_dims(true);
        let params = demo_model(&dims, 3).unwrap();
        let (k, n) = linear_dims(&dims, 0);
        let data = params.get("wq").unwrap().1;
        for layer in 0..dims.n_layers {
            let (narrow, wide, wide_rows) = demo_sigma_profile(layer, k);
            let w = &data[layer * k * n..(layer + 1) * k * n];
            let s_wide = stats::std_dev_f32(&w[..wide_rows * n]) as f64;
            let s_narrow = stats::std_dev_f32(&w[wide_rows * n..]) as f64;
            assert!(
                (s_wide / wide - 1.0).abs() < 0.4,
                "layer {layer}: wide σ {s_wide} vs {wide}"
            );
            assert!(
                (s_narrow / narrow - 1.0).abs() < 0.4,
                "layer {layer}: narrow σ {s_narrow} vs {narrow}"
            );
        }
    }

    #[test]
    fn candidate_space_filters_misaligned_blocks() {
        let dims = demo_dims(true); // d_model 64, d_ff 128
        let c = candidate_space(
            &dims,
            &["fp4_e2m1".into()],
            &["ue4m3".into()],
            &[8, 48, 64],
            false,
        )
        .unwrap();
        // 48 does not divide 64; 64 divides both
        let sizes: Vec<usize> =
            c.iter().map(|q| q.bs_override.unwrap()).collect();
        assert_eq!(sizes, vec![8, 64]);
        // rotation doubles the space
        let cr = candidate_space(
            &dims,
            &["fp4_e2m1".into()],
            &["ue4m3".into()],
            &[8, 64],
            true,
        )
        .unwrap();
        assert_eq!(cr.len(), 4);
        assert_eq!(cr.iter().filter(|q| q.rotate).count(), 2);
    }
}
