//! Persistent result cache: JSON file keyed by job key.
//!
//! Figures re-run incrementally: a sweep first consults the cache, then
//! computes only the missing points, flushing after each completion so an
//! interrupted run loses nothing.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub struct ResultCache {
    path: PathBuf,
    entries: BTreeMap<String, Json>,
    dirty: usize,
    flush_every: usize,
}

impl ResultCache {
    pub fn open(path: &Path) -> Result<ResultCache> {
        let entries = if path.exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading cache {path:?}"))?;
            match Json::parse(&text) {
                Ok(Json::Obj(m)) => m,
                _ => {
                    log::warn!("cache {path:?} unreadable; starting fresh");
                    BTreeMap::new()
                }
            }
        } else {
            BTreeMap::new()
        };
        Ok(ResultCache { path: path.to_path_buf(), entries, dirty: 0, flush_every: 32 })
    }

    /// In-memory cache (tests).
    pub fn ephemeral() -> ResultCache {
        ResultCache {
            path: PathBuf::new(),
            entries: BTreeMap::new(),
            dirty: 0,
            flush_every: usize::MAX,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.get(key)
    }

    pub fn put(&mut self, key: String, value: Json) {
        self.entries.insert(key, value);
        self.dirty += 1;
        if self.dirty >= self.flush_every {
            if let Err(e) = self.flush() {
                log::warn!("cache flush failed: {e:#}");
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn flush(&mut self) -> Result<()> {
        if self.path.as_os_str().is_empty() {
            return Ok(());
        }
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let tmp = self.path.with_extension("tmp");
        std::fs::write(&tmp, Json::Obj(self.entries.clone()).to_string())?;
        std::fs::rename(&tmp, &self.path)?;
        self.dirty = 0;
        Ok(())
    }
}

impl Drop for ResultCache {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn roundtrip_through_disk() {
        let path = std::env::temp_dir().join("microscale_cache_test.json");
        std::fs::remove_file(&path).ok();
        {
            let mut c = ResultCache::open(&path).unwrap();
            c.put("a/b".into(), num(1.5));
            c.flush().unwrap();
        }
        let c = ResultCache::open(&path).unwrap();
        assert_eq!(c.get("a/b").unwrap().as_f64().unwrap(), 1.5);
        assert!(c.get("missing").is_none());
        std::fs::remove_file(&path).ok();
    }
}
