//! Result sinks: CSV series and JSON documents under `results/`.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Directory layout helper for experiment outputs.
pub struct Sink {
    pub dir: PathBuf,
}

impl Sink {
    pub fn new(dir: &Path) -> Result<Sink> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {dir:?}"))?;
        Ok(Sink { dir: dir.to_path_buf() })
    }

    /// Write a CSV with a header row; cells are formatted with enough
    /// precision to round-trip f64.
    pub fn csv(
        &self,
        name: &str,
        header: &[&str],
        rows: &[Vec<String>],
    ) -> Result<PathBuf> {
        let path = self.dir.join(format!("{name}.csv"));
        let mut out = String::new();
        out.push_str(&header.join(","));
        out.push('\n');
        for r in rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        Ok(path)
    }

    pub fn json(&self, name: &str, value: &Json) -> Result<PathBuf> {
        let path = self.dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string())?;
        Ok(path)
    }

    pub fn text(&self, name: &str, body: &str) -> Result<PathBuf> {
        let path = self.dir.join(name);
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

pub fn fmt_g(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if (1e-4..1e7).contains(&a) {
        let s = format!("{v:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        format!("{v:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("microscale_sink_test");
        let s = Sink::new(&dir).unwrap();
        let p = s
            .csv(
                "t",
                &["a", "b"],
                &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
            )
            .unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fmt_g_reasonable() {
        assert_eq!(fmt_g(0.0), "0");
        assert_eq!(fmt_g(1.5), "1.5");
        assert_eq!(fmt_g(2.0), "2");
        assert!(fmt_g(1.23e-9).contains('e'));
    }
}
