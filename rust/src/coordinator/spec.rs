//! Job specification: a keyed unit of experiment work, plus parallel
//! sweep expansion.

use crate::util::json::Json;
use crate::util::par;

/// A unit of work with a stable cache key.
pub struct Job {
    /// Stable, human-readable cache key, e.g.
    /// `mse_sigma/normal/fp4_e2m1/ue4m3/bs8/s=0.02/n=65536/seed=3`.
    pub key: String,
    /// Pure CPU jobs may run on pool workers; runtime jobs (PJRT) must
    /// run on the coordinator thread.
    pub pure: bool,
    /// The work itself; returns a JSON result payload.
    pub run: Box<dyn FnOnce() -> anyhow::Result<Json> + Send>,
}

impl Job {
    /// A pure CPU job (eligible for pool workers).
    pub fn pure<F>(key: impl Into<String>, f: F) -> Job
    where
        F: FnOnce() -> anyhow::Result<Json> + Send + 'static,
    {
        Job { key: key.into(), pure: true, run: Box::new(f) }
    }

    /// A runtime-bound job (PJRT session is not `Sync`; runs inline on
    /// the coordinator thread).
    pub fn runtime<F>(key: impl Into<String>, f: F) -> Job
    where
        F: FnOnce() -> anyhow::Result<Json> + Send + 'static,
    {
        Job { key: key.into(), pure: false, run: Box::new(f) }
    }
}

/// Expand sweep points into jobs, preserving sweep order (job order is
/// what [`super::Pool::run`] returns results in).
///
/// Today's generators build cheap jobs (a key + a deferred closure),
/// so small expansions run serially — threads only engage past 32
/// points, where a builder that pre-computes per-point state (tensor
/// draws, σ grids) would start to matter. The helper exists so sweep
/// construction has one order-preserving entry point whose
/// parallelism ([`crate::util::par::par_map`]) scales with the sweep
/// instead of being re-invented per figure.
pub fn expand_jobs<P, F>(points: Vec<P>, build: F) -> Vec<Job>
where
    P: Send,
    F: Fn(P) -> Job + Sync,
{
    let threads = if points.len() >= 32 { par::max_threads() } else { 1 };
    par::par_map(points, threads, build)
}

/// A completed job.
#[derive(Debug, Clone)]
pub struct JobOutput {
    /// The job's cache key.
    pub key: String,
    /// The JSON result payload.
    pub value: Json,
    /// Wall seconds spent computing (0 when served from cache).
    pub seconds: f64,
    /// Whether the value came from the result cache.
    pub from_cache: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn expand_preserves_order_and_keys() {
        let jobs = expand_jobs((0..33).collect(), |i: i32| {
            Job::pure(format!("k/{i}"), move || Ok(num(i as f64)))
        });
        assert_eq!(jobs.len(), 33);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.key, format!("k/{i}"));
            assert!(j.pure);
        }
    }
}
