//! Job specification: a keyed unit of experiment work.

use crate::util::json::Json;

/// A unit of work with a stable cache key.
pub struct Job {
    /// Stable, human-readable cache key, e.g.
    /// `mse_sigma/normal/fp4_e2m1/ue4m3/bs8/s=0.02/n=65536/seed=3`.
    pub key: String,
    /// Pure CPU jobs may run on pool workers; runtime jobs (PJRT) must
    /// run on the coordinator thread.
    pub pure: bool,
    /// The work itself; returns a JSON result payload.
    pub run: Box<dyn FnOnce() -> anyhow::Result<Json> + Send>,
}

impl Job {
    pub fn pure<F>(key: impl Into<String>, f: F) -> Job
    where
        F: FnOnce() -> anyhow::Result<Json> + Send + 'static,
    {
        Job { key: key.into(), pure: true, run: Box::new(f) }
    }

    pub fn runtime<F>(key: impl Into<String>, f: F) -> Job
    where
        F: FnOnce() -> anyhow::Result<Json> + Send + 'static,
    {
        Job { key: key.into(), pure: false, run: Box::new(f) }
    }
}

/// A completed job.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub key: String,
    pub value: Json,
    /// wall seconds (0 when served from cache)
    pub seconds: f64,
    pub from_cache: bool,
}
