//! Experiment coordinator: the L3 orchestration runtime.
//!
//! Every figure/table of the paper is a *sweep* — a deterministic
//! expansion into jobs (one per (model, format, block size, σ, ...)
//! point). The coordinator:
//!
//! * expands sweeps into keyed [`spec::Job`]s,
//! * serves results from a persistent JSON [`cache`] (re-running a figure
//!   is incremental: only missing points compute),
//! * executes CPU-pure jobs on a [`pool`] of workers with a bounded queue
//!   (backpressure) and panic isolation, while PJRT-bound jobs run on the
//!   coordinator thread (the PJRT client is not Sync),
//! * streams results to CSV/JSON [`sink`]s consumed by EXPERIMENTS.md,
//! * hosts the offline mixed-precision auto-[`tuner`] behind
//!   `microscale tune` (DESIGN.md §16).

pub mod cache;
pub mod pool;
pub mod sink;
pub mod spec;
pub mod tuner;

pub use cache::ResultCache;
pub use pool::Pool;
pub use spec::{Job, JobOutput};
