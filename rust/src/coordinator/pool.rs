//! Worker pool with a bounded queue, panic isolation and result caching.
//!
//! Pure jobs fan out to `std::thread` workers over a bounded channel
//! (backpressure: submission blocks when the queue is full); PJRT-bound
//! jobs run inline on the coordinator thread because the client is not
//! Sync. On this sandbox (1 core) the pool degenerates gracefully to
//! sequential execution, but the structure is what a multi-core deploy
//! uses.

use std::collections::VecDeque;
use std::panic::AssertUnwindSafe;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::cache::ResultCache;
use super::spec::{Job, JobOutput};
use crate::util::json::Json;

/// Minimal bounded MPMC channel (std::sync::mpsc has no bounded MPMC and
/// crossbeam-channel is not vendored).
struct Bounded<T> {
    q: Mutex<(VecDeque<T>, bool)>, // (queue, closed)
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl<T> Bounded<T> {
    fn new(cap: usize) -> Self {
        Bounded {
            q: Mutex::new((VecDeque::new(), false)),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    fn send(&self, item: T) {
        let mut g = self.q.lock().unwrap();
        while g.0.len() >= self.cap {
            g = self.not_full.wait(g).unwrap();
        }
        g.0.push_back(item);
        self.not_empty.notify_one();
    }

    fn recv(&self) -> Option<T> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(item) = g.0.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.1 {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.1 = true;
        self.not_empty.notify_all();
    }
}

/// Execution pool configuration.
pub struct Pool {
    pub workers: usize,
    pub queue_cap: usize,
    pub progress: bool,
}

impl Default for Pool {
    fn default() -> Self {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Pool { workers, queue_cap: 2 * workers.max(1), progress: true }
    }
}

impl Pool {
    /// Run `jobs`, serving repeats from `cache`. Results are returned in
    /// the original job order. Pure jobs run on workers; runtime jobs run
    /// inline after the pure jobs are dispatched.
    pub fn run(
        &self,
        jobs: Vec<Job>,
        cache: &mut ResultCache,
    ) -> Result<Vec<JobOutput>> {
        let total = jobs.len();
        let mut outputs: Vec<Option<JobOutput>> = Vec::new();
        outputs.resize_with(total, || None);

        let mut pure_jobs = Vec::new();
        let mut inline_jobs = Vec::new();
        for (idx, job) in jobs.into_iter().enumerate() {
            if let Some(v) = cache.get(&job.key) {
                outputs[idx] = Some(JobOutput {
                    key: job.key,
                    value: v.clone(),
                    seconds: 0.0,
                    from_cache: true,
                });
            } else if job.pure {
                pure_jobs.push((idx, job));
            } else {
                inline_jobs.push((idx, job));
            }
        }
        let fresh = pure_jobs.len() + inline_jobs.len();
        if self.progress && total > 0 {
            log::info!(
                "pool: {total} jobs ({} cached, {fresh} to run, {} workers)",
                total - fresh,
                self.workers
            );
        }

        // -- pure jobs on workers ---------------------------------------
        if !pure_jobs.is_empty() {
            let chan: Bounded<(usize, Job)> = Bounded::new(self.queue_cap);
            let results: Mutex<Vec<(usize, String, Result<Json>, f64)>> =
                Mutex::new(Vec::new());
            // std::thread::scope re-raises worker panics on exit; workers
            // catch job panics themselves, so a scope-level panic only
            // happens on truly unrecoverable states (poisoned mutex).
            std::panic::catch_unwind(AssertUnwindSafe(|| {
                std::thread::scope(|s| {
                    for _ in 0..self.workers.max(1) {
                        s.spawn(|| {
                            // inner data-parallel kernels stay serial on
                            // pool workers (see util::par docs)
                            let _guard = crate::util::par::WorkerGuard::enter();
                            while let Some((idx, job)) = chan.recv() {
                                let t = Instant::now();
                                let key = job.key;
                                let run = job.run;
                                let r = std::panic::catch_unwind(
                                    AssertUnwindSafe(run),
                                )
                                .unwrap_or_else(|p| {
                                    Err(anyhow!(
                                        "job panicked: {}",
                                        panic_msg(&p)
                                    ))
                                });
                                results.lock().unwrap().push((
                                    idx,
                                    key,
                                    r,
                                    t.elapsed().as_secs_f64(),
                                ));
                            }
                        });
                    }
                    for item in pure_jobs {
                        chan.send(item);
                    }
                    chan.close();
                })
            }))
            .map_err(|_| anyhow!("worker panicked irrecoverably"))?;
            for (idx, key, r, secs) in results.into_inner().unwrap() {
                let value = r?;
                cache.put(key.clone(), value.clone());
                outputs[idx] =
                    Some(JobOutput { key, value, seconds: secs, from_cache: false });
            }
        }

        // -- runtime jobs inline ------------------------------------------
        let n_inline = inline_jobs.len();
        for (done, (idx, job)) in inline_jobs.into_iter().enumerate() {
            let t = Instant::now();
            let key = job.key.clone();
            let value = (job.run)()?;
            cache.put(key.clone(), value.clone());
            if self.progress && (done % 8 == 0 || done + 1 == n_inline) {
                log::info!(
                    "  [{}/{}] {key} ({:.1}s)",
                    done + 1,
                    n_inline,
                    t.elapsed().as_secs_f64()
                );
            }
            outputs[idx] = Some(JobOutput {
                key,
                value,
                seconds: t.elapsed().as_secs_f64(),
                from_cache: false,
            });
        }
        cache.flush()?;
        Ok(outputs.into_iter().map(|o| o.unwrap()).collect())
    }
}

fn panic_msg(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn runs_and_caches() {
        let mut cache = ResultCache::ephemeral();
        let jobs: Vec<Job> = (0..20)
            .map(|i| {
                Job::pure(format!("sq/{i}"), move || Ok(num((i * i) as f64)))
            })
            .collect();
        let pool = Pool { workers: 3, queue_cap: 4, progress: false };
        let out = pool.run(jobs, &mut cache).unwrap();
        assert_eq!(out.len(), 20);
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.value.as_f64().unwrap(), (i * i) as f64);
            assert!(!o.from_cache);
        }
        // second run: everything cached
        let jobs: Vec<Job> = (0..20)
            .map(|i| Job::pure(format!("sq/{i}"), move || Ok(num(-1.0))))
            .collect();
        let out = pool.run(jobs, &mut cache).unwrap();
        assert!(out.iter().all(|o| o.from_cache));
        assert_eq!(out[3].value.as_f64().unwrap(), 9.0);
    }

    #[test]
    fn job_panic_is_an_error_not_a_crash() {
        let mut cache = ResultCache::ephemeral();
        let jobs = vec![Job::pure("boom", || panic!("kapow"))];
        let pool = Pool { workers: 2, queue_cap: 2, progress: false };
        let err = pool.run(jobs, &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("kapow"));
    }

    #[test]
    fn preserves_order_with_mixed_kinds() {
        let mut cache = ResultCache::ephemeral();
        let jobs = vec![
            Job::pure("a", || Ok(num(1.0))),
            Job::runtime("b", || Ok(num(2.0))),
            Job::pure("c", || Ok(num(3.0))),
        ];
        let pool = Pool { workers: 2, queue_cap: 2, progress: false };
        let out = pool.run(jobs, &mut cache).unwrap();
        let vals: Vec<f64> =
            out.iter().map(|o| o.value.as_f64().unwrap()).collect();
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
    }
}
