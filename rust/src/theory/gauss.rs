//! Gaussian special functions and closed-form moment integrals.
//!
//! The framework's inner integrals (eq. 3/22/35) are of the form
//! `∫ₐᵇ (u − c)² φ(u) du`, which has the closed form implemented by
//! [`second_moment_about`] — no quadrature needed on the hot path.
//!
//! `erf` is implemented from scratch (libm is unavailable offline):
//! Maclaurin series for |x| ≤ 3 and a Lentz continued fraction for the
//! complementary function beyond, giving ~1e-15 relative accuracy.

use std::f64::consts::{FRAC_1_SQRT_2, PI};

const FRAC_2_SQRT_PI: f64 = 1.128_379_167_095_512_6;

/// Error function, |err| ~ 1e-15.
pub fn erf(x: f64) -> f64 {
    if x < 0.0 {
        return -erf(-x);
    }
    if x <= 3.0 {
        // Maclaurin: erf(x) = 2/√π Σ (−1)ⁿ x^{2n+1} / (n! (2n+1))
        let x2 = x * x;
        let mut term = x;
        let mut sum = x;
        let mut n = 1.0f64;
        loop {
            term *= -x2 / n;
            let add = term / (2.0 * n + 1.0);
            sum += add;
            if add.abs() < 1e-18 * sum.abs().max(1e-300) {
                break;
            }
            n += 1.0;
            if n > 200.0 {
                break;
            }
        }
        sum * FRAC_2_SQRT_PI
    } else {
        1.0 - erfc_large(x)
    }
}

/// Complementary error function, accurate in both tails.
pub fn erfc(x: f64) -> f64 {
    if x < 3.0 {
        1.0 - erf(x)
    } else {
        erfc_large(x)
    }
}

/// erfc for x >= 3 via the classic continued fraction
/// erfc(x) = e^{−x²}/√π · 1/(x + (1/2)/(x + 1/(x + (3/2)/(x + ...))))
/// evaluated with modified Lentz.
fn erfc_large(x: f64) -> f64 {
    if x > 27.0 {
        return 0.0; // below 1e-308
    }
    let tiny = 1e-300;
    let mut f = x;
    let mut c = f;
    let mut d = 0.0f64;
    let mut n = 0.5f64;
    for _ in 0..200 {
        d = x + n * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = x + n / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
        n += 0.5;
    }
    (-x * x).exp() / PI.sqrt() / f
}

/// Standard normal PDF φ(x).
#[inline]
pub fn phi(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * PI).sqrt()
}

/// Standard normal CDF Φ(x), accurate in both tails.
#[inline]
pub fn cap_phi(x: f64) -> f64 {
    0.5 * erfc(-x * FRAC_1_SQRT_2)
}

/// `2Φ(x) − 1` = P(|Z| ≤ x), computed tail-stably (= erf(x/√2)).
#[inline]
pub fn central_mass(x: f64) -> f64 {
    erf(x * FRAC_1_SQRT_2)
}

/// Closed form of `∫ₐᵇ (u − c)² φ(u) du`:
///
/// `(1 + c²)(Φ(b) − Φ(a)) − (b φ(b) − a φ(a)) − 2c (φ(a) − φ(b))`.
pub fn second_moment_about(a: f64, b: f64, c: f64) -> f64 {
    debug_assert!(b >= a);
    let dphi_cap = 0.5 * (erf(b * FRAC_1_SQRT_2) - erf(a * FRAC_1_SQRT_2));
    let pa = phi(a);
    let pb = phi(b);
    ((1.0 + c * c) * dphi_cap - (b * pb - a * pa) - 2.0 * c * (pa - pb))
        .max(0.0)
}

/// `∫ₐᵇ u² φ(u) du` (the c = 0 case, used by the s = 0 term).
#[inline]
pub fn second_moment(a: f64, b: f64) -> f64 {
    second_moment_about(a, b, 0.0)
}

/// Nodes/weights for n-point Gauss–Legendre on [-1, 1], computed by
/// Newton iteration on Legendre polynomials (no tables needed).
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut xs = vec![0.0; n];
    let mut ws = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // initial guess (Abramowitz–Stegun 25.4.30 vicinity)
        let mut x = (PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        let mut dp = 0.0;
        for _ in 0..100 {
            // evaluate P_n(x) and P'_n(x) by recurrence
            let mut p0 = 1.0;
            let mut p1 = x;
            for k in 2..=n {
                let kf = k as f64;
                let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
                p0 = p1;
                p1 = p2;
            }
            dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / dp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        xs[i] = -x;
        xs[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        ws[i] = w;
        ws[n - 1 - i] = w;
    }
    (xs, ws)
}

/// Integrate `f` over [a, b] with a fixed n-point Gauss–Legendre rule.
pub fn integrate_gl<F: FnMut(f64) -> f64>(
    a: f64,
    b: f64,
    nodes: &(Vec<f64>, Vec<f64>),
    mut f: F,
) -> f64 {
    let half = 0.5 * (b - a);
    let mid = 0.5 * (a + b);
    let mut acc = 0.0;
    for (&x, &w) in nodes.0.iter().zip(&nodes.1) {
        acc += w * f(mid + half * x);
    }
    acc * half
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // (x, erf(x)) from standard tables / mpmath
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
            (4.0, 0.9999999845827421),
        ];
        for (x, want) in cases {
            // series accumulation near the x=3 crossover costs a few ulps
            assert!((erf(x) - want).abs() < 2e-13, "erf({x}) = {}", erf(x));
            assert!((erf(-x) + want).abs() < 2e-13);
        }
        // deep tail via erfc: erfc(5) = 1.5374597944280351e-12
        assert!((erfc(5.0) / 1.5374597944280351e-12 - 1.0).abs() < 1e-10);
        assert!((erfc(10.0) / 2.0884875837625447e-45 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_symmetry() {
        for x in [0.0, 0.3, 1.7, 4.0] {
            assert!((cap_phi(x) + cap_phi(-x) - 1.0).abs() < 1e-14);
        }
        assert!((cap_phi(0.0) - 0.5).abs() < 1e-15);
    }

    #[test]
    fn second_moment_vs_quadrature() {
        let nodes = gauss_legendre(64);
        for (a, b, c) in [
            (0.0, 1.0, 0.5),
            (-2.0, 3.0, -1.0),
            (1.5, 6.0, 2.0),
            (0.0, 0.01, 0.005),
        ] {
            let closed = second_moment_about(a, b, c);
            let quad =
                integrate_gl(a, b, &nodes, |u| (u - c) * (u - c) * phi(u));
            assert!(
                (closed - quad).abs() < 1e-12 * (1.0 + quad.abs()),
                "({a},{b},{c}): {closed} vs {quad}"
            );
        }
    }

    #[test]
    fn total_second_moment_is_unit_variance() {
        assert!((second_moment(-8.0, 8.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gl_integrates_polynomials_exactly() {
        let nodes = gauss_legendre(8);
        // degree 15 is exact for 8-point GL
        let got = integrate_gl(0.0, 1.0, &nodes, |x| x.powi(15));
        assert!((got - 1.0 / 16.0).abs() < 1e-14);
    }
}
