//! The paper's theoretical framework (Sec. 4, App. E–H): first-principles
//! MSE of microscaling quantization of a zero-mean Normal tensor, as a
//! function of σ, block size N, element format, and scale format.
//!
//! Two regimes:
//!
//! * [`mse_unquantized_scales`] — App. E (eq. 1–5/29): scales kept at
//!   infinite precision; only the element quantization contributes.
//! * [`mse_quantized_scales`] — App. F (eq. 6–10/42): FP8/FP6 scales;
//!   three separate contributions ([`MseBreakdown`]):
//!   1. `xi_ne_xmax` — elements other than the block max (eq. 36),
//!   2. `xi_eq_xmax` — the block max itself, no longer exact (eq. 38),
//!   3. `s_zero`     — whole-block collapse when the scale rounds to 0
//!      (eq. 39–41).
//!
//! The framework is generic over the element format (FP4/FP6/INT4 —
//! App. G) and scale format (UE4M3/UE5M3/UE4M4/UE5M1/UE4M2/E8M0 — App. H),
//! exactly as the paper advertises.
//!
//! All Gaussian integrals use the closed forms in [`gauss`]; only the
//! eq. 38 term needs (cheap, per-subinterval) Gauss–Legendre quadrature.

pub mod gauss;

use crate::formats::levels::{
    elem_positive_levels, positive_levels, voronoi, zero_cell_hi, Level,
};
use crate::formats::{ElemFormat, MiniFloat};
use gauss::{central_mass, gauss_legendre, integrate_gl, phi, second_moment_about};

/// The three error contributions of eq. 42 (Fig. 3(c), Fig. 12).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MseBreakdown {
    pub xi_ne_xmax: f64,
    pub xi_eq_xmax: f64,
    pub s_zero: f64,
}

impl MseBreakdown {
    pub fn total(&self) -> f64 {
        self.xi_ne_xmax + self.xi_eq_xmax + self.s_zero
    }
}

/// Precomputed element-format geometry shared by both regimes.
struct ElemGeometry {
    /// positive levels with Voronoi cells; the top cell is closed at the
    /// truncation boundary `m` (the element max), matching eq. 20's
    /// truncated-support formulation.
    cells: Vec<Level>,
    /// upper boundary of the zero cell (first level / 2)
    zero_hi: f64,
    /// element format max (the paper's m: 6.0 for FP4 E2M1, 7 for INT4)
    m: f64,
}

impl ElemGeometry {
    fn new(elem: &ElemFormat) -> Self {
        let levels = elem_positive_levels(elem);
        let m = elem.max_val() as f64;
        let cells = voronoi(&levels, m);
        ElemGeometry { zero_hi: zero_cell_hi(&levels), cells, m }
    }

    /// Inner sum of eq. 22/35: Σ_j ∫ (u − q_j α)² φ(u) du over the
    /// Voronoi cells scaled by α, INCLUDING the zero level and doubling
    /// for the negative half (symmetry).
    fn bin_error_sum(&self, alpha: f64) -> f64 {
        // zero bin: c = 0 over [0, zero_hi·α]
        let mut acc = second_moment_about(0.0, self.zero_hi * alpha, 0.0);
        for c in &self.cells {
            acc += second_moment_about(c.lo * alpha, c.hi * alpha, c.q * alpha);
        }
        2.0 * acc
    }

    /// Q_elem(y) for y >= 0 via the cells (saturating at the top level).
    fn quantize(&self, y: f64) -> f64 {
        if y < self.zero_hi {
            return 0.0;
        }
        for c in &self.cells {
            if y < c.hi {
                return c.q;
            }
        }
        self.cells.last().map(|c| c.q).unwrap_or(0.0)
    }
}

/// PDF of x_max = max |x_i| over N i.i.d. N(0, σ²) draws (eq. 5/28):
/// `f(θ) = (2N/σ) [2Φ(θ/σ) − 1]^{N−1} φ(θ/σ)`.
pub fn f_xmax(theta: f64, sigma: f64, n: usize) -> f64 {
    let t = theta / sigma;
    2.0 * n as f64 / sigma * central_mass(t).powi(n as i32 - 1) * phi(t)
}

/// CDF of x_max (eq. 27): `[2Φ(θ/σ) − 1]^N`.
pub fn cdf_xmax(theta: f64, sigma: f64, n: usize) -> f64 {
    central_mass(theta / sigma).powi(n as i32)
}

/// App. E (eq. 29): MSE with non-quantized (infinite-precision) scales.
///
/// Integrates `Σ_j MSE_{Z,j}(q_j | x_max) · f_xmax` over x_max with
/// composite Gauss–Legendre on θ/σ ∈ (0, upper], where the upper limit
/// covers the max distribution for any practical N.
pub fn mse_unquantized_scales(
    elem: &ElemFormat,
    sigma: f64,
    n: usize,
) -> f64 {
    let geo = ElemGeometry::new(elem);
    let nodes = gauss_legendre(24);
    let nf = n as f64;
    let upper = (2.0 * (nf.max(2.0)).ln()).sqrt() + 8.0; // in σ units
    let segments = 64;
    let mut total = 0.0;
    for seg in 0..segments {
        let a = upper * seg as f64 / segments as f64;
        let b = upper * (seg + 1) as f64 / segments as f64;
        total += integrate_gl(a, b, &nodes, |t| {
            // t = θ/σ; α = θ/(mσ) = t/m
            let alpha = t / geo.m;
            if alpha <= 0.0 {
                return 0.0;
            }
            let denom = central_mass(geo.m * alpha);
            if denom < 1e-300 {
                return 0.0;
            }
            let mse_j = sigma * sigma / denom * (nf - 1.0) / nf
                * geo.bin_error_sum(alpha);
            // f_xmax(θ)dθ = f̂(t)dt with f̂(t) = 2N [2Φ(t)−1]^{N−1} φ(t)
            let fx = 2.0 * nf * central_mass(t).powi(n as i32 - 1) * phi(t);
            mse_j * fx
        });
    }
    total
}

/// App. F (eq. 42): the three-term MSE with quantized scales.
pub fn mse_quantized_scales(
    elem: &ElemFormat,
    scale: &MiniFloat,
    sigma: f64,
    n: usize,
) -> MseBreakdown {
    let geo = ElemGeometry::new(elem);
    let nf = n as f64;
    // scale levels + Voronoi cells on the scale axis; cap enumeration:
    // levels with x_max ≳ σ(√(2lnN)+10) carry no probability mass.
    let s_levels = positive_levels(scale, 8192);
    let s_cells = voronoi(&s_levels, f64::INFINITY);
    let s_min = s_levels.first().copied().unwrap_or(0.0);

    // -- contribution 3: s = 0 (eq. 39-41) ------------------------------
    // s rounds to 0 iff x_max/m < s_min/2, i.e. x_max < t0 := m·s_min/2.
    let t0 = geo.m * s_min / 2.0;
    let p_zero = cdf_xmax(t0, sigma, n);
    let s_zero = if p_zero > 0.0 {
        // E[X² | |X| < t0] for the truncated normal (eq. 41)
        let a = t0 / sigma;
        let mass = central_mass(a);
        if mass > 0.0 {
            let ex2 = sigma * sigma
                * gauss::second_moment(-a, a)
                / mass;
            p_zero * ex2
        } else {
            0.0
        }
    } else {
        0.0
    };

    // per-k accumulation for contributions 1 and 2
    let nodes = gauss_legendre(16);
    let mut xi_ne = 0.0;
    let mut xi_eq = 0.0;
    let upper_theta = sigma * ((2.0 * nf.max(2.0).ln()).sqrt() + 10.0);
    for cell in &s_cells {
        let s_k = cell.q;
        // probability mass of this scale bin (closed form via the CDF):
        // p_k = F_xmax(m·b_k) − F_xmax(m·a_k)
        let theta_lo = geo.m * cell.lo;
        if theta_lo > upper_theta {
            break; // no mass further out
        }
        let theta_hi = (geo.m * cell.hi).min(upper_theta * 4.0);
        let p_k = cdf_xmax(theta_hi, sigma, n) - cdf_xmax(theta_lo, sigma, n);
        if p_k < 1e-18 {
            continue;
        }

        // -- contribution 1 (eq. 35/36): x_i ≠ x_max --------------------
        let alpha_k = s_k / sigma;
        let denom = central_mass(geo.m * alpha_k);
        if denom > 1e-300 && n > 1 {
            let mse_k = sigma * sigma / denom * (nf - 1.0) / nf
                * geo.bin_error_sum(alpha_k);
            xi_ne += p_k * mse_k;
        }

        // -- contribution 2 (eq. 37/38): x_i = x_max --------------------
        // ∫_{mθa}^{mθb} (Q(x/s_k)·s_k − x)² f_xmax(x) dx, split at the
        // element-level Voronoi edges mapped back to x = s_k · boundary.
        let mut edges = vec![theta_lo];
        for c in &geo.cells {
            for e in [c.lo * s_k, c.hi * s_k] {
                if e > theta_lo && e < theta_hi {
                    edges.push(e);
                }
            }
        }
        edges.push(theta_hi);
        edges.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut term = 0.0;
        for w in edges.windows(2) {
            let (a, b) = (w[0], w[1]);
            if b <= a {
                continue;
            }
            let mid = 0.5 * (a + b);
            let q = geo.quantize(mid / s_k);
            term += integrate_gl(a, b, &nodes, |x| {
                let err = q * s_k - x;
                err * err * f_xmax(x, sigma, n)
            });
        }
        xi_eq += term / nf;
    }

    MseBreakdown { xi_ne_xmax: xi_ne, xi_eq_xmax: xi_eq, s_zero }
}

/// Sweep MSE-vs-σ for a format configuration (Figs. 3(c), 10, 11, 13, 15).
pub fn sweep_quantized(
    elem: &ElemFormat,
    scale: &MiniFloat,
    sigmas: &[f64],
    n: usize,
) -> Vec<MseBreakdown> {
    sigmas
        .iter()
        .map(|&s| mse_quantized_scales(elem, scale, s, n))
        .collect()
}

/// Sweep for the non-quantized-scale regime (Fig. 10).
pub fn sweep_unquantized(
    elem: &ElemFormat,
    sigmas: &[f64],
    n: usize,
) -> Vec<f64> {
    sigmas
        .iter()
        .map(|&s| mse_unquantized_scales(elem, s, n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::formats::{ElemFormat, BF16_SCALE, UE4M3, UE5M3};
    use crate::quant::{fake_quant, QuantScheme};
    use crate::stats;

    fn mc_mse(scheme: &QuantScheme, sigma: f64, n_samples: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::new(seed);
        let x = rng.normal_vec_f32(n_samples, sigma);
        let xq = fake_quant(scheme, &x);
        stats::mse_f32(&x, &xq)
    }

    #[test]
    fn unquantized_theory_matches_monte_carlo() {
        // App. E / Fig. 10: theory vs experiment on a Normal distribution.
        let elem = ElemFormat::FP4;
        for (sigma, n) in [(0.02, 8), (0.5, 16), (1.0, 32), (3e-3, 8)] {
            let theory = mse_unquantized_scales(&elem, sigma, n);
            let scheme = QuantScheme::new(elem, BF16_SCALE, n);
            let mc = mc_mse(&scheme, sigma, 1 << 18, 42);
            let rel = (theory - mc).abs() / theory.max(1e-300);
            assert!(rel < 0.05, "σ={sigma} N={n}: theory {theory} mc {mc}");
        }
    }

    #[test]
    fn quantized_theory_matches_monte_carlo() {
        // App. F / Fig. 11: the three-term model vs experiment.
        let elem = ElemFormat::FP4;
        for (sigma, n) in [(0.1, 8), (0.02, 16), (5e-3, 8), (1e-3, 16), (2.0, 32)] {
            let theory = mse_quantized_scales(&elem, &UE4M3, sigma, n).total();
            let scheme = QuantScheme::new(elem, UE4M3, n);
            let mc = mc_mse(&scheme, sigma, 1 << 18, 7);
            let rel = (theory - mc).abs() / theory.max(1e-300);
            assert!(rel < 0.06, "σ={sigma} N={n}: theory {theory} mc {mc}");
        }
    }

    #[test]
    fn int4_theory_matches_monte_carlo() {
        // App. G / Fig. 13.
        let elem = ElemFormat::INT4;
        for (sigma, n) in [(0.05, 8), (4e-3, 16)] {
            let theory = mse_quantized_scales(&elem, &UE4M3, sigma, n).total();
            let scheme = QuantScheme::new(elem, UE4M3, n);
            let mc = mc_mse(&scheme, sigma, 1 << 18, 11);
            let rel = (theory - mc).abs() / theory.max(1e-300);
            assert!(rel < 0.06, "σ={sigma} N={n}: theory {theory} mc {mc}");
        }
    }

    #[test]
    fn crossover_bs8_vs_bs16_near_paper_sigma() {
        // Sec. 3.2: under UE4M3 the bs-8 and bs-16 curves cross near
        // σ ≈ 2e-2 (bs8 worse below).
        let elem = ElemFormat::FP4;
        let lo = mse_quantized_scales(&elem, &UE4M3, 4e-3, 8).total()
            - mse_quantized_scales(&elem, &UE4M3, 4e-3, 16).total();
        let hi = mse_quantized_scales(&elem, &UE4M3, 0.1, 8).total()
            - mse_quantized_scales(&elem, &UE4M3, 0.1, 16).total();
        assert!(lo > 0.0, "bs8 should be worse at σ=4e-3: Δ={lo}");
        assert!(hi < 0.0, "bs8 should be better at σ=0.1: Δ={hi}");
    }

    #[test]
    fn ue5m3_removes_low_sigma_blowup() {
        // Sec. 5.2: at narrow σ the UE5M3 total error is far below UE4M3.
        let elem = ElemFormat::FP4;
        let sigma = 2e-3;
        let e43 = mse_quantized_scales(&elem, &UE4M3, sigma, 8).total();
        let e53 = mse_quantized_scales(&elem, &UE5M3, sigma, 8).total();
        assert!(e53 < e43 * 0.5, "ue5m3 {e53} vs ue4m3 {e43}");
    }

    #[test]
    fn szero_dominates_ultra_narrow() {
        // Fig. 3(c)/Fig. 12: at the lowest σ the zero-collapse term wins.
        let b = mse_quantized_scales(&ElemFormat::FP4, &UE4M3, 2e-4, 8);
        assert!(b.s_zero > b.xi_ne_xmax && b.s_zero > b.xi_eq_xmax, "{b:?}");
    }

    #[test]
    fn xmax_pdf_normalizes() {
        let nodes = gauss_legendre(32);
        for n in [2usize, 8, 32] {
            let mut total = 0.0;
            for seg in 0..64 {
                let a = 8.0 * seg as f64 / 64.0;
                let b = 8.0 * (seg + 1) as f64 / 64.0;
                total += integrate_gl(a, b, &nodes, |t| f_xmax(t, 1.0, n));
            }
            assert!((total - 1.0).abs() < 1e-9, "N={n}: {total}");
        }
    }
}
