//! Rendering: aligned ASCII tables, log-log series plots, and Markdown —
//! the terminal/EXPERIMENTS.md faces of every figure and table.

/// An aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut w = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(display_width(h));
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(display_width(c));
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| {
                    format!("{}{}", c, " ".repeat(w[i] - display_width(c)))
                })
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured Markdown rendering (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

fn display_width(s: &str) -> usize {
    s.chars().count()
}

/// A named (x, y) series — one curve of a figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series { name: name.into(), x: Vec::new(), y: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.x.push(x);
        self.y.push(y);
    }
}

/// ASCII log-log plot of several series (the terminal face of the MSE-σ
/// figures). Each series gets a distinct glyph; overlapping points show
/// the later series' glyph.
pub fn ascii_loglog(series: &[Series], width: usize, height: usize) -> String {
    const GLYPHS: &[char] = &['o', 'x', '+', '*', '#', '@', '%', '&'];
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.x.iter().zip(&s.y).map(|(&a, &b)| (a, b)))
        .filter(|(a, b)| *a > 0.0 && *b > 0.0)
        .collect();
    if pts.is_empty() {
        return "(no positive data)\n".to_string();
    }
    let (mut x0, mut x1, mut y0, mut y1) =
        (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
    for (a, b) in &pts {
        x0 = x0.min(a.log10());
        x1 = x1.max(a.log10());
        y0 = y0.min(b.log10());
        y1 = y1.max(b.log10());
    }
    if x1 - x0 < 1e-12 {
        x1 = x0 + 1.0;
    }
    if y1 - y0 < 1e-12 {
        y1 = y0 + 1.0;
    }
    // clamp the y span to 12 decades below the top so vanishing tails
    // (e.g. the s=0 term at large σ) don't squash the interesting region
    y0 = y0.max(y1 - 12.0);
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for (&a, &b) in s.x.iter().zip(&s.y) {
            if !(a > 0.0 && b > 0.0) {
                continue;
            }
            let ix = (((a.log10() - x0) / (x1 - x0)) * (width - 1) as f64)
                .round() as usize;
            let iy = (((b.log10() - y0) / (y1 - y0)) * (height - 1) as f64)
                .round() as usize;
            grid[height - 1 - iy][ix.min(width - 1)] = g;
        }
    }
    let mut out = String::new();
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "  {} {}\n",
            GLYPHS[si % GLYPHS.len()],
            s.name
        ));
    }
    out.push_str(&format!("  y: log10 in [{y0:.1}, {y1:.1}]\n"));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("  x: log10 in [{x0:.1}, {x1:.1}]\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(vec!["a".into(), "1.25".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(r.contains("longer  2"));
        let md = t.markdown();
        assert!(md.starts_with("| name | v |"));
    }

    #[test]
    fn plot_handles_data() {
        let mut s = Series::new("curve");
        for i in 1..20 {
            s.push(i as f64 * 1e-3, (i as f64).powi(2) * 1e-6);
        }
        let p = ascii_loglog(&[s], 40, 10);
        assert!(p.contains("curve"));
        assert!(p.contains('o'));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
