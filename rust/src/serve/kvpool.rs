//! Paged, byte-budgeted KV-cache storage with pluggable page codecs.
//!
//! PR 4's decode engine stored each sequence's K/V rows in unbounded
//! per-sequence `Vec<f32>`s — fine for tests, unusable under production
//! memory pressure, where the KV cache (not the weights) dominates the
//! resident bytes of serving at scale. This module replaces that storage
//! with a process-wide [`KvPool`]: fixed-size **pages** (a page holds
//! [`KvPool::page_rows`] cache rows of one layer's K *or* V stream)
//! allocated against a hard byte budget, with every allocation and free
//! accounted exactly ([`KvPool::used_bytes`] is the sum of live page
//! bytes, nothing estimated). Sequences hold page handles per layer
//! (the internal `PagedKv`, wrapped by [`super::SeqKv`]); the
//! scheduler turns the budget into admission/eviction decisions
//! (DESIGN.md §11).
//!
//! # Page codecs and the exactness-contract split
//!
//! Each layer's pages run one codec, derived from a
//! [`PerLayerQConfig`]:
//!
//! * **Exact** (`bf16-exact` / quantization off — the default): rows are
//!   stored as raw f32 little-endian bytes. Writing and reading a page
//!   is a bit-copy, so the PR-4 decode contract — cached step logits
//!   bit-identical to the full-prefix reference — holds unchanged
//!   (`rust/tests/decode.rs` and the Exact half of
//!   `rust/tests/kvpool.rs` pin it, evict-and-requeue included).
//! * **Mx** (any `quant_on` config): each row is blocked along
//!   `d_model`, and every block stores bit-packed sign-magnitude element
//!   codes (FP8 → 8 bits, FP6 → 6, FP4 → 4) plus its scale — a 1-byte
//!   level index for UE4M3/UE5M3/E8M0-class scale formats, a 4-byte f32
//!   for quasi-continuous BF16 scales — through the exact same encode
//!   pipeline as [`crate::quant::packed::PackedMxTensor`]. The decode
//!   guarantee is deliberately **weaker** and precisely stated: a cached
//!   row reads back as `fake_quant(scheme, row)` of the row that was
//!   written, bit for bit. Attention therefore runs over quantized K/V,
//!   and logits carry the corresponding error (the in-vivo testbed for
//!   the paper's block-size anomaly — `microscale kv-sweep`). What *is*
//!   still exact: incremental decode and whole-prefix re-forward see the
//!   same quantized rows, so KV-cached stepping remains bit-identical
//!   to re-running the prefix **under the same codec** (pinned by the
//!   differential matrix in `rust/tests/kvpool.rs`).
//!
//! Per-tensor ("-S") KV configs are refused at [`KvPool::build`]: their
//! eq. 11 absmax spans the whole stream, which rows written one step at
//! a time can never see.
//!
//! # Accounting
//!
//! Pages are allocated lazily as rows append and freed eagerly when a
//! sequence resets (eviction) or drops. [`KvPool::bytes_for_rows`]
//! prices a planned append exactly — same page arithmetic the allocator
//! uses — which is what lets the scheduler *reserve* a step's pages up
//! front and evict-and-requeue instead of failing mid-forward. A failed
//! allocation (budget exhausted) changes nothing and is counted in
//! [`KvPoolStats::failed_allocs`].

use std::sync::{Arc, Mutex};

use anyhow::ensure;

use crate::quant::packed::{encode_block, pack_codes, unpack_codes, LevelCodec};
use crate::quant::QuantScheme;
use crate::util::simd;
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::PerLayerQConfig;

use super::packed_model::SeqKv;

/// How one layer's pages encode cache rows (see module docs).
enum CodecKind {
    /// Raw f32 LE rows — bit-identical storage, the PR-4 contract.
    Exact,
    /// Per-block element codes + scales via the packed-MX pipeline.
    Mx {
        scheme: QuantScheme,
        elem: LevelCodec,
        /// bits per element code (sign + magnitude index)
        elem_bits: u32,
        /// signed decode LUT over the full code space
        lut: Vec<f32>,
        /// 1-byte scale codec; `None` stores f32 scales (BF16 class)
        scale: Option<LevelCodec>,
    },
}

/// One layer's codec plus its derived row geometry.
struct LayerCodec {
    kind: CodecKind,
    /// exact bytes one cache row occupies inside a page
    row_bytes: usize,
}

impl LayerCodec {
    fn exact(d: usize) -> LayerCodec {
        LayerCodec { kind: CodecKind::Exact, row_bytes: d * 4 }
    }

    fn mx(scheme: QuantScheme, d: usize) -> crate::Result<LayerCodec> {
        ensure!(
            !scheme.per_tensor,
            "per-tensor (-S) KV configs are unsupported: the eq. 11 absmax \
             spans the whole stream, which incremental appends never see"
        );
        ensure!(
            d % scheme.block_size == 0,
            "KV block size {} must divide d_model {d}",
            scheme.block_size
        );
        let elem = LevelCodec::for_elem(&scheme.elem);
        let elem_bits = elem.mag_bits() + 1;
        ensure!(
            elem_bits <= 8,
            "element format {} needs {elem_bits} bits/code (max 8)",
            scheme.elem.name()
        );
        let scale = LevelCodec::for_scale(&scheme.scale);
        let scale_bytes = if scale.is_some() { 1 } else { 4 };
        let row_bytes = (d * elem_bits as usize + 7) / 8
            + (d / scheme.block_size) * scale_bytes;
        let lut = elem.signed_lut();
        Ok(LayerCodec {
            kind: CodecKind::Mx { scheme, elem, elem_bits, lut, scale },
            row_bytes,
        })
    }

    fn id(&self) -> String {
        match &self.kind {
            CodecKind::Exact => "exact".to_string(),
            CodecKind::Mx { scheme, .. } => scheme.id(),
        }
    }

    /// Encode one `d`-wide row into `out` (`row_bytes` long).
    /// `codes` is a zeroed `d`-byte scratch buffer (re-zeroed here).
    fn encode_row(
        &self,
        row: &[f32],
        out: &mut [u8],
        codes: &mut [u8],
    ) -> crate::Result<()> {
        match &self.kind {
            CodecKind::Exact => {
                for (c, &v) in out.chunks_exact_mut(4).zip(row) {
                    c.copy_from_slice(&v.to_le_bytes());
                }
            }
            CodecKind::Mx { scheme, elem, elem_bits, scale, .. } => {
                let d = row.len();
                let bs = scheme.block_size;
                let code_bytes = (d * *elem_bits as usize + 7) / 8;
                codes[..d].fill(0);
                let (code_region, scale_region) = out.split_at_mut(code_bytes);
                for (bi, block) in row.chunks(bs).enumerate() {
                    let s = encode_block(
                        scheme,
                        elem,
                        1.0,
                        block,
                        &mut codes[bi * bs..bi * bs + block.len()],
                    )?;
                    match scale {
                        Some(sc) => {
                            scale_region[bi] = sc.encode_mag(s).ok_or_else(
                                || {
                                    anyhow::anyhow!(
                                        "KV scale {s} is not on the {} grid",
                                        scheme.scale.name
                                    )
                                },
                            )? as u8;
                        }
                        None => scale_region[bi * 4..bi * 4 + 4]
                            .copy_from_slice(&s.to_le_bytes()),
                    }
                }
                pack_codes(&codes[..d], *elem_bits, code_region);
            }
        }
        Ok(())
    }

    /// Decode one row from `data` (`row_bytes` long) into `out` (`d`),
    /// using `codes` as a zeroable `d`-byte scratch.
    fn decode_row(&self, data: &[u8], out: &mut [f32], codes: &mut [u8]) {
        match &self.kind {
            CodecKind::Exact => {
                for (v, c) in out.iter_mut().zip(data.chunks_exact(4)) {
                    *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            CodecKind::Mx { scheme, elem_bits, lut, scale, .. } => {
                let d = out.len();
                let bs = scheme.block_size;
                let code_bytes = (d * *elem_bits as usize + 7) / 8;
                let (code_region, scale_region) = data.split_at(code_bytes);
                unpack_codes(code_region, *elem_bits, &mut codes[..d]);
                for (bi, block) in out.chunks_mut(bs).enumerate() {
                    let s = match scale {
                        Some(sc) => sc.decode(scale_region[bi] as u32),
                        None => f32::from_le_bytes([
                            scale_region[bi * 4],
                            scale_region[bi * 4 + 1],
                            scale_region[bi * 4 + 2],
                            scale_region[bi * 4 + 3],
                        ]),
                    };
                    // same op order as fake_quant: s * (±level), one
                    // rounded multiply per element, so any lane width
                    // computes identical bits ([`crate::util::simd`]
                    // dispatches: FP4's 16-entry LUT as an in-register
                    // shuffle, FP6/FP8 as a gather). A collapsed block
                    // (s = 0) fills +0.0 — its codes were written as
                    // zero.
                    if s > 0.0 {
                        let bc = &codes[bi * bs..bi * bs + block.len()];
                        if *elem_bits == 4 {
                            simd::scale_lut16(s, bc, lut, block);
                        } else {
                            simd::scale_lut(s, bc, lut, block);
                        }
                    } else {
                        block.fill(0.0);
                    }
                }
            }
        }
    }
}

/// One live page: encoded row payload plus its fill level.
struct Page {
    data: Vec<u8>,
    rows: usize,
}

/// Allocator state behind the pool mutex.
struct Inner {
    /// handle → page (freed handles are `None` and recycled)
    slots: Vec<Option<Page>>,
    free_slots: Vec<u32>,
    used_bytes: usize,
    peak_bytes: usize,
    allocs: u64,
    frees: u64,
    failed: u64,
}

/// A snapshot of the pool's allocation counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Pages allocated over the pool's lifetime.
    pub allocs: u64,
    /// Pages freed over the pool's lifetime.
    pub frees: u64,
    /// Allocations refused because they would exceed the budget.
    pub failed_allocs: u64,
    /// Pages currently live.
    pub live_pages: usize,
    /// Bytes currently allocated (sum of live page payloads — exact).
    pub used_bytes: usize,
    /// High-water mark of [`KvPoolStats::used_bytes`].
    pub peak_bytes: usize,
}

/// The process-wide paged KV arena (see module docs): fixed-row pages,
/// a hard byte budget, one codec per layer. Shared by every sequence
/// created through [`KvPool::seq`]; thread-safe (allocation state sits
/// behind one mutex).
pub struct KvPool {
    d_model: usize,
    n_layers: usize,
    page_rows: usize,
    budget: usize,
    layers: Vec<LayerCodec>,
    inner: Mutex<Inner>,
}

impl KvPool {
    /// Build a pool for `dims` with per-layer KV codecs from `kv_cfg`
    /// (`quant_on == false` → Exact; anything else → Mx with that
    /// element/scale at `block_size`-wide blocks along `d_model`).
    /// `page_rows` cache rows per page; `budget_bytes` caps the live
    /// page bytes across all sequences.
    pub fn build(
        dims: &ModelDims,
        kv_cfg: &PerLayerQConfig,
        block_size: usize,
        page_rows: usize,
        budget_bytes: usize,
    ) -> crate::Result<Arc<KvPool>> {
        ensure!(page_rows > 0, "page_rows must be positive");
        ensure!(dims.n_layers > 0 && dims.d_model > 0, "degenerate dims");
        let mut layers = Vec::with_capacity(dims.n_layers);
        for l in 0..dims.n_layers {
            let cfg = kv_cfg.layer(l);
            let lc = if cfg.quant_on {
                LayerCodec::mx(cfg.scheme(block_size), dims.d_model)?
            } else {
                LayerCodec::exact(dims.d_model)
            };
            layers.push(lc);
        }
        Ok(Arc::new(KvPool {
            d_model: dims.d_model,
            n_layers: dims.n_layers,
            page_rows,
            budget: budget_bytes,
            layers,
            inner: Mutex::new(Inner {
                slots: Vec::new(),
                free_slots: Vec::new(),
                used_bytes: 0,
                peak_bytes: 0,
                allocs: 0,
                frees: 0,
                failed: 0,
            }),
        }))
    }

    /// All-layers-Exact pool: the f32 PR-4 contract, now byte-budgeted.
    pub fn exact(
        dims: &ModelDims,
        page_rows: usize,
        budget_bytes: usize,
    ) -> crate::Result<Arc<KvPool>> {
        Self::build(
            dims,
            &PerLayerQConfig::uniform(crate::runtime::QConfig::baseline()),
            1,
            page_rows,
            budget_bytes,
        )
    }

    /// A fresh empty sequence cache backed by this pool.
    pub fn seq(self: &Arc<Self>) -> SeqKv {
        SeqKv::paged(PagedKv::new(self.clone()))
    }

    /// Row width every page stores (the model's `d_model`).
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Layers per sequence.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Cache rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// The hard byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bytes currently allocated (exact; see [`KvPoolStats`]).
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().used_bytes
    }

    /// Budget headroom.
    pub fn free_bytes(&self) -> usize {
        self.budget.saturating_sub(self.used_bytes())
    }

    /// Allocation counters snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let g = self.inner.lock().unwrap();
        KvPoolStats {
            allocs: g.allocs,
            frees: g.frees,
            failed_allocs: g.failed,
            live_pages: (g.allocs - g.frees) as usize,
            used_bytes: g.used_bytes,
            peak_bytes: g.peak_bytes,
        }
    }

    /// Exact bytes one cache row of `layer` occupies.
    pub fn row_bytes(&self, layer: usize) -> usize {
        self.layers[layer].row_bytes
    }

    /// Exact bytes of one `layer` page (`page_rows · row_bytes`).
    pub fn page_bytes(&self, layer: usize) -> usize {
        self.page_rows * self.layers[layer].row_bytes
    }

    /// Row-level storage cost of one cached position across all layers
    /// and both K/V streams — the marginal (page-amortized) cost of one
    /// decoded token.
    pub fn position_bytes(&self) -> usize {
        self.layers.iter().map(|lc| 2 * lc.row_bytes).sum()
    }

    /// The codec id of `layer`'s pages (`"exact"` or a scheme id).
    pub fn codec_id(&self, layer: usize) -> String {
        self.layers[layer].id()
    }

    /// Whether every layer runs the Exact codec (the bit-exact decode
    /// contract applies to the whole model).
    pub fn is_exact(&self) -> bool {
        self.layers.iter().all(|l| matches!(l.kind, CodecKind::Exact))
    }

    /// Push `rows` (`n · d_model` values, row-major) through `layer`'s
    /// page codec — encode then decode, no page allocation — returning
    /// what a cached read would see. This is the codec's contract
    /// surface (`fake_quant` of each row under the layer scheme, bit
    /// for bit, for Mx; identity for Exact) exposed directly so the
    /// differential suite (`rust/tests/simd.rs`) can compare it across
    /// `MICROSCALE_SIMD` levels without standing up sequences.
    pub fn codec_roundtrip(
        &self,
        layer: usize,
        rows: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let d = self.d_model;
        ensure!(
            rows.len() % d == 0,
            "rows length {} is not a multiple of d_model {d}",
            rows.len()
        );
        let lc = &self.layers[layer];
        let mut buf = vec![0u8; lc.row_bytes];
        let mut codes = vec![0u8; d];
        let mut out = vec![0.0f32; rows.len()];
        for (row, orow) in rows.chunks(d).zip(out.chunks_mut(d)) {
            lc.encode_row(row, &mut buf, &mut codes)?;
            lc.decode_row(&buf, orow, &mut codes);
        }
        Ok(out)
    }

    /// Exact page bytes that growing a sequence from `existing` to
    /// `existing + new` resident positions allocates — the same
    /// arithmetic the allocator performs, so a reservation made with
    /// this number cannot fail mid-forward.
    pub fn bytes_for_rows(&self, existing: usize, new: usize) -> usize {
        let pages =
            |rows: usize| (rows + self.page_rows - 1) / self.page_rows;
        let dp = pages(existing + new) - pages(existing);
        self.layers.iter().map(|lc| 2 * dp * self.page_rows * lc.row_bytes).sum()
    }

    /// Page bytes a fresh sequence of `positions` rows allocates.
    pub fn bytes_for_positions(&self, positions: usize) -> usize {
        self.bytes_for_rows(0, positions)
    }

    /// Allocate one `layer` page against the budget.
    fn alloc(&self, layer: usize) -> crate::Result<u32> {
        let pb = self.page_bytes(layer);
        let mut g = self.inner.lock().unwrap();
        if g.used_bytes + pb > self.budget {
            g.failed += 1;
            anyhow::bail!(
                "KV pool budget exhausted: {} used + {pb} page bytes > {} \
                 budget (evict or raise the budget)",
                g.used_bytes,
                self.budget
            );
        }
        g.used_bytes += pb;
        g.peak_bytes = g.peak_bytes.max(g.used_bytes);
        g.allocs += 1;
        let page = Page { data: vec![0u8; pb], rows: 0 };
        let id = match g.free_slots.pop() {
            Some(id) => {
                g.slots[id as usize] = Some(page);
                id
            }
            None => {
                g.slots.push(Some(page));
                (g.slots.len() - 1) as u32
            }
        };
        Ok(id)
    }

    /// Free one page (memory is released, not retained).
    fn free(&self, id: u32) {
        let mut g = self.inner.lock().unwrap();
        let page = g.slots[id as usize].take().expect("double free");
        g.used_bytes -= page.data.len();
        g.frees += 1;
        g.free_slots.push(id);
    }

    /// Append `rows` (`n · d_model` values) to one layer stream. Every
    /// page the append needs is allocated **up front**, then one lock
    /// acquisition covers the whole row-encode loop (this runs once per
    /// layer-stream per decode step — the hot path). A budget failure
    /// is atomic for the stream: pages this call allocated are freed
    /// again and no rows are written (callers additionally reserve via
    /// [`KvPool::bytes_for_rows`], so the path is cold).
    fn stream_append(
        &self,
        layer: usize,
        stream: &mut Stream,
        rows: &[f32],
        codes: &mut [u8],
    ) -> crate::Result<()> {
        let d = self.d_model;
        debug_assert_eq!(rows.len() % d, 0);
        let total = stream.rows + rows.len() / d;
        let pages_before = stream.pages.len();
        while stream.pages.len() * self.page_rows < total {
            match self.alloc(layer) {
                Ok(id) => stream.pages.push(id),
                Err(e) => {
                    for id in stream.pages.drain(pages_before..) {
                        self.free(id);
                    }
                    return Err(e);
                }
            }
        }
        let lc = &self.layers[layer];
        let rb = lc.row_bytes;
        let mut g = self.inner.lock().unwrap();
        for row in rows.chunks_exact(d) {
            let page_id = stream.pages[stream.rows / self.page_rows];
            let slot = stream.rows % self.page_rows;
            let page = g.slots[page_id as usize]
                .as_mut()
                .expect("stream page is live");
            debug_assert_eq!(page.rows, slot);
            lc.encode_row(row, &mut page.data[slot * rb..(slot + 1) * rb], codes)?;
            page.rows = slot + 1;
            stream.rows += 1;
        }
        Ok(())
    }

    /// Decode a whole layer's K and V streams into `k_out`/`v_out`
    /// (cleared first) under a single lock acquisition — the spine's
    /// per-layer attention read.
    fn stream_gather_pair(
        &self,
        layer: usize,
        ks: &Stream,
        vs: &Stream,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
        codes: &mut [u8],
    ) {
        let d = self.d_model;
        let lc = &self.layers[layer];
        let g = self.inner.lock().unwrap();
        for (stream, out) in [(ks, k_out), (vs, v_out)] {
            out.clear();
            out.resize(stream.rows * d, 0.0);
            for (pi, &page_id) in stream.pages.iter().enumerate() {
                let page = g.slots[page_id as usize]
                    .as_ref()
                    .expect("stream page is live");
                let base = pi * self.page_rows;
                // saturating: an aborted append may leave an allocated
                // page holding no rows for this stream
                let n = page.rows.min(stream.rows.saturating_sub(base));
                for r in 0..n {
                    lc.decode_row(
                        &page.data[r * lc.row_bytes..(r + 1) * lc.row_bytes],
                        &mut out[(base + r) * d..(base + r + 1) * d],
                        codes,
                    );
                }
            }
        }
    }

    /// Release every page of a stream.
    fn stream_free(&self, stream: &mut Stream) {
        for id in stream.pages.drain(..) {
            self.free(id);
        }
        stream.rows = 0;
    }
}

/// One layer-stream's page handles.
#[derive(Default)]
struct Stream {
    pages: Vec<u32>,
    rows: usize,
}

/// A pool-backed sequence cache: per layer, one K and one V page
/// stream. Created via [`KvPool::seq`] (which wraps it in the public
/// [`SeqKv`]); pages return to the pool on [`PagedKv::reset`] or drop.
pub(crate) struct PagedKv {
    pool: Arc<KvPool>,
    k: Vec<Stream>,
    v: Vec<Stream>,
    /// `d_model`-byte element-code scratch shared by every append and
    /// gather (the per-row codec would otherwise allocate per call on
    /// the decode hot path).
    codes: Vec<u8>,
}

impl PagedKv {
    fn new(pool: Arc<KvPool>) -> PagedKv {
        let mk = || (0..pool.n_layers).map(|_| Stream::default()).collect();
        let codes = vec![0u8; pool.d_model];
        PagedKv { k: mk(), v: mk(), codes, pool }
    }

    pub(crate) fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    pub(crate) fn layers(&self) -> usize {
        self.k.len()
    }

    /// `(k rows, v rows)` resident in `layer`.
    pub(crate) fn rows(&self, layer: usize) -> (usize, usize) {
        (self.k[layer].rows, self.v[layer].rows)
    }

    pub(crate) fn append(
        &mut self,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> crate::Result<()> {
        self.pool.stream_append(
            layer,
            &mut self.k[layer],
            k_rows,
            &mut self.codes,
        )?;
        self.pool.stream_append(
            layer,
            &mut self.v[layer],
            v_rows,
            &mut self.codes,
        )
    }

    /// Decode one layer's K and V rows into the output buffers; the
    /// caller threads the element-code scratch (resized here) so the
    /// per-token attention read allocates nothing.
    pub(crate) fn gather_with(
        &self,
        layer: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
        codes: &mut Vec<u8>,
    ) {
        codes.resize(self.pool.d_model, 0);
        self.pool.stream_gather_pair(
            layer,
            &self.k[layer],
            &self.v[layer],
            k_out,
            v_out,
            codes,
        );
    }

    /// Allocating convenience wrapper over [`PagedKv::gather_with`]
    /// (cold paths: trace capture, tests).
    pub(crate) fn gather(
        &self,
        layer: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let mut codes = Vec::new();
        self.gather_with(layer, k_out, v_out, &mut codes);
    }

    /// Allocated page bytes across all streams (what this sequence
    /// holds of the pool budget — includes partially filled pages).
    pub(crate) fn resident_bytes(&self) -> usize {
        self.k
            .iter()
            .zip(&self.v)
            .enumerate()
            .map(|(l, (ks, vs))| {
                (ks.pages.len() + vs.pages.len()) * self.pool.page_bytes(l)
            })
            .sum()
    }

    /// Free every page and return to the empty state.
    pub(crate) fn reset(&mut self) {
        for s in self.k.iter_mut().chain(self.v.iter_mut()) {
            self.pool.stream_free(s);
        }
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.reset();
    }
}

impl std::fmt::Debug for PagedKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedKv")
            .field("layers", &self.k.len())
            .field("rows", &self.k.first().map_or(0, |s| s.rows))
            .field("resident_bytes", &self.resident_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::quant::fake_quant;
    use crate::runtime::QConfig;

    fn dims(d_model: usize, n_layers: usize) -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model,
            n_heads: 1,
            n_layers,
            d_ff: 2 * d_model,
            seq_len: 64,
        }
    }

    #[test]
    fn exact_pages_roundtrip_bit_identically() {
        let pool = KvPool::exact(&dims(16, 2), 4, 1 << 20).unwrap();
        let mut kv = PagedKv::new(pool.clone());
        // awkward values: -0.0, subnormals, extremes
        let mut rng = Pcg64::new(3);
        let mut rows = rng.normal_vec_f32(6 * 16, 1e-3);
        rows[0] = -0.0;
        rows[1] = f32::MIN_POSITIVE / 2.0;
        rows[2] = 3.4e38;
        rows[3] = -1.1754944e-38;
        kv.append(0, &rows, &rows).unwrap();
        let (mut k, mut v) = (Vec::new(), Vec::new());
        kv.gather(0, &mut k, &mut v);
        assert_eq!(k.len(), rows.len());
        for (a, b) in rows.iter().zip(k.iter().chain(&v)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mx_pages_decode_as_fake_quant_of_the_written_row() {
        // the stated Mx error model: reading back a row yields exactly
        // fake_quant(scheme, row) — across elements, scales (incl. the
        // f32-scale bf16 path), block sizes, and σ regimes
        crate::util::check::property("kv mx roundtrip", 60, |g| {
            let d = *g.pick(&[16usize, 32, 64]);
            let bs = *g.pick(&[4usize, 8, 16]);
            if d % bs != 0 {
                return;
            }
            let elem = *g.pick(&["fp4_e2m1", "fp8_e4m3", "fp6_e2m3"]);
            let scale = *g.pick(&["ue4m3", "ue5m3", "e8m0", "bf16"]);
            let sigma = g.log_uniform(1e-5, 1.0);
            let cfg = QConfig::named(elem, scale, false).unwrap();
            let pool = KvPool::build(
                &dims(d, 1),
                &PerLayerQConfig::uniform(cfg),
                bs,
                4,
                1 << 24,
            )
            .unwrap();
            let mut kv = PagedKv::new(pool);
            let n_rows = g.usize_in(1, 9);
            let rows = g.normal_vec_f32(n_rows * d, sigma);
            kv.append(0, &rows, &rows).unwrap();
            let (mut k, mut v) = (Vec::new(), Vec::new());
            kv.gather(0, &mut k, &mut v);
            let scheme = cfg.scheme(bs);
            // per-row quantization: blocks never span rows
            let mut want = Vec::new();
            for row in rows.chunks(d) {
                want.extend(fake_quant(&scheme, row));
            }
            for (i, (a, b)) in k.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{elem}/{scale}/bs{bs} elem {i}: {a} vs {b}"
                );
            }
            assert_eq!(v, k);
        });
    }

    #[test]
    fn byte_accounting_is_exact_after_every_alloc_and_free() {
        let d = dims(8, 2);
        let pool = KvPool::exact(&d, 4, 10_000).unwrap();
        let row_bytes = 8 * 4;
        let page_bytes = 4 * row_bytes;
        assert_eq!(pool.page_bytes(0), page_bytes);
        assert_eq!(pool.position_bytes(), 2 * 2 * row_bytes);
        let mut kv = PagedKv::new(pool.clone());
        let mut expect = 0usize;
        let one = vec![0.5f32; 8];
        for step in 1..=9usize {
            for layer in 0..2 {
                kv.append(layer, &one, &one).unwrap();
            }
            // each layer has 2 streams; pages grow at rows 1, 5, 9...
            let pages_per_stream = (step + 3) / 4;
            expect = 2 * 2 * pages_per_stream * page_bytes;
            assert_eq!(pool.used_bytes(), expect, "after step {step}");
            assert_eq!(kv.resident_bytes(), expect);
            assert_eq!(
                pool.bytes_for_rows(0, step),
                expect,
                "reservation math at {step} rows"
            );
        }
        // marginal growth math matches the allocator exactly
        assert_eq!(pool.bytes_for_rows(9, 3), 0); // rows 10..12 fit page 3
        assert_eq!(pool.bytes_for_rows(9, 4), 4 * page_bytes);
        kv.reset();
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(kv.resident_bytes(), 0);
        let s = pool.stats();
        assert_eq!(s.allocs, s.frees);
        assert_eq!(s.failed_allocs, 0);
        assert_eq!(s.peak_bytes, expect);
        // drop-frees also return pages
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &one, &one).unwrap();
        assert!(pool.used_bytes() > 0);
        drop(kv2);
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn budget_refusal_leaves_accounting_unchanged() {
        let d = dims(8, 1);
        // room for exactly 2 pages (one K + one V page of 4 rows)
        let page = 4 * 8 * 4;
        let pool = KvPool::exact(&d, 4, 2 * page).unwrap();
        let mut kv = PagedKv::new(pool.clone());
        let rows = vec![1.0f32; 4 * 8];
        kv.append(0, &rows, &rows).unwrap();
        assert_eq!(pool.used_bytes(), 2 * page);
        assert_eq!(pool.free_bytes(), 0);
        let one = vec![1.0f32; 8];
        let err = kv.append(0, &one, &one).unwrap_err();
        assert!(format!("{err}").contains("budget exhausted"));
        assert_eq!(pool.used_bytes(), 2 * page);
        assert_eq!(pool.stats().failed_allocs, 1);
        // the failed append wrote nothing: row counts are unchanged
        assert_eq!(kv.rows(0), (4, 4));
        kv.reset();
        assert_eq!(pool.used_bytes(), 0);
        // after the free the same append succeeds
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &one, &one).unwrap();
        assert_eq!(kv2.rows(0), (1, 1));
    }

    #[test]
    fn build_rejects_unsupported_kv_configs() {
        let d = dims(16, 1);
        // per-tensor KV scaling is refused
        let per_tensor = PerLayerQConfig::uniform(
            QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
        );
        assert!(KvPool::build(&d, &per_tensor, 8, 4, 1 << 20).is_err());
        // block size must divide d_model
        let fp8 = PerLayerQConfig::uniform(
            QConfig::named("fp8_e4m3", "ue5m3", false).unwrap(),
        );
        assert!(KvPool::build(&d, &fp8, 12, 4, 1 << 20).is_err());
        assert!(KvPool::build(&d, &fp8, 8, 0, 1 << 20).is_err());
        let pool = KvPool::build(&d, &fp8, 8, 4, 1 << 20).unwrap();
        assert_eq!(pool.codec_id(0), "fp8_e4m3/ue5m3/bs8");
        assert!(!pool.is_exact());
        // fp8 codes (8b) + 1-byte scales every 8 elems
        assert_eq!(pool.row_bytes(0), 16 + 2);
    }

    #[test]
    fn mixed_per_layer_codecs_price_rows_independently() {
        let d = dims(32, 3);
        let cfg = PerLayerQConfig::uniform(QConfig::baseline())
            .with_override(1, QConfig::fp4("ue5m3").unwrap())
            .with_override(
                2,
                QConfig::named("fp8_e4m3", "ue4m3", false).unwrap(),
            );
        let pool = KvPool::build(&d, &cfg, 16, 4, 1 << 24).unwrap();
        assert_eq!(pool.row_bytes(0), 32 * 4); // exact f32
        assert_eq!(pool.row_bytes(1), 16 + 2); // fp4: d/2 codes + 2 scales
        assert_eq!(pool.row_bytes(2), 32 + 2); // fp8: d codes + 2 scales
        assert_eq!(
            pool.position_bytes(),
            2 * (128 + 18 + 34),
            "K+V row bytes across layers"
        );
        assert_eq!(pool.codec_id(0), "exact");
    }
}
