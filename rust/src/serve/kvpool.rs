//! Paged, byte-budgeted KV-cache storage with pluggable page codecs.
//!
//! PR 4's decode engine stored each sequence's K/V rows in unbounded
//! per-sequence `Vec<f32>`s — fine for tests, unusable under production
//! memory pressure, where the KV cache (not the weights) dominates the
//! resident bytes of serving at scale. This module replaces that storage
//! with a process-wide [`KvPool`]: fixed-size **pages** (a page holds
//! [`KvPool::page_rows`] cache rows of one layer's K *or* V stream)
//! allocated against a hard byte budget, with every allocation and free
//! accounted exactly ([`KvPool::used_bytes`] is the sum of live page
//! bytes, nothing estimated). Sequences hold page handles per layer
//! (the internal `PagedKv`, wrapped by [`super::SeqKv`]); the
//! scheduler turns the budget into admission/eviction decisions
//! (DESIGN.md §11).
//!
//! # Page codecs and the exactness-contract split
//!
//! Each layer's pages run one codec, derived from a
//! [`PerLayerQConfig`]:
//!
//! * **Exact** (`bf16-exact` / quantization off — the default): rows are
//!   stored as raw f32 little-endian bytes. Writing and reading a page
//!   is a bit-copy, so the PR-4 decode contract — cached step logits
//!   bit-identical to the full-prefix reference — holds unchanged
//!   (`rust/tests/decode.rs` and the Exact half of
//!   `rust/tests/kvpool.rs` pin it, evict-and-requeue included).
//! * **Mx** (any `quant_on` config): each row is blocked along
//!   `d_model`, and every block stores bit-packed sign-magnitude element
//!   codes (FP8 → 8 bits, FP6 → 6, FP4 → 4) plus its scale — a 1-byte
//!   level index for UE4M3/UE5M3/E8M0-class scale formats, a 4-byte f32
//!   for quasi-continuous BF16 scales — through the exact same encode
//!   pipeline as [`crate::quant::packed::PackedMxTensor`]. The decode
//!   guarantee is deliberately **weaker** and precisely stated: a cached
//!   row reads back as `fake_quant(scheme, row)` of the row that was
//!   written, bit for bit. Attention therefore runs over quantized K/V,
//!   and logits carry the corresponding error (the in-vivo testbed for
//!   the paper's block-size anomaly — `microscale kv-sweep`). What *is*
//!   still exact: incremental decode and whole-prefix re-forward see the
//!   same quantized rows, so KV-cached stepping remains bit-identical
//!   to re-running the prefix **under the same codec** (pinned by the
//!   differential matrix in `rust/tests/kvpool.rs`).
//!
//! Per-tensor ("-S") KV configs are refused at [`KvPool::build`]: their
//! eq. 11 absmax spans the whole stream, which rows written one step at
//! a time can never see.
//!
//! # Accounting
//!
//! Pages are allocated lazily as rows append and freed eagerly when a
//! sequence resets (eviction) or drops. [`KvPool::bytes_for_rows`]
//! prices a planned append exactly — same page arithmetic the allocator
//! uses — which is what lets the scheduler *reserve* a step's pages up
//! front and evict-and-requeue instead of failing mid-forward. A failed
//! allocation (budget exhausted) changes nothing and is counted in
//! [`KvPoolStats::failed_allocs`].
//!
//! # Prefix sharing (hash-consed read-only pages)
//!
//! Production traffic is dominated by shared system prompts: N
//! concurrent requests over one 1k-token prefix write N bit-identical
//! copies of its KV pages (prefill is deterministic, so identical
//! prompt → identical rows → identical encoded bytes). With
//! [`KvPool::build_with`]`(.., prefix_sharing: true)` the pool
//! **hash-conses full pages by content**: the moment a page fills, its
//! payload is digested (dual independent FNV-1a over the page words,
//! confirmed by a full byte compare on any digest hit — a hash
//! collision can never alias two different pages) and looked up in a
//! per-codec intern table. A hit repoints the stream at the canonical
//! page, bumps its refcount, and physically frees the duplicate;
//! a miss makes this page the canonical copy. Sharing is invisible to
//! readers — a shared page decodes the same bytes as the private copy
//! it replaced, so token streams stay bit-identical to the unshared
//! pool (`rust/tests/prefix.rs` pins this across codecs, eviction, and
//! cancellation).
//!
//! Copy-on-write degenerates structurally: only **full** pages are
//! interned, full pages are never written again (appends land in the
//! tail page at `rows % page_rows`), and every tail page is private.
//! Divergence after a shared prefix therefore needs no write fault —
//! the diverging rows go to pages that were never shared. An explicit
//! prefix clone ([`SeqKv::fork`]) shares full pages by refcount bump
//! and deep-copies only the partial tail.
//!
//! Accounting under sharing: [`KvPoolStats::used_bytes`] and
//! `live_pages` count **physical** pages (a page freed by a dedup hit
//! really is released), [`KvPoolStats::shared_bytes`] is the extra
//! bytes an unshared pool would hold (`Σ (refs − 1) · page_bytes`),
//! and refcounted frees only destroy a page at its last reference.
//! [`KvPool::bytes_for_rows`] stays deliberately conservative — it
//! prices an append as if every page were private, so a reservation
//! can only over-estimate; dedup then returns the saved pages.
//! [`KvPool::build`] keeps sharing **off** so existing byte-accounting
//! contracts (kv-bench's `peak_bytes`/drain cross-checks) are
//! unchanged.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::ensure;

use crate::quant::packed::{encode_block, pack_codes, unpack_codes, LevelCodec};
use crate::quant::QuantScheme;
use crate::util::simd;
use crate::util::{fnv1a_words, FNV_OFFSET_BASIS};
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::PerLayerQConfig;

use super::packed_model::SeqKv;

/// How one layer's pages encode cache rows (see module docs).
enum CodecKind {
    /// Raw f32 LE rows — bit-identical storage, the PR-4 contract.
    Exact,
    /// Per-block element codes + scales via the packed-MX pipeline.
    Mx {
        scheme: QuantScheme,
        elem: LevelCodec,
        /// bits per element code (sign + magnitude index)
        elem_bits: u32,
        /// signed decode LUT over the full code space
        lut: Vec<f32>,
        /// 1-byte scale codec; `None` stores f32 scales (BF16 class)
        scale: Option<LevelCodec>,
    },
}

/// One layer's codec plus its derived row geometry.
struct LayerCodec {
    kind: CodecKind,
    /// exact bytes one cache row occupies inside a page
    row_bytes: usize,
}

impl LayerCodec {
    fn exact(d: usize) -> LayerCodec {
        LayerCodec { kind: CodecKind::Exact, row_bytes: d * 4 }
    }

    fn mx(scheme: QuantScheme, d: usize) -> crate::Result<LayerCodec> {
        ensure!(
            !scheme.per_tensor,
            "per-tensor (-S) KV configs are unsupported: the eq. 11 absmax \
             spans the whole stream, which incremental appends never see"
        );
        ensure!(
            d % scheme.block_size == 0,
            "KV block size {} must divide d_model {d}",
            scheme.block_size
        );
        let elem = LevelCodec::for_elem(&scheme.elem);
        let elem_bits = elem.mag_bits() + 1;
        ensure!(
            elem_bits <= 8,
            "element format {} needs {elem_bits} bits/code (max 8)",
            scheme.elem.name()
        );
        let scale = LevelCodec::for_scale(&scheme.scale);
        let scale_bytes = if scale.is_some() { 1 } else { 4 };
        let row_bytes = (d * elem_bits as usize + 7) / 8
            + (d / scheme.block_size) * scale_bytes;
        let lut = elem.signed_lut();
        Ok(LayerCodec {
            kind: CodecKind::Mx { scheme, elem, elem_bits, lut, scale },
            row_bytes,
        })
    }

    fn id(&self) -> String {
        match &self.kind {
            CodecKind::Exact => "exact".to_string(),
            CodecKind::Mx { scheme, .. } => scheme.id(),
        }
    }

    /// Encode one `d`-wide row into `out` (`row_bytes` long).
    /// `codes` is a zeroed `d`-byte scratch buffer (re-zeroed here).
    fn encode_row(
        &self,
        row: &[f32],
        out: &mut [u8],
        codes: &mut [u8],
    ) -> crate::Result<()> {
        match &self.kind {
            CodecKind::Exact => {
                for (c, &v) in out.chunks_exact_mut(4).zip(row) {
                    c.copy_from_slice(&v.to_le_bytes());
                }
            }
            CodecKind::Mx { scheme, elem, elem_bits, scale, .. } => {
                let d = row.len();
                let bs = scheme.block_size;
                let code_bytes = (d * *elem_bits as usize + 7) / 8;
                codes[..d].fill(0);
                let (code_region, scale_region) = out.split_at_mut(code_bytes);
                for (bi, block) in row.chunks(bs).enumerate() {
                    let s = encode_block(
                        scheme,
                        elem,
                        1.0,
                        block,
                        &mut codes[bi * bs..bi * bs + block.len()],
                    )?;
                    match scale {
                        Some(sc) => {
                            scale_region[bi] = sc.encode_mag(s).ok_or_else(
                                || {
                                    anyhow::anyhow!(
                                        "KV scale {s} is not on the {} grid",
                                        scheme.scale.name
                                    )
                                },
                            )? as u8;
                        }
                        None => scale_region[bi * 4..bi * 4 + 4]
                            .copy_from_slice(&s.to_le_bytes()),
                    }
                }
                pack_codes(&codes[..d], *elem_bits, code_region);
            }
        }
        Ok(())
    }

    /// Decode one row from `data` (`row_bytes` long) into `out` (`d`),
    /// using `codes` as a zeroable `d`-byte scratch.
    fn decode_row(&self, data: &[u8], out: &mut [f32], codes: &mut [u8]) {
        match &self.kind {
            CodecKind::Exact => {
                for (v, c) in out.iter_mut().zip(data.chunks_exact(4)) {
                    *v = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                }
            }
            CodecKind::Mx { scheme, elem_bits, lut, scale, .. } => {
                let d = out.len();
                let bs = scheme.block_size;
                let code_bytes = (d * *elem_bits as usize + 7) / 8;
                let (code_region, scale_region) = data.split_at(code_bytes);
                unpack_codes(code_region, *elem_bits, &mut codes[..d]);
                for (bi, block) in out.chunks_mut(bs).enumerate() {
                    let s = match scale {
                        Some(sc) => sc.decode(scale_region[bi] as u32),
                        None => f32::from_le_bytes([
                            scale_region[bi * 4],
                            scale_region[bi * 4 + 1],
                            scale_region[bi * 4 + 2],
                            scale_region[bi * 4 + 3],
                        ]),
                    };
                    // same op order as fake_quant: s * (±level), one
                    // rounded multiply per element, so any lane width
                    // computes identical bits ([`crate::util::simd`]
                    // dispatches: FP4's 16-entry LUT as an in-register
                    // shuffle, FP6/FP8 as a gather). A collapsed block
                    // (s = 0) fills +0.0 — its codes were written as
                    // zero.
                    if s > 0.0 {
                        let bc = &codes[bi * bs..bi * bs + block.len()];
                        if *elem_bits == 4 {
                            simd::scale_lut16(s, bc, lut, block);
                        } else {
                            simd::scale_lut(s, bc, lut, block);
                        }
                    } else {
                        block.fill(0.0);
                    }
                }
            }
        }
    }
}

/// Intern-table key: codec space + dual independent page digests.
/// Distinct codecs decode the same bytes differently, so pages only
/// dedup inside one codec space (layers with equal codec ids share a
/// space; K and V streams of one layer always do).
type DedupKey = (u32, u64, u64);

/// One live page: encoded row payload plus its fill level and, under
/// prefix sharing, its reference count / intern-table key.
struct Page {
    data: Vec<u8>,
    rows: usize,
    /// streams holding this page (> 1 only for hash-consed full pages)
    refs: u32,
    /// set iff this page is a canonical entry in `Inner::dedup`
    interned: Option<DedupKey>,
}

/// Allocator state behind the pool mutex.
struct Inner {
    /// handle → page (freed handles are `None` and recycled)
    slots: Vec<Option<Page>>,
    free_slots: Vec<u32>,
    /// content digest → canonical page handle (prefix sharing only)
    dedup: HashMap<DedupKey, u32>,
    used_bytes: usize,
    peak_bytes: usize,
    allocs: u64,
    frees: u64,
    failed: u64,
    dedup_hits: u64,
    /// `Σ (refs − 1) · page_bytes` over live pages — what an unshared
    /// pool would additionally hold
    shared_saved: usize,
    /// prefix registrations: pin handle → page ids each holding one
    /// extra reference ([`KvPool::pin_prefix`])
    pins: HashMap<u64, Vec<u32>>,
    next_pin: u64,
}

impl Inner {
    /// Allocate one `pb`-byte page against `budget`.
    fn alloc_page(&mut self, pb: usize, budget: usize) -> crate::Result<u32> {
        if self.used_bytes + pb > budget {
            self.failed += 1;
            anyhow::bail!(
                "KV pool budget exhausted: {} used + {pb} page bytes > \
                 {budget} budget (evict or raise the budget)",
                self.used_bytes,
            );
        }
        self.used_bytes += pb;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.allocs += 1;
        let page =
            Page { data: vec![0u8; pb], rows: 0, refs: 1, interned: None };
        let id = match self.free_slots.pop() {
            Some(id) => {
                self.slots[id as usize] = Some(page);
                id
            }
            None => {
                self.slots.push(Some(page));
                (self.slots.len() - 1) as u32
            }
        };
        Ok(id)
    }

    /// Drop one reference; the page is destroyed (memory released, not
    /// retained) only at its last reference, so `allocs − frees` and
    /// `used_bytes` always describe physical pages.
    fn free_page(&mut self, id: u32) {
        let page = self.slots[id as usize].as_mut().expect("double free");
        if page.refs > 1 {
            page.refs -= 1;
            self.shared_saved -= page.data.len();
            return;
        }
        let page = self.slots[id as usize].take().expect("double free");
        if let Some(key) = page.interned {
            self.dedup.remove(&key);
        }
        self.used_bytes -= page.data.len();
        self.frees += 1;
        self.free_slots.push(id);
    }
}

/// Dual independent FNV-1a digests over a page payload (u64 LE words,
/// zero-padded tail). Two 64-bit hashes make an accidental collision
/// astronomically unlikely, and the intern path byte-compares on every
/// digest hit anyway — the digests are an index, never the identity.
fn page_digest(data: &[u8]) -> (u64, u64) {
    let words = || {
        data.chunks(8).map(|c| {
            let mut b = [0u8; 8];
            b[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(b)
        })
    };
    (
        fnv1a_words(words(), FNV_OFFSET_BASIS),
        fnv1a_words(words(), !FNV_OFFSET_BASIS),
    )
}

/// A snapshot of the pool's allocation counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolStats {
    /// Pages allocated over the pool's lifetime.
    pub allocs: u64,
    /// Pages freed over the pool's lifetime.
    pub frees: u64,
    /// Allocations refused because they would exceed the budget.
    pub failed_allocs: u64,
    /// Pages currently live.
    pub live_pages: usize,
    /// Bytes currently allocated (sum of live page payloads — exact).
    pub used_bytes: usize,
    /// High-water mark of [`KvPoolStats::used_bytes`].
    pub peak_bytes: usize,
    /// Full pages deduplicated against an existing canonical copy
    /// (prefix sharing; 0 when sharing is off).
    pub dedup_hits: u64,
    /// Extra bytes an unshared pool would currently hold:
    /// `Σ (refs − 1) · page_bytes` over live pages.
    pub shared_bytes: usize,
}

/// The process-wide paged KV arena (see module docs): fixed-row pages,
/// a hard byte budget, one codec per layer. Shared by every sequence
/// created through [`KvPool::seq`]; thread-safe (allocation state sits
/// behind one mutex).
pub struct KvPool {
    d_model: usize,
    n_layers: usize,
    page_rows: usize,
    budget: usize,
    /// codec banks: `banks[0]` backs target sequences ([`KvPool::seq`]),
    /// `banks[1]` (present only on [`KvPool::build_spec`] pools) backs
    /// draft sequences ([`KvPool::draft_seq`]) under their own per-layer
    /// codecs. Both banks allocate from the same budget and counters.
    banks: Vec<Vec<LayerCodec>>,
    /// per-(bank, layer) dedup space: layers with equal codec ids share
    /// one — across banks too, since equal codecs decode equal bytes
    sharing_spaces: Vec<Vec<u32>>,
    /// hash-cons full pages by content (see module docs)
    sharing: bool,
    inner: Mutex<Inner>,
}

impl KvPool {
    /// Build a pool for `dims` with per-layer KV codecs from `kv_cfg`
    /// (`quant_on == false` → Exact; anything else → Mx with that
    /// element/scale at `block_size`-wide blocks along `d_model`).
    /// `page_rows` cache rows per page; `budget_bytes` caps the live
    /// page bytes across all sequences. Prefix sharing stays **off**
    /// (see [`KvPool::build_with`]).
    pub fn build(
        dims: &ModelDims,
        kv_cfg: &PerLayerQConfig,
        block_size: usize,
        page_rows: usize,
        budget_bytes: usize,
    ) -> crate::Result<Arc<KvPool>> {
        Self::build_with(dims, kv_cfg, block_size, page_rows, budget_bytes, false)
    }

    /// [`KvPool::build`] with prefix sharing selectable: when
    /// `prefix_sharing` is true, full pages are hash-consed by content
    /// so identical prefixes across sequences (and identical K/V
    /// streams) hold one refcounted physical copy — see the module
    /// docs for the exactness and accounting contracts.
    pub fn build_with(
        dims: &ModelDims,
        kv_cfg: &PerLayerQConfig,
        block_size: usize,
        page_rows: usize,
        budget_bytes: usize,
        prefix_sharing: bool,
    ) -> crate::Result<Arc<KvPool>> {
        Self::assemble(
            dims,
            vec![kv_cfg],
            block_size,
            page_rows,
            budget_bytes,
            prefix_sharing,
        )
    }

    /// [`KvPool::build_with`] plus a second codec bank for speculative
    /// decoding: draft sequences created through [`KvPool::draft_seq`]
    /// encode their pages under `draft_cfg` while target sequences keep
    /// `kv_cfg`, and both draw pages from the **same** byte budget and
    /// counters — draft cache bytes are real serving memory, priced by
    /// [`KvPool::draft_bytes_for_rows`] exactly like target bytes.
    pub fn build_spec(
        dims: &ModelDims,
        kv_cfg: &PerLayerQConfig,
        draft_cfg: &PerLayerQConfig,
        block_size: usize,
        page_rows: usize,
        budget_bytes: usize,
        prefix_sharing: bool,
    ) -> crate::Result<Arc<KvPool>> {
        Self::assemble(
            dims,
            vec![kv_cfg, draft_cfg],
            block_size,
            page_rows,
            budget_bytes,
            prefix_sharing,
        )
    }

    fn assemble(
        dims: &ModelDims,
        bank_cfgs: Vec<&PerLayerQConfig>,
        block_size: usize,
        page_rows: usize,
        budget_bytes: usize,
        prefix_sharing: bool,
    ) -> crate::Result<Arc<KvPool>> {
        ensure!(page_rows > 0, "page_rows must be positive");
        ensure!(dims.n_layers > 0 && dims.d_model > 0, "degenerate dims");
        let mut banks = Vec::with_capacity(bank_cfgs.len());
        for cfg in &bank_cfgs {
            let mut layers = Vec::with_capacity(dims.n_layers);
            for l in 0..dims.n_layers {
                let c = cfg.layer(l);
                let lc = if c.quant_on {
                    LayerCodec::mx(c.scheme(block_size), dims.d_model)?
                } else {
                    LayerCodec::exact(dims.d_model)
                };
                layers.push(lc);
            }
            banks.push(layers);
        }
        let mut space_ids: Vec<String> = Vec::new();
        let sharing_spaces = banks
            .iter()
            .map(|layers| {
                layers
                    .iter()
                    .map(|lc| {
                        let id = lc.id();
                        match space_ids.iter().position(|s| *s == id) {
                            Some(i) => i as u32,
                            None => {
                                space_ids.push(id);
                                (space_ids.len() - 1) as u32
                            }
                        }
                    })
                    .collect()
            })
            .collect();
        Ok(Arc::new(KvPool {
            d_model: dims.d_model,
            n_layers: dims.n_layers,
            page_rows,
            budget: budget_bytes,
            banks,
            sharing_spaces,
            sharing: prefix_sharing,
            inner: Mutex::new(Inner {
                slots: Vec::new(),
                free_slots: Vec::new(),
                dedup: HashMap::new(),
                used_bytes: 0,
                peak_bytes: 0,
                allocs: 0,
                frees: 0,
                failed: 0,
                dedup_hits: 0,
                shared_saved: 0,
                pins: HashMap::new(),
                next_pin: 1,
            }),
        }))
    }

    /// All-layers-Exact pool: the f32 PR-4 contract, now byte-budgeted.
    pub fn exact(
        dims: &ModelDims,
        page_rows: usize,
        budget_bytes: usize,
    ) -> crate::Result<Arc<KvPool>> {
        Self::build(
            dims,
            &PerLayerQConfig::uniform(crate::runtime::QConfig::baseline()),
            1,
            page_rows,
            budget_bytes,
        )
    }

    /// A fresh empty sequence cache backed by this pool.
    pub fn seq(self: &Arc<Self>) -> SeqKv {
        SeqKv::paged(PagedKv::new(self.clone()))
    }

    /// A fresh empty **draft** sequence cache: pages encode under the
    /// draft codec bank of a [`KvPool::build_spec`] pool.
    pub fn draft_seq(self: &Arc<Self>) -> crate::Result<SeqKv> {
        ensure!(
            self.has_draft_bank(),
            "pool has no draft codec bank (build it with KvPool::build_spec)"
        );
        Ok(SeqKv::paged(PagedKv::new_bank(self.clone(), 1)))
    }

    /// Whether this pool carries a second (draft) codec bank.
    pub fn has_draft_bank(&self) -> bool {
        self.banks.len() > 1
    }

    /// Row width every page stores (the model's `d_model`).
    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Layers per sequence.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Cache rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// The hard byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Bytes currently allocated (exact; see [`KvPoolStats`]).
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().used_bytes
    }

    /// Budget headroom.
    pub fn free_bytes(&self) -> usize {
        self.budget.saturating_sub(self.used_bytes())
    }

    /// Whether full pages are hash-consed by content (see
    /// [`KvPool::build_with`]).
    pub fn prefix_sharing(&self) -> bool {
        self.sharing
    }

    /// Allocation counters snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let g = self.inner.lock().unwrap();
        KvPoolStats {
            allocs: g.allocs,
            frees: g.frees,
            failed_allocs: g.failed,
            live_pages: (g.allocs - g.frees) as usize,
            used_bytes: g.used_bytes,
            peak_bytes: g.peak_bytes,
            dedup_hits: g.dedup_hits,
            shared_bytes: g.shared_saved,
        }
    }

    /// Exact bytes one cache row of `layer` occupies.
    pub fn row_bytes(&self, layer: usize) -> usize {
        self.banks[0][layer].row_bytes
    }

    /// Exact bytes of one `layer` page (`page_rows · row_bytes`).
    pub fn page_bytes(&self, layer: usize) -> usize {
        self.page_rows * self.banks[0][layer].row_bytes
    }

    /// [`KvPool::page_bytes`] for an explicit codec bank.
    fn bank_page_bytes(&self, bank: usize, layer: usize) -> usize {
        self.page_rows * self.banks[bank][layer].row_bytes
    }

    /// Row-level storage cost of one cached position across all layers
    /// and both K/V streams — the marginal (page-amortized) cost of one
    /// decoded token.
    pub fn position_bytes(&self) -> usize {
        self.banks[0].iter().map(|lc| 2 * lc.row_bytes).sum()
    }

    /// The codec id of `layer`'s pages (`"exact"` or a scheme id).
    pub fn codec_id(&self, layer: usize) -> String {
        self.banks[0][layer].id()
    }

    /// The codec id of `layer`'s pages in the draft bank.
    pub fn draft_codec_id(&self, layer: usize) -> Option<String> {
        self.banks.get(1).map(|b| b[layer].id())
    }

    /// Whether every layer runs the Exact codec (the bit-exact decode
    /// contract applies to the whole model).
    pub fn is_exact(&self) -> bool {
        self.banks[0].iter().all(|l| matches!(l.kind, CodecKind::Exact))
    }

    /// Push `rows` (`n · d_model` values, row-major) through `layer`'s
    /// page codec — encode then decode, no page allocation — returning
    /// what a cached read would see. This is the codec's contract
    /// surface (`fake_quant` of each row under the layer scheme, bit
    /// for bit, for Mx; identity for Exact) exposed directly so the
    /// differential suite (`rust/tests/simd.rs`) can compare it across
    /// `MICROSCALE_SIMD` levels without standing up sequences.
    pub fn codec_roundtrip(
        &self,
        layer: usize,
        rows: &[f32],
    ) -> crate::Result<Vec<f32>> {
        let d = self.d_model;
        ensure!(
            rows.len() % d == 0,
            "rows length {} is not a multiple of d_model {d}",
            rows.len()
        );
        let lc = &self.banks[0][layer];
        let mut buf = vec![0u8; lc.row_bytes];
        let mut codes = vec![0u8; d];
        let mut out = vec![0.0f32; rows.len()];
        for (row, orow) in rows.chunks(d).zip(out.chunks_mut(d)) {
            lc.encode_row(row, &mut buf, &mut codes)?;
            lc.decode_row(&buf, orow, &mut codes);
        }
        Ok(out)
    }

    /// Exact page bytes that growing a sequence from `existing` to
    /// `existing + new` resident positions allocates — the same
    /// arithmetic the allocator performs, so a reservation made with
    /// this number cannot fail mid-forward.
    pub fn bytes_for_rows(&self, existing: usize, new: usize) -> usize {
        self.bank_bytes_for_rows(0, existing, new)
    }

    /// [`KvPool::bytes_for_rows`] under the draft codec bank (0 when
    /// the pool has none).
    pub fn draft_bytes_for_rows(&self, existing: usize, new: usize) -> usize {
        if self.has_draft_bank() {
            self.bank_bytes_for_rows(1, existing, new)
        } else {
            0
        }
    }

    fn bank_bytes_for_rows(
        &self,
        bank: usize,
        existing: usize,
        new: usize,
    ) -> usize {
        let pages =
            |rows: usize| (rows + self.page_rows - 1) / self.page_rows;
        let dp = pages(existing + new) - pages(existing);
        self.banks[bank]
            .iter()
            .map(|lc| 2 * dp * self.page_rows * lc.row_bytes)
            .sum()
    }

    /// Page bytes a fresh sequence of `positions` rows allocates.
    pub fn bytes_for_positions(&self, positions: usize) -> usize {
        self.bytes_for_rows(0, positions)
    }

    /// Allocate one `layer` page against the budget.
    fn alloc(&self, bank: usize, layer: usize) -> crate::Result<u32> {
        let pb = self.bank_page_bytes(bank, layer);
        self.inner.lock().unwrap().alloc_page(pb, self.budget)
    }

    /// Drop one reference to a page (see [`Inner::free_page`]).
    fn free(&self, id: u32) {
        self.inner.lock().unwrap().free_page(id);
    }

    /// Hash-cons the just-filled page at `stream.pages[pidx]`: on a
    /// confirmed content match the stream is repointed at the canonical
    /// page and its private copy physically freed; otherwise this page
    /// becomes the canonical copy for its digest. Runs under the append
    /// lock, once per page fill.
    fn intern_full_page(
        &self,
        g: &mut Inner,
        stream: &mut Stream,
        pidx: usize,
        bank: usize,
        layer: usize,
    ) {
        let own_id = stream.pages[pidx];
        let own = g.slots[own_id as usize].as_ref().expect("page is live");
        debug_assert_eq!(own.rows, self.page_rows);
        let key: DedupKey = {
            let (h1, h2) = page_digest(&own.data);
            (self.sharing_spaces[bank][layer], h1, h2)
        };
        match g.dedup.get(&key).copied() {
            Some(canon_id) => {
                let canon = g.slots[canon_id as usize]
                    .as_ref()
                    .expect("canonical page is live");
                let own = g.slots[own_id as usize].as_ref().unwrap();
                if canon.data != own.data {
                    // digest collision: both pages stay private
                    return;
                }
                let pb = canon.data.len();
                let canon =
                    g.slots[canon_id as usize].as_mut().unwrap();
                canon.refs += 1;
                g.shared_saved += pb;
                g.dedup_hits += 1;
                stream.pages[pidx] = canon_id;
                g.free_page(own_id);
            }
            None => {
                g.dedup.insert(key, own_id);
                g.slots[own_id as usize].as_mut().unwrap().interned =
                    Some(key);
            }
        }
    }

    /// Append `rows` (`n · d_model` values) to one layer stream. Every
    /// page the append needs is allocated **up front**, then one lock
    /// acquisition covers the whole row-encode loop (this runs once per
    /// layer-stream per decode step — the hot path). A budget failure
    /// is atomic for the stream: pages this call allocated are freed
    /// again and no rows are written (callers additionally reserve via
    /// [`KvPool::bytes_for_rows`], so the path is cold).
    fn stream_append(
        &self,
        bank: usize,
        layer: usize,
        stream: &mut Stream,
        rows: &[f32],
        codes: &mut [u8],
    ) -> crate::Result<()> {
        let d = self.d_model;
        debug_assert_eq!(rows.len() % d, 0);
        let total = stream.rows + rows.len() / d;
        let pages_before = stream.pages.len();
        while stream.pages.len() * self.page_rows < total {
            match self.alloc(bank, layer) {
                Ok(id) => stream.pages.push(id),
                Err(e) => {
                    for id in stream.pages.drain(pages_before..) {
                        self.free(id);
                    }
                    return Err(e);
                }
            }
        }
        let lc = &self.banks[bank][layer];
        let rb = lc.row_bytes;
        let mut g = self.inner.lock().unwrap();
        for row in rows.chunks_exact(d) {
            let pidx = stream.rows / self.page_rows;
            let page_id = stream.pages[pidx];
            let slot = stream.rows % self.page_rows;
            let page = g.slots[page_id as usize]
                .as_mut()
                .expect("stream page is live");
            debug_assert_eq!(page.refs, 1, "shared pages are read-only");
            debug_assert_eq!(page.rows, slot);
            lc.encode_row(row, &mut page.data[slot * rb..(slot + 1) * rb], codes)?;
            page.rows = slot + 1;
            stream.rows += 1;
            if self.sharing && slot + 1 == self.page_rows {
                self.intern_full_page(&mut g, stream, pidx, bank, layer);
            }
        }
        Ok(())
    }

    /// Truncate one stream to `rows` rows: whole pages beyond the cut
    /// are freed (refcount-aware), and a partial cut inside the new
    /// tail page privatizes it — a shared tail is replaced by a fresh
    /// private copy of the kept rows (the canonical page is untouched),
    /// a privately-interned tail leaves the dedup table, since a page
    /// whose tail rows will be rewritten must never be shareable. This
    /// is what rolls rejected speculative-draft rows back off a
    /// sequence. On a budget failure (privatizing copy of a shared tail
    /// page) the stream still *reads* correctly but must be reset
    /// before appending again; callers treat it as fatal for the
    /// sequence.
    fn stream_truncate(
        &self,
        bank: usize,
        layer: usize,
        stream: &mut Stream,
        rows: usize,
    ) -> crate::Result<()> {
        if rows >= stream.rows {
            return Ok(());
        }
        let pr = self.page_rows;
        let keep_pages = (rows + pr - 1) / pr;
        let mut g = self.inner.lock().unwrap();
        for id in stream.pages.drain(keep_pages..) {
            g.free_page(id);
        }
        stream.rows = rows;
        let cut = rows % pr;
        if cut == 0 {
            // the cut lands on a page boundary: the new tail page (if
            // any) is still full, so it may legitimately stay interned
            // and shared
            return Ok(());
        }
        let id = stream.pages[keep_pages - 1];
        let shared =
            g.slots[id as usize].as_ref().expect("page is live").refs > 1;
        if shared {
            let rb = self.banks[bank][layer].row_bytes;
            let data =
                g.slots[id as usize].as_ref().unwrap().data[..cut * rb]
                    .to_vec();
            let nid = g
                .alloc_page(self.bank_page_bytes(bank, layer), self.budget)?;
            let np = g.slots[nid as usize].as_mut().unwrap();
            np.data[..cut * rb].copy_from_slice(&data);
            np.rows = cut;
            stream.pages[keep_pages - 1] = nid;
            g.free_page(id);
        } else {
            let key = g.slots[id as usize].as_mut().unwrap().interned.take();
            if let Some(key) = key {
                g.dedup.remove(&key);
            }
            g.slots[id as usize].as_mut().unwrap().rows = cut;
        }
        Ok(())
    }

    /// Decode a whole layer's K and V streams into `k_out`/`v_out`
    /// (cleared first) under a single lock acquisition — the spine's
    /// per-layer attention read.
    fn stream_gather_pair(
        &self,
        bank: usize,
        layer: usize,
        ks: &Stream,
        vs: &Stream,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
        codes: &mut [u8],
    ) {
        let d = self.d_model;
        let lc = &self.banks[bank][layer];
        let g = self.inner.lock().unwrap();
        for (stream, out) in [(ks, k_out), (vs, v_out)] {
            out.clear();
            out.resize(stream.rows * d, 0.0);
            for (pi, &page_id) in stream.pages.iter().enumerate() {
                let page = g.slots[page_id as usize]
                    .as_ref()
                    .expect("stream page is live");
                let base = pi * self.page_rows;
                // saturating: an aborted append may leave an allocated
                // page holding no rows for this stream
                let n = page.rows.min(stream.rows.saturating_sub(base));
                for r in 0..n {
                    lc.decode_row(
                        &page.data[r * lc.row_bytes..(r + 1) * lc.row_bytes],
                        &mut out[(base + r) * d..(base + r + 1) * d],
                        codes,
                    );
                }
            }
        }
    }

    /// Release every page of a stream.
    fn stream_free(&self, stream: &mut Stream) {
        for id in stream.pages.drain(..) {
            self.free(id);
        }
        stream.rows = 0;
    }

    /// Register `seq`'s resident **full** pages as a pinned prefix:
    /// each gains one reference held by the returned registration
    /// handle, so a known system prompt stays resident (and, with
    /// sharing on, stays in the intern table — the next identical
    /// prefill dedups against it instead of re-allocating) across idle
    /// periods where every live sequence retires. Partial tail pages
    /// are skipped — they are still append-mutable and must stay
    /// private, so a pin covers the page-aligned prefix. Requires a
    /// prefix-sharing pool (a pin without the intern table would hold
    /// bytes no future sequence could attach to).
    ///
    /// Pinned references use the ordinary refcount machinery:
    /// [`KvPoolStats::shared_bytes`] counts them, and
    /// [`KvPool::unpin_prefix`] releases them through the same
    /// refcount-aware free as any retiring sequence, so
    /// allocs − frees and `used_bytes` drain to exactly zero once every
    /// sequence *and* every pin is gone.
    pub fn pin_prefix(&self, seq: &SeqKv) -> crate::Result<u64> {
        ensure!(
            self.sharing,
            "pin_prefix needs a prefix-sharing pool (KvPool::build_with)"
        );
        let kv = seq.as_paged().ok_or_else(|| {
            anyhow::anyhow!("pin_prefix needs a pool-backed sequence")
        })?;
        ensure!(
            std::ptr::eq(kv.pool().as_ref(), self),
            "sequence belongs to a different pool"
        );
        let mut g = self.inner.lock().unwrap();
        let mut held = Vec::new();
        for stream in kv.k.iter().chain(kv.v.iter()) {
            for &id in &stream.pages {
                let len = {
                    let page = g.slots[id as usize]
                        .as_mut()
                        .expect("page is live");
                    if page.rows < self.page_rows {
                        continue;
                    }
                    page.refs += 1;
                    page.data.len()
                };
                g.shared_saved += len;
                held.push(id);
            }
        }
        let pin = g.next_pin;
        g.next_pin += 1;
        g.pins.insert(pin, held);
        Ok(pin)
    }

    /// Release a [`KvPool::pin_prefix`] registration, dropping one
    /// reference per pinned page. Returns false for an unknown handle.
    pub fn unpin_prefix(&self, pin: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.pins.remove(&pin) {
            Some(ids) => {
                for id in ids {
                    g.free_page(id);
                }
                true
            }
            None => false,
        }
    }

    /// Live prefix registrations ([`KvPool::pin_prefix`]).
    pub fn pinned_prefixes(&self) -> usize {
        self.inner.lock().unwrap().pins.len()
    }
}

/// One layer-stream's page handles.
#[derive(Default)]
struct Stream {
    pages: Vec<u32>,
    rows: usize,
}

/// Clone one stream for [`PagedKv::fork`]: full pages are shared by a
/// refcount bump, partial (tail) pages deep-copied into fresh private
/// pages. Every touched page id is recorded in `bumped`/`fresh` so a
/// mid-clone budget failure can be rolled back exactly.
fn clone_stream(
    pool: &KvPool,
    g: &mut Inner,
    bank: usize,
    layer: usize,
    src: &Stream,
    bumped: &mut Vec<u32>,
    fresh: &mut Vec<u32>,
) -> crate::Result<Stream> {
    let mut pages = Vec::with_capacity(src.pages.len());
    for &id in &src.pages {
        let (full, len) = {
            let p = g.slots[id as usize].as_ref().expect("page is live");
            (p.rows == pool.page_rows, p.data.len())
        };
        if full {
            g.slots[id as usize].as_mut().unwrap().refs += 1;
            g.shared_saved += len;
            bumped.push(id);
            pages.push(id);
        } else {
            let nid =
                g.alloc_page(pool.bank_page_bytes(bank, layer), pool.budget)?;
            let (data, rows) = {
                let p = g.slots[id as usize].as_ref().unwrap();
                (p.data.clone(), p.rows)
            };
            let np = g.slots[nid as usize].as_mut().unwrap();
            np.data.copy_from_slice(&data);
            np.rows = rows;
            fresh.push(nid);
            pages.push(nid);
        }
    }
    Ok(Stream { pages, rows: src.rows })
}

/// A pool-backed sequence cache: per layer, one K and one V page
/// stream. Created via [`KvPool::seq`] (which wraps it in the public
/// [`SeqKv`]); pages return to the pool on [`PagedKv::reset`] or drop.
pub(crate) struct PagedKv {
    pool: Arc<KvPool>,
    /// which codec bank this sequence's pages encode under (0 =
    /// target, 1 = draft — see [`KvPool::build_spec`])
    bank: usize,
    k: Vec<Stream>,
    v: Vec<Stream>,
    /// `d_model`-byte element-code scratch shared by every append and
    /// gather (the per-row codec would otherwise allocate per call on
    /// the decode hot path).
    codes: Vec<u8>,
}

impl PagedKv {
    fn new(pool: Arc<KvPool>) -> PagedKv {
        Self::new_bank(pool, 0)
    }

    fn new_bank(pool: Arc<KvPool>, bank: usize) -> PagedKv {
        let mk = || (0..pool.n_layers).map(|_| Stream::default()).collect();
        let codes = vec![0u8; pool.d_model];
        PagedKv { k: mk(), v: mk(), codes, pool, bank }
    }

    pub(crate) fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    pub(crate) fn layers(&self) -> usize {
        self.k.len()
    }

    /// `(k rows, v rows)` resident in `layer`.
    pub(crate) fn rows(&self, layer: usize) -> (usize, usize) {
        (self.k[layer].rows, self.v[layer].rows)
    }

    pub(crate) fn append(
        &mut self,
        layer: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> crate::Result<()> {
        self.pool.stream_append(
            self.bank,
            layer,
            &mut self.k[layer],
            k_rows,
            &mut self.codes,
        )?;
        self.pool.stream_append(
            self.bank,
            layer,
            &mut self.v[layer],
            v_rows,
            &mut self.codes,
        )
    }

    /// Truncate every layer's K and V streams to `rows` resident rows
    /// (no-op layers already at or below it) — the speculative-decode
    /// rollback that discards rejected draft rows. See
    /// [`KvPool::stream_truncate`] for the sharing semantics.
    pub(crate) fn truncate(&mut self, rows: usize) -> crate::Result<()> {
        for layer in 0..self.k.len() {
            self.pool.stream_truncate(
                self.bank,
                layer,
                &mut self.k[layer],
                rows,
            )?;
            self.pool.stream_truncate(
                self.bank,
                layer,
                &mut self.v[layer],
                rows,
            )?;
        }
        Ok(())
    }

    /// Decode one layer's K and V rows into the output buffers; the
    /// caller threads the element-code scratch (resized here) so the
    /// per-token attention read allocates nothing.
    pub(crate) fn gather_with(
        &self,
        layer: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
        codes: &mut Vec<u8>,
    ) {
        codes.resize(self.pool.d_model, 0);
        self.pool.stream_gather_pair(
            self.bank,
            layer,
            &self.k[layer],
            &self.v[layer],
            k_out,
            v_out,
            codes,
        );
    }

    /// Allocating convenience wrapper over [`PagedKv::gather_with`]
    /// (cold paths: trace capture, tests).
    pub(crate) fn gather(
        &self,
        layer: usize,
        k_out: &mut Vec<f32>,
        v_out: &mut Vec<f32>,
    ) {
        let mut codes = Vec::new();
        self.gather_with(layer, k_out, v_out, &mut codes);
    }

    /// Allocated page bytes across all streams (what this sequence
    /// holds of the pool budget — includes partially filled pages).
    pub(crate) fn resident_bytes(&self) -> usize {
        self.k
            .iter()
            .zip(&self.v)
            .enumerate()
            .map(|(l, (ks, vs))| {
                (ks.pages.len() + vs.pages.len())
                    * self.pool.bank_page_bytes(self.bank, l)
            })
            .sum()
    }

    /// Free every page and return to the empty state.
    pub(crate) fn reset(&mut self) {
        for s in self.k.iter_mut().chain(self.v.iter_mut()) {
            self.pool.stream_free(s);
        }
    }

    /// Clone this sequence's resident prefix into a new cache. Full
    /// pages are shared by refcount bump — copy-on-write degenerates
    /// structurally, because shared pages are immutable and divergence
    /// writes land in private tail pages — while partial tail pages
    /// are deep-copied. The whole clone is priced against the budget
    /// under one lock: a mid-clone budget failure rolls back every
    /// refcount bump and fresh page, changing nothing.
    pub(crate) fn fork(&self) -> crate::Result<PagedKv> {
        let mut g = self.pool.inner.lock().unwrap();
        let mut bumped: Vec<u32> = Vec::new();
        let mut fresh: Vec<u32> = Vec::new();
        let mut k = Vec::with_capacity(self.k.len());
        let mut v = Vec::with_capacity(self.v.len());
        let mut err = None;
        'clone: for (dst, streams) in [(&mut k, &self.k), (&mut v, &self.v)] {
            for (layer, src) in streams.iter().enumerate() {
                match clone_stream(
                    &self.pool,
                    &mut g,
                    self.bank,
                    layer,
                    src,
                    &mut bumped,
                    &mut fresh,
                ) {
                    Ok(s) => dst.push(s),
                    Err(e) => {
                        err = Some(e);
                        break 'clone;
                    }
                }
            }
        }
        if let Some(e) = err {
            for id in bumped.into_iter().chain(fresh) {
                g.free_page(id);
            }
            return Err(e);
        }
        drop(g);
        Ok(PagedKv {
            pool: self.pool.clone(),
            bank: self.bank,
            k,
            v,
            codes: vec![0u8; self.pool.d_model],
        })
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.reset();
    }
}

impl std::fmt::Debug for PagedKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedKv")
            .field("layers", &self.k.len())
            .field("rows", &self.k.first().map_or(0, |s| s.rows))
            .field("resident_bytes", &self.resident_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::quant::fake_quant;
    use crate::runtime::QConfig;

    fn dims(d_model: usize, n_layers: usize) -> ModelDims {
        ModelDims {
            vocab: 16,
            d_model,
            n_heads: 1,
            n_layers,
            d_ff: 2 * d_model,
            seq_len: 64,
        }
    }

    #[test]
    fn exact_pages_roundtrip_bit_identically() {
        let pool = KvPool::exact(&dims(16, 2), 4, 1 << 20).unwrap();
        let mut kv = PagedKv::new(pool.clone());
        // awkward values: -0.0, subnormals, extremes
        let mut rng = Pcg64::new(3);
        let mut rows = rng.normal_vec_f32(6 * 16, 1e-3);
        rows[0] = -0.0;
        rows[1] = f32::MIN_POSITIVE / 2.0;
        rows[2] = 3.4e38;
        rows[3] = -1.1754944e-38;
        kv.append(0, &rows, &rows).unwrap();
        let (mut k, mut v) = (Vec::new(), Vec::new());
        kv.gather(0, &mut k, &mut v);
        assert_eq!(k.len(), rows.len());
        for (a, b) in rows.iter().zip(k.iter().chain(&v)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn mx_pages_decode_as_fake_quant_of_the_written_row() {
        // the stated Mx error model: reading back a row yields exactly
        // fake_quant(scheme, row) — across elements, scales (incl. the
        // f32-scale bf16 path), block sizes, and σ regimes
        crate::util::check::property("kv mx roundtrip", 60, |g| {
            let d = *g.pick(&[16usize, 32, 64]);
            let bs = *g.pick(&[4usize, 8, 16]);
            if d % bs != 0 {
                return;
            }
            let elem = *g.pick(&["fp4_e2m1", "fp8_e4m3", "fp6_e2m3"]);
            let scale = *g.pick(&["ue4m3", "ue5m3", "e8m0", "bf16"]);
            let sigma = g.log_uniform(1e-5, 1.0);
            let cfg = QConfig::named(elem, scale, false).unwrap();
            let pool = KvPool::build(
                &dims(d, 1),
                &PerLayerQConfig::uniform(cfg),
                bs,
                4,
                1 << 24,
            )
            .unwrap();
            let mut kv = PagedKv::new(pool);
            let n_rows = g.usize_in(1, 9);
            let rows = g.normal_vec_f32(n_rows * d, sigma);
            kv.append(0, &rows, &rows).unwrap();
            let (mut k, mut v) = (Vec::new(), Vec::new());
            kv.gather(0, &mut k, &mut v);
            let scheme = cfg.scheme(bs);
            // per-row quantization: blocks never span rows
            let mut want = Vec::new();
            for row in rows.chunks(d) {
                want.extend(fake_quant(&scheme, row));
            }
            for (i, (a, b)) in k.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{elem}/{scale}/bs{bs} elem {i}: {a} vs {b}"
                );
            }
            assert_eq!(v, k);
        });
    }

    #[test]
    fn byte_accounting_is_exact_after_every_alloc_and_free() {
        let d = dims(8, 2);
        let pool = KvPool::exact(&d, 4, 10_000).unwrap();
        let row_bytes = 8 * 4;
        let page_bytes = 4 * row_bytes;
        assert_eq!(pool.page_bytes(0), page_bytes);
        assert_eq!(pool.position_bytes(), 2 * 2 * row_bytes);
        let mut kv = PagedKv::new(pool.clone());
        let mut expect = 0usize;
        let one = vec![0.5f32; 8];
        for step in 1..=9usize {
            for layer in 0..2 {
                kv.append(layer, &one, &one).unwrap();
            }
            // each layer has 2 streams; pages grow at rows 1, 5, 9...
            let pages_per_stream = (step + 3) / 4;
            expect = 2 * 2 * pages_per_stream * page_bytes;
            assert_eq!(pool.used_bytes(), expect, "after step {step}");
            assert_eq!(kv.resident_bytes(), expect);
            assert_eq!(
                pool.bytes_for_rows(0, step),
                expect,
                "reservation math at {step} rows"
            );
        }
        // marginal growth math matches the allocator exactly
        assert_eq!(pool.bytes_for_rows(9, 3), 0); // rows 10..12 fit page 3
        assert_eq!(pool.bytes_for_rows(9, 4), 4 * page_bytes);
        kv.reset();
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(kv.resident_bytes(), 0);
        let s = pool.stats();
        assert_eq!(s.allocs, s.frees);
        assert_eq!(s.failed_allocs, 0);
        assert_eq!(s.peak_bytes, expect);
        // drop-frees also return pages
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &one, &one).unwrap();
        assert!(pool.used_bytes() > 0);
        drop(kv2);
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn budget_refusal_leaves_accounting_unchanged() {
        let d = dims(8, 1);
        // room for exactly 2 pages (one K + one V page of 4 rows)
        let page = 4 * 8 * 4;
        let pool = KvPool::exact(&d, 4, 2 * page).unwrap();
        let mut kv = PagedKv::new(pool.clone());
        let rows = vec![1.0f32; 4 * 8];
        kv.append(0, &rows, &rows).unwrap();
        assert_eq!(pool.used_bytes(), 2 * page);
        assert_eq!(pool.free_bytes(), 0);
        let one = vec![1.0f32; 8];
        let err = kv.append(0, &one, &one).unwrap_err();
        assert!(format!("{err}").contains("budget exhausted"));
        assert_eq!(pool.used_bytes(), 2 * page);
        assert_eq!(pool.stats().failed_allocs, 1);
        // the failed append wrote nothing: row counts are unchanged
        assert_eq!(kv.rows(0), (4, 4));
        kv.reset();
        assert_eq!(pool.used_bytes(), 0);
        // after the free the same append succeeds
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &one, &one).unwrap();
        assert_eq!(kv2.rows(0), (1, 1));
    }

    #[test]
    fn build_rejects_unsupported_kv_configs() {
        let d = dims(16, 1);
        // per-tensor KV scaling is refused
        let per_tensor = PerLayerQConfig::uniform(
            QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
        );
        assert!(KvPool::build(&d, &per_tensor, 8, 4, 1 << 20).is_err());
        // block size must divide d_model
        let fp8 = PerLayerQConfig::uniform(
            QConfig::named("fp8_e4m3", "ue5m3", false).unwrap(),
        );
        assert!(KvPool::build(&d, &fp8, 12, 4, 1 << 20).is_err());
        assert!(KvPool::build(&d, &fp8, 8, 0, 1 << 20).is_err());
        let pool = KvPool::build(&d, &fp8, 8, 4, 1 << 20).unwrap();
        assert_eq!(pool.codec_id(0), "fp8_e4m3/ue5m3/bs8");
        assert!(!pool.is_exact());
        // fp8 codes (8b) + 1-byte scales every 8 elems
        assert_eq!(pool.row_bytes(0), 16 + 2);
    }

    #[test]
    fn mixed_per_layer_codecs_price_rows_independently() {
        let d = dims(32, 3);
        let cfg = PerLayerQConfig::uniform(QConfig::baseline())
            .with_override(1, QConfig::fp4("ue5m3").unwrap())
            .with_override(
                2,
                QConfig::named("fp8_e4m3", "ue4m3", false).unwrap(),
            );
        let pool = KvPool::build(&d, &cfg, 16, 4, 1 << 24).unwrap();
        assert_eq!(pool.row_bytes(0), 32 * 4); // exact f32
        assert_eq!(pool.row_bytes(1), 16 + 2); // fp4: d/2 codes + 2 scales
        assert_eq!(pool.row_bytes(2), 32 + 2); // fp8: d codes + 2 scales
        assert_eq!(
            pool.position_bytes(),
            2 * (128 + 18 + 34),
            "K+V row bytes across layers"
        );
        assert_eq!(pool.codec_id(0), "exact");
    }

    /// 8 distinct rows of d_model = 8 (two full 4-row pages' worth).
    fn eight_rows() -> Vec<f32> {
        (0..64).map(|i| (i as f32 + 1.0) / 7.0).collect()
    }

    #[test]
    fn shared_pages_hash_cons_to_one_physical_copy() {
        let d = dims(8, 1);
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        assert!(pool.prefix_sharing());
        let pb = pool.page_bytes(0);
        let rows = eight_rows();
        // one sequence: its V stream dedups against its K stream
        let mut kv1 = PagedKv::new(pool.clone());
        kv1.append(0, &rows, &rows).unwrap();
        let s = pool.stats();
        assert_eq!(s.allocs, 4, "2 K + 2 V pages allocated");
        assert_eq!(s.frees, 2, "both V pages deduplicated away");
        assert_eq!(s.live_pages, 2);
        assert_eq!(s.used_bytes, 2 * pb, "one physical prefix copy");
        assert_eq!(s.dedup_hits, 2);
        assert_eq!(s.shared_bytes, 2 * pb);
        // a second sequence over the same prefix adds zero bytes
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &rows, &rows).unwrap();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 2 * pb, "still one physical copy");
        assert_eq!(s.dedup_hits, 6);
        assert_eq!(s.shared_bytes, 6 * pb, "3 extra holders × 2 pages");
        assert_eq!(s.live_pages, 2);
        // sharing is invisible to readers: bit-exact gathers
        let (mut k, mut v) = (Vec::new(), Vec::new());
        kv2.gather(0, &mut k, &mut v);
        for (a, b) in rows.iter().zip(k.iter().chain(&v)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // refcounted free: kv1's release leaves kv2's pages live…
        kv1.reset();
        assert_eq!(pool.used_bytes(), 2 * pb);
        kv2.gather(0, &mut k, &mut v);
        for (a, b) in rows.iter().zip(&k) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // …and the last reference drains the pool to zero
        kv2.reset();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 0);
        assert_eq!(s.allocs, s.frees);
        assert_eq!(s.shared_bytes, 0);
    }

    #[test]
    fn sharing_stays_off_in_the_default_build() {
        let d = dims(8, 1);
        let pool = KvPool::exact(&d, 4, 1 << 20).unwrap();
        assert!(!pool.prefix_sharing());
        let rows = eight_rows();
        let mut kv1 = PagedKv::new(pool.clone());
        let mut kv2 = PagedKv::new(pool.clone());
        kv1.append(0, &rows, &rows).unwrap();
        kv2.append(0, &rows, &rows).unwrap();
        let s = pool.stats();
        assert_eq!(s.dedup_hits, 0);
        assert_eq!(s.shared_bytes, 0);
        assert_eq!(s.used_bytes, 8 * pool.page_bytes(0), "every copy private");
    }

    #[test]
    fn fork_shares_full_pages_and_copies_the_tail() {
        let d = dims(8, 1);
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        let pb = pool.page_bytes(0);
        let rows: Vec<f32> = eight_rows()[..48].to_vec(); // 6 rows
        let mut base = PagedKv::new(pool.clone());
        base.append(0, &rows, &rows).unwrap();
        // K: full page (canonical) + 2-row tail; V: shared full + tail
        let used0 = pool.used_bytes();
        assert_eq!(used0, 3 * pb);
        let shared0 = pool.stats().shared_bytes;
        // fork: both full-page holders bump refs, both tails copied
        let mut fork = base.fork().unwrap();
        assert_eq!(pool.used_bytes(), used0 + 2 * pb, "only tails copied");
        assert_eq!(pool.stats().shared_bytes, shared0 + 2 * pb);
        assert_eq!(fork.rows(0), (6, 6));
        // divergence: each side appends different rows; the shared
        // prefix pages are immutable, so neither sees the other's tail
        let a = vec![0.25f32; 16]; // 2 rows
        let b = vec![-0.75f32; 16];
        base.append(0, &a, &a).unwrap();
        fork.append(0, &b, &b).unwrap();
        let (mut kb, mut vb) = (Vec::new(), Vec::new());
        let (mut kf, mut vf) = (Vec::new(), Vec::new());
        base.gather(0, &mut kb, &mut vb);
        fork.gather(0, &mut kf, &mut vf);
        assert_eq!(kb[..48], rows[..], "base prefix intact");
        assert_eq!(kf[..48], rows[..], "fork prefix intact");
        assert_eq!(kb[48..], a[..]);
        assert_eq!(kf[48..], b[..]);
        // both sides release: the pool drains to zero
        base.reset();
        fork.reset();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 0);
        assert_eq!(s.allocs, s.frees);
    }

    #[test]
    fn fork_budget_failure_rolls_back_exactly() {
        let d = dims(8, 1);
        let pb = 4 * 8 * 4;
        // room for 4 pages: base usage is 3 (shared full + two tails),
        // the fork needs 2 tail copies — the second one must fail
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            4 * pb,
            true,
        )
        .unwrap();
        let rows: Vec<f32> = eight_rows()[..48].to_vec(); // 6 rows
        let mut base = PagedKv::new(pool.clone());
        base.append(0, &rows, &rows).unwrap();
        let before = pool.stats();
        assert_eq!(before.used_bytes, 3 * pb);
        let err = base.fork().unwrap_err();
        assert!(format!("{err}").contains("budget exhausted"));
        let after = pool.stats();
        assert_eq!(after.used_bytes, before.used_bytes);
        assert_eq!(after.live_pages, before.live_pages);
        assert_eq!(after.shared_bytes, before.shared_bytes);
        assert_eq!(after.failed_allocs, before.failed_allocs + 1);
        // the base sequence is untouched and still drains cleanly
        let (mut k, mut v) = (Vec::new(), Vec::new());
        base.gather(0, &mut k, &mut v);
        assert_eq!(k[..], rows[..]);
        base.reset();
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn truncate_frees_tail_pages_and_reappends_cleanly() {
        let d = dims(8, 2);
        let pool = KvPool::exact(&d, 4, 1 << 20).unwrap();
        let pb = pool.page_bytes(0);
        let rows = eight_rows(); // 8 rows = 2 full pages/stream
        let mut kv = PagedKv::new(pool.clone());
        for layer in 0..2 {
            kv.append(layer, &rows, &rows).unwrap();
        }
        assert_eq!(pool.used_bytes(), 2 * 2 * 2 * pb);
        // cut to 5 rows: ceil(5/4) = 2 pages per stream — nothing freed
        // yet, the second page just became a 1-row tail
        kv.truncate(5).unwrap();
        assert_eq!(kv.rows(0), (5, 5));
        assert_eq!(pool.used_bytes(), 2 * 2 * 2 * pb);
        // cut to the page boundary: each stream drops its tail page
        kv.truncate(4).unwrap();
        assert_eq!(pool.used_bytes(), 2 * 2 * pb, "1 page per stream");
        // the kept rows read back bit-exactly, and a re-append after
        // the cut overwrites the stale region
        let (mut k, mut v) = (Vec::new(), Vec::new());
        kv.gather(0, &mut k, &mut v);
        assert_eq!(k[..], rows[..32]);
        let fresh = vec![9.0f32; 3 * 8];
        for layer in 0..2 {
            kv.append(layer, &fresh, &fresh).unwrap();
        }
        kv.gather(0, &mut k, &mut v);
        assert_eq!(k[..32], rows[..32]);
        assert_eq!(k[32..], fresh[..]);
        kv.truncate(0).unwrap();
        assert_eq!(pool.used_bytes(), 0);
        let s = pool.stats();
        assert_eq!(s.allocs, s.frees);
    }

    #[test]
    fn truncate_into_a_shared_page_privatizes_the_kept_rows() {
        let d = dims(8, 1);
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        let rows = eight_rows();
        let mut a = PagedKv::new(pool.clone());
        let mut b = PagedKv::new(pool.clone());
        a.append(0, &rows, &rows).unwrap();
        b.append(0, &rows, &rows).unwrap();
        let before = pool.stats();
        assert_eq!(before.used_bytes, 2 * pool.page_bytes(0));
        // b cuts into the shared page: its reference moves to a private
        // copy, a's pages (and the canonical dedup entries) survive
        b.truncate(2).unwrap();
        let (mut k, mut v) = (Vec::new(), Vec::new());
        b.gather(0, &mut k, &mut v);
        assert_eq!(k[..], rows[..16], "kept rows intact after privatize");
        a.gather(0, &mut k, &mut v);
        assert_eq!(k[..], rows[..], "canonical holder untouched");
        // b appends different rows after the cut — no COW fault, the
        // private tail just grows
        let tail = vec![4.5f32; 2 * 8];
        b.append(0, &tail, &tail).unwrap();
        b.gather(0, &mut k, &mut v);
        assert_eq!(k[..16], rows[..16]);
        assert_eq!(k[16..], tail[..]);
        a.reset();
        b.reset();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 0);
        assert_eq!(s.allocs, s.frees);
    }

    #[test]
    fn truncate_uninterns_a_private_full_page_before_rewriting() {
        let d = dims(8, 1);
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        let rows = eight_rows();
        let mut a = PagedKv::new(pool.clone());
        // K page interns; V dedups against it. Free V first so the K
        // page is private-but-interned, then truncate into it.
        a.append(0, &rows[..32], &rows[..32]).unwrap();
        pool.stream_free(&mut a.v[0]);
        a.truncate(3).unwrap();
        // the cut page left the dedup table: a new sequence writing the
        // original content does NOT dedup against stale bytes
        let mut b = PagedKv::new(pool.clone());
        b.append(0, &rows[..32], &rows[..32]).unwrap();
        let (mut k, mut v) = (Vec::new(), Vec::new());
        b.gather(0, &mut k, &mut v);
        assert_eq!(k[..], rows[..32]);
        // and refilling a's page after the cut reads back what was
        // written, not the stale suffix
        let fill = vec![7.0f32; 8];
        a.pool.stream_append(0, 0, &mut a.k[0], &fill, &mut a.codes).unwrap();
        let (mut ka, mut va) = (Vec::new(), Vec::new());
        a.gather(0, &mut ka, &mut va);
        assert_eq!(ka[..24], rows[..24]);
        assert_eq!(ka[24..32], fill[..]);
        assert!(va.is_empty(), "v stream was freed above");
    }

    #[test]
    fn pinned_prefix_survives_idle_drain_and_unpin_drains_to_zero() {
        let d = dims(8, 1);
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        let pb = pool.page_bytes(0);
        let prefix = eight_rows(); // 2 full pages
        let mut kv = PagedKv::new(pool.clone());
        kv.append(0, &prefix, &prefix).unwrap();
        let seq = SeqKv::paged(kv);
        let pin = pool.pin_prefix(&seq).unwrap();
        assert_eq!(pool.pinned_prefixes(), 1);
        // idle drain: the last sequence retires, pinned pages stay
        drop(seq);
        let s = pool.stats();
        assert_eq!(s.used_bytes, 2 * pb, "pin holds the physical prefix");
        assert!(s.allocs > s.frees);
        // a new sequence over the same prompt dedups against the
        // pinned pages instead of re-allocating
        let hits0 = pool.stats().dedup_hits;
        let mut kv2 = PagedKv::new(pool.clone());
        kv2.append(0, &prefix, &prefix).unwrap();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 2 * pb, "re-arrival attached to the pin");
        assert!(s.dedup_hits > hits0);
        kv2.reset();
        assert_eq!(pool.used_bytes(), 2 * pb);
        // unpin: drain-to-zero accounting is exact again
        assert!(pool.unpin_prefix(pin));
        assert!(!pool.unpin_prefix(pin), "double unpin is refused");
        let s = pool.stats();
        assert_eq!(s.used_bytes, 0);
        assert_eq!(s.allocs, s.frees);
        assert_eq!(s.shared_bytes, 0);
        assert_eq!(pool.pinned_prefixes(), 0);
    }

    #[test]
    fn pin_skips_partial_tails_and_requires_sharing() {
        let d = dims(8, 1);
        // sharing off → refused
        let off = KvPool::exact(&d, 4, 1 << 20).unwrap();
        let mut kv = PagedKv::new(off.clone());
        kv.append(0, &eight_rows(), &eight_rows()).unwrap();
        let seq = SeqKv::paged(kv);
        assert!(off.pin_prefix(&seq).is_err());
        drop(seq);
        // a 6-row sequence pins only its full page per stream
        let pool = KvPool::build_with(
            &d,
            &PerLayerQConfig::uniform(QConfig::baseline()),
            1,
            4,
            1 << 20,
            true,
        )
        .unwrap();
        let pb = pool.page_bytes(0);
        let rows: Vec<f32> = eight_rows()[..48].to_vec();
        let mut kv = PagedKv::new(pool.clone());
        kv.append(0, &rows, &rows).unwrap();
        let seq = SeqKv::paged(kv);
        let pin = pool.pin_prefix(&seq).unwrap();
        drop(seq);
        // only the page-aligned prefix survives: 1 shared full page
        // (K dedup'd V), the two private tails were freed on retire
        assert_eq!(pool.used_bytes(), pb);
        assert!(pool.unpin_prefix(pin));
        assert_eq!(pool.used_bytes(), 0);
    }

    #[test]
    fn draft_bank_prices_and_encodes_under_its_own_codec() {
        let d = dims(16, 2);
        let target = PerLayerQConfig::uniform(QConfig::baseline());
        let draft = PerLayerQConfig::uniform(
            QConfig::named("fp4_e2m1", "ue5m3", false).unwrap(),
        );
        let pool =
            KvPool::build_spec(&d, &target, &draft, 8, 4, 1 << 20, false)
                .unwrap();
        assert!(pool.has_draft_bank());
        assert_eq!(pool.codec_id(0), "exact");
        assert_eq!(
            pool.draft_codec_id(0).unwrap(),
            "fp4_e2m1/ue5m3/bs8"
        );
        // draft rows are strictly cheaper than exact target rows and
        // priced by their own arithmetic
        let t1 = pool.bytes_for_rows(0, 1);
        let d1 = pool.draft_bytes_for_rows(0, 1);
        assert!(d1 < t1, "draft {d1} >= target {t1}");
        // one draft page: 4 rows × (8 codes + 2 scales)
        assert_eq!(d1, 2 * 2 * 4 * (8 + 2));
        // both banks draw from the same budget/counters
        let mut tseq = PagedKv::new(pool.clone());
        let mut dseq = PagedKv::new_bank(pool.clone(), 1);
        let one = vec![0.5f32; 16];
        tseq.append(0, &one, &one).unwrap();
        dseq.append(0, &one, &one).unwrap();
        assert_eq!(pool.used_bytes(), t1 / 2 + d1 / 2);
        // draft reads decode as fake_quant under the draft scheme
        let (mut k, mut v) = (Vec::new(), Vec::new());
        dseq.gather(0, &mut k, &mut v);
        let scheme =
            QConfig::named("fp4_e2m1", "ue5m3", false).unwrap().scheme(8);
        let want = fake_quant(&scheme, &one);
        for (a, b) in k.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        tseq.reset();
        dseq.reset();
        let s = pool.stats();
        assert_eq!(s.used_bytes, 0);
        assert_eq!(s.allocs, s.frees);
        // no draft bank → draft pricing is zero and draft_seq refuses
        let plain = KvPool::exact(&d, 4, 1 << 20).unwrap();
        assert_eq!(plain.draft_bytes_for_rows(0, 8), 0);
        assert!(!plain.has_draft_bank());
        assert!(plain.draft_seq().is_err());
    }
}
