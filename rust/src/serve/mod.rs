//! Native packed-domain inference serving: the repo's model runs
//! end-to-end on prepacked quantized weights — no XLA artifacts, no
//! dequantized weight tensors, no python.
//!
//! The paper's headline claim (FP4 microscaling with UE5M3 scales
//! matches E4M3 without global rescaling) only pays off if inference
//! actually executes natively on the packed representation; this
//! subsystem is that on-ramp. Four pieces:
//!
//! * [`packed_model`] — [`PackedModel`]: the surrogate transformer of
//!   `python/compile/model.py` (embed + pos, per-layer LN → quantized
//!   Q/K/V/O linears → full-precision attention → quantized GELU MLP,
//!   unquantized head) with every linear weight prepacked **once** as a
//!   transposed [`crate::quant::gemm::GemmOperand`]; `forward()`
//!   quantizes activations per batch and dispatches through
//!   [`crate::quant::gemm::PackedGemm`]. Bit-identical to the scalar
//!   fake-quant [`reference_forward`] (pinned by `rust/tests/serve.rs`).
//!   Per-layer [`crate::runtime::qconfig::PerLayerQConfig`] overrides
//!   express mixed-precision assignments (cf. *Scaling Laws For Mixed
//!   Quantization*).
//! * [`batcher`] — [`Batcher`]: an admission queue with deadline/size
//!   triggered micro-batching. Coalesced neighbors never change a
//!   request's logits (batching invariance — quantization, GEMM rows,
//!   LN, attention and softmax are all per-row/per-sequence; per-tensor
//!   "-S" activation scaling is applied per *sequence*, not per batch).
//! * [`engine`] — [`ServeEngine`]: multi-worker serving loop over one
//!   shared model (submit/collect API, p50/p95/p99 latency + throughput
//!   stats). Workers reuse the [`crate::util::par::WorkerGuard`]
//!   pool-worker protocol so nested GEMM threading never oversubscribes.
//! * [`cache`] — [`OperandCache`]: the process-wide prepacked
//!   weight-operand cache keyed by (tensor content, shape, qconfig),
//!   shared across serve sessions *and* by
//!   [`crate::quant::matmul::quantized_matmul`] sweeps; hits return the
//!   exact operand the first encode produced, so cached and fresh paths
//!   are bit-identical by construction.
//!
//! Linears scale *within* one forward via tensor-parallel sharding:
//! [`PackedModel::build_sharded`] splits every packed weight into
//! block-aligned column shards
//! ([`crate::quant::shard::ShardedOperand`], one
//! [`OperandCache`] entry per shard slot) and runs them concurrently
//! on a persistent [`crate::util::par::ShardPool`] whose workers
//! follow the same [`crate::util::par::WorkerGuard`] protocol, so
//! engine workers × shards never oversubscribes. Sharded logits and
//! decode streams are bit-identical to `shards = 1` (DESIGN.md §12,
//! pinned differentially by `rust/tests/shard.rs`).
//!
//! `microscale serve-bench` ([`bench`]) drives synthetic traffic across
//! {FP4/UE4M3, FP4/UE5M3, FP8, mixed-per-layer} × batch sizes × shard
//! counts and emits machine-readable `BENCH_serve.json` (field map in
//! EXPERIMENTS.md §Perf). Architecture notes live in DESIGN.md §9.
//!
//! On top of the one-shot forward path sits token-by-token
//! **generation**:
//!
//! * [`decode`] — [`DecodeEngine`]: KV-cached autoregressive stepping
//!   over the shared incremental forward spine, bit-identical at every
//!   generated token to re-running the full prefix through
//!   [`reference_forward`] (the decode exactness contract, DESIGN.md
//!   §10; pinned by `rust/tests/decode.rs`).
//! * [`scheduler`] — [`Scheduler`]: continuous batching — sequences
//!   admitted and retired mid-flight, prefill and decode fused into one
//!   ragged forward per iteration, deterministic seeded sampling.
//! * [`kvpool`] — [`KvPool`]: the paged, byte-budgeted KV-cache arena
//!   (DESIGN.md §11). Fixed-size pages with a per-layer page codec:
//!   `Exact` pages keep the decode contract bit for bit, `Mx` pages
//!   store block-quantized K/V (FP8/FP4 codes + UE4M3/UE5M3/BF16-class
//!   scales) under a stated error model — the KV cache as an in-vivo
//!   testbed for the paper's block-size anomaly (`microscale
//!   kv-sweep`). With a pool attached ([`DecodeEngine::with_pool`])
//!   the scheduler admits and evicts on real page-budget accounting:
//!   requests queue at capacity, and evicted sequences resume with
//!   their token streams unchanged.
//!
//! `microscale decode-bench` ([`decode_bench`]) measures generation
//! throughput/latency and emits `BENCH_decode.json`; `microscale
//! kv-bench` ([`kv_bench`]) measures the memory/throughput trade of
//! Exact vs FP8 vs FP4 KV pages at a fixed page budget and emits
//! `BENCH_kv.json`.
//!
//! The **serving edge** (DESIGN.md §14) puts real traffic in front of
//! the scheduler:
//!
//! * [`kvpool`] grows **prefix sharing** — with
//!   [`KvPool::build_with`]`(.., prefix_sharing: true)` full pages are
//!   hash-consed by content, so N requests over one system prompt hold
//!   exactly one refcounted copy of its KV pages; divergence is
//!   structurally copy-on-write (tails are always private) and token
//!   streams stay bit-identical to the unshared pool.
//! * [`net`] + [`http`] — a dependency-free HTTP/1.1 front-end
//!   ([`HttpServer`]): `POST /v1/completions` with chunked SSE token
//!   streaming, priority classes ([`Priority`]) honored in admission
//!   and eviction, client disconnects cancelling mid-flight requests
//!   and draining their pool pages.
//! * [`traffic`] — `microscale traffic-bench`: a seeded trace (bursty
//!   Poisson arrivals, length mixtures, shared-prefix ratio,
//!   disconnect fraction) driven over loopback sockets, emitting
//!   `BENCH_traffic.json` with per-class p50/p95/p99 TTFT/ITL/queue
//!   wait, goodput, shared-vs-unshared peak KV bytes, and a
//!   host-independent pass verdict.
//!
//! **Speculative decoding** (DESIGN.md §15) turns the repo's multiple
//! bit-exact execution paths for one weight source into throughput:
//!
//! * [`spec`] — [`SpecDecodeEngine`]: a cheap draft config (default
//!   FP4/UE5M3) proposes k tokens through the m == 1 decode fast path;
//!   the target config verifies all k + 1 positions in **one** ragged
//!   spine call; replay acceptance (the request's own greedy or
//!   seeded-Pcg64 sampler re-picks every emitted token from target
//!   logits) keeps the emitted stream bit-identical to
//!   non-speculative decode for every k, draft config, and
//!   thread/shard count. [`Scheduler::new_speculative`] runs the same
//!   protocol under continuous batching with draft KV in the shared
//!   [`KvPool`] under its own codec bank (draft pages evict first).
//! * [`spec_bench`] — `microscale spec-bench`: sweeps draft acceptance
//!   over the paper's {FP4, FP8} × {UE4M3, UE5M3} × block-size grid
//!   (the anomaly as an acceptance-rate curve) and emits
//!   `BENCH_spec.json`, stream-invariance gated before any timing.

pub mod batcher;
pub mod bench;
pub mod decode;
pub mod decode_bench;
pub mod engine;
pub mod http;
pub mod kv_bench;
pub mod kvpool;
pub mod net;
pub mod packed_model;
pub mod scheduler;
pub mod spec;
pub mod spec_bench;
pub mod traffic;

/// The weight-operand cache lives in the quant layer
/// ([`crate::quant::opcache`] — it is generic quant infrastructure);
/// re-exported here because serve sessions are its primary consumer.
pub use crate::quant::opcache as cache;

pub use batcher::{Batcher, BatcherConfig};
pub use self::cache::{operand_cache, CacheStats, OperandCache};
pub use decode::{DecodeEngine, Sampler, Sampling};
pub use engine::{EngineConfig, ResponseHandle, ServeEngine, ServeStats};
pub use crate::quant::shard::{shard_ranges, ShardedOperand};
pub use crate::util::par::ShardPool;
pub use http::{HttpServer, ServerStats};
pub use kvpool::{KvPool, KvPoolStats};
pub use packed_model::{reference_forward, PackedModel, SeqKv};
pub use scheduler::{
    DecodeRequest, DecodeResult, FinishReason, Priority, Scheduler,
    SchedulerConfig, StreamEvent,
};
pub use spec::{SpecDecodeEngine, SpecOutput};
