//! The `microscale traffic-bench` driver: trace-driven traffic against
//! the real serving edge ([`super::http`]) — bursty arrivals, shared
//! system prompts, mixed priority classes, mid-stream disconnects —
//! measuring what production SLOs measure: per-class TTFT/ITL/queue
//! wait at the socket, goodput, and peak KV bytes with and without
//! prefix sharing.
//!
//! Two phases, one report (**`BENCH_traffic.json`**, field map in
//! EXPERIMENTS.md §Perf):
//!
//! 1. **Sharing gates** (deterministic, no clocks): per KV codec in
//!    {FP8, FP4} × {UE4M3, UE5M3}, the same backlog — shared-prefix
//!    requests, a tight page budget forcing eviction, one mid-flight
//!    cancellation — runs against a prefix-sharing pool and an
//!    unshared one. Token streams must match bit for bit (admission
//!    dynamics differ — sharing frees pages — so this exercises the
//!    full order-invariance contract), the shared peak must not
//!    exceed the unshared peak with `dedup_hits > 0`, both pools must
//!    drain to zero, and N prefills of one page-aligned prompt must
//!    leave **exactly one physical copy** of its pages
//!    (`used == bytes_for_positions`, `shared == (N-1)·that`).
//! 2. **Timed loopback run**: a seeded trace (Poisson arrivals inside
//!    fixed-size bursts, prompt/output length mixtures, configurable
//!    shared-prefix ratio, interactive/batch mix, a cancellation
//!    fraction) drives [`super::http::HttpServer`] over real sockets,
//!    one SSE-streaming client thread per request timestamping every
//!    chunk. Afterwards the surviving streams are replayed through a
//!    direct scheduler on an **unshared** pool under a different
//!    prefill-chunking config — served tokens must match bit for bit
//!    — and `/stats` must show the pool drained to zero.
//!
//! The `pass` verdict is host-independent: gates, stream equality,
//! accounting, and drain — never the latency numbers. The per-class
//! percentiles are reported for SLO eyeballs and trend lines, not
//! gated (CI machines are not serving hardware).
//!
//! Shared by the CLI subcommand and `cargo bench --bench
//! traffic_bench`.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context};

use super::cache::operand_cache;
use super::decode::{DecodeEngine, Sampling};
use super::decode_bench::bench_dims;
use super::http::HttpServer;
use super::kvpool::KvPool;
use super::net;
use super::packed_model::PackedModel;
use super::scheduler::{
    DecodeRequest, Priority, Scheduler, SchedulerConfig,
};
use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::stats::percentiles;
use crate::util::json::{self, Json};

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct TrafficOpts {
    /// CI-sized run: tiny model, tiny trace.
    pub smoke: bool,
    /// Report path (`BENCH_traffic.json` in the working directory).
    pub out: PathBuf,
    /// Requests in the trace.
    pub requests: usize,
    /// Scheduler `max_active` for the served run.
    pub concurrency: usize,
    /// Trace seed — same seed, same trace, always.
    pub seed: u64,
    /// Shared system-prompt length in tokens.
    pub prefix_len: usize,
    /// Fraction of requests that start with the shared prefix.
    pub shared_ratio: f64,
    /// Fraction of requests in the batch priority class.
    pub batch_frac: f64,
    /// Fraction of clients that hang up after their first token.
    pub cancel_frac: f64,
    /// Requests per burst (Poisson arrivals inside, a gap between).
    pub burst_len: usize,
    /// Poisson arrival rate inside a burst (requests/second).
    pub rate_per_s: f64,
    /// Idle gap between bursts (milliseconds).
    pub burst_gap_ms: f64,
    /// Cache rows per KV pool page.
    pub page_rows: usize,
    /// Pool budget in full-context sequences of the serving codec.
    pub budget_seqs: f64,
    /// Longest random tail appended after the prefix (tokens).
    pub tail_max: usize,
    /// Largest generation budget in the mixture (tokens).
    pub max_new_max: usize,
    /// Interactive-class TTFT p95 limit in ms (`--slo-ttft-p95-ms`).
    /// `None` leaves the SLO verdict disarmed (`slo_verdict: null`).
    pub slo_ttft_p95_ms: Option<f64>,
    /// Interactive-class ITL p95 limit in ms (`--slo-itl-p95-ms`).
    pub slo_itl_p95_ms: Option<f64>,
}

impl TrafficOpts {
    pub fn new(smoke: bool) -> TrafficOpts {
        TrafficOpts {
            smoke,
            out: PathBuf::from("BENCH_traffic.json"),
            requests: if smoke { 12 } else { 48 },
            concurrency: if smoke { 3 } else { 8 },
            seed: 0x7AFF1C,
            prefix_len: if smoke { 8 } else { 32 },
            shared_ratio: 0.6,
            batch_frac: 0.35,
            cancel_frac: if smoke { 0.2 } else { 0.15 },
            burst_len: if smoke { 4 } else { 8 },
            rate_per_s: if smoke { 400.0 } else { 200.0 },
            burst_gap_ms: if smoke { 15.0 } else { 40.0 },
            page_rows: if smoke { 4 } else { 16 },
            budget_seqs: if smoke { 1.5 } else { 3.0 },
            tail_max: if smoke { 4 } else { 16 },
            max_new_max: if smoke { 6 } else { 24 },
            slo_ttft_p95_ms: None,
            slo_itl_p95_ms: None,
        }
    }
}

/// The sharing-gate codec axis: the paper's {element} × {scale}
/// matrix for KV pages.
fn gate_codecs() -> crate::Result<Vec<(&'static str, PerLayerQConfig)>> {
    Ok(vec![
        (
            "fp8_ue4m3",
            PerLayerQConfig::uniform(QConfig::named(
                "fp8_e4m3", "ue4m3", false,
            )?),
        ),
        (
            "fp8_ue5m3",
            PerLayerQConfig::uniform(QConfig::named(
                "fp8_e4m3", "ue5m3", false,
            )?),
        ),
        ("fp4_ue4m3", PerLayerQConfig::uniform(QConfig::fp4("ue4m3")?)),
        ("fp4_ue5m3", PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?)),
    ])
}

fn rand_prompt(rng: &mut Pcg64, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

/// One request of the trace, with its arrival offset.
#[derive(Debug, Clone)]
struct TraceReq {
    /// Arrival offset from trace start (milliseconds).
    at_ms: f64,
    prompt: Vec<i32>,
    max_new: usize,
    priority: Priority,
    /// Per-request sampling seed (small, so it survives JSON's f64).
    seed: u64,
    /// Hang up after receiving this many tokens (client disconnect).
    cancel_after: Option<usize>,
}

/// Mixture draw: 70% in the lower half of `1..=max`, 30% upper.
fn mixed_len(rng: &mut Pcg64, max: usize) -> usize {
    let lo_max = (max / 2).max(1);
    if rng.uniform() < 0.7 || lo_max == max {
        1 + (rng.next_u64() as usize) % lo_max
    } else {
        lo_max + 1 + (rng.next_u64() as usize) % (max - lo_max)
    }
}

/// Build the seeded trace (see module docs for the traffic model).
fn build_trace(
    opts: &TrafficOpts,
    vocab: usize,
    shared_prefix: &[i32],
    rng: &mut Pcg64,
) -> Vec<TraceReq> {
    let mut at_ms = 0.0f64;
    let mut trace = Vec::with_capacity(opts.requests);
    for i in 0..opts.requests {
        if i > 0 && i % opts.burst_len == 0 {
            at_ms += opts.burst_gap_ms;
        }
        // exponential inter-arrival inside the burst
        at_ms += -(1.0 - rng.uniform()).ln() * 1e3 / opts.rate_per_s;
        let mut prompt = if rng.uniform() < opts.shared_ratio {
            shared_prefix.to_vec()
        } else {
            Vec::new()
        };
        let tail = mixed_len(rng, opts.tail_max);
        prompt.extend(rand_prompt(rng, vocab, tail));
        // floor 3 so a first-token disconnect is genuinely mid-flight
        let max_new = mixed_len(rng, opts.max_new_max).max(3);
        let priority = if rng.uniform() < opts.batch_frac {
            Priority::Batch
        } else {
            Priority::Interactive
        };
        let cancel_after = (rng.uniform() < opts.cancel_frac).then_some(1);
        trace.push(TraceReq {
            at_ms,
            prompt,
            max_new,
            priority,
            seed: 0xB0B ^ (i as u64),
            cancel_after,
        });
    }
    trace
}

// ---------------------------------------------------------------- gates

/// Drive one backlog to completion on `pool`, cancelling `cancel_id`
/// after `cancel_at` steps. Returns `(results sorted by id, peak
/// shared_bytes observed, final pool stats)`.
fn drive_backlog(
    model: &Arc<PackedModel>,
    pool: &Arc<KvPool>,
    reqs: &[DecodeRequest],
    cfg: SchedulerConfig,
    cancel_id: u64,
    cancel_at: usize,
) -> crate::Result<(Vec<super::scheduler::DecodeResult>, usize)> {
    let mut sched =
        Scheduler::new(DecodeEngine::with_pool(model.clone(), pool.clone())?, cfg);
    for r in reqs {
        sched.submit(r.clone())?;
    }
    let mut peak_shared = 0usize;
    let mut steps = 0usize;
    while !sched.is_idle() {
        if steps == cancel_at {
            sched.cancel(cancel_id);
            if sched.is_idle() {
                break;
            }
        }
        sched.step()?;
        steps += 1;
        peak_shared = peak_shared.max(pool.stats().shared_bytes);
        ensure!(steps < 100_000, "gate run failed to converge");
    }
    Ok((sched.take_finished(), peak_shared))
}

/// N prefills of one page-aligned prompt must leave exactly one
/// physical copy of its pages (the ISSUE's refcount acceptance,
/// checked on real pool counters).
fn one_copy_check(
    model: &Arc<PackedModel>,
    pool: &Arc<KvPool>,
    prompt: &[i32],
) -> crate::Result<Json> {
    let n = 3usize;
    ensure!(
        !prompt.is_empty() && prompt.len() % pool.page_rows() == 0,
        "one-copy prompt must be page-aligned"
    );
    let engine = DecodeEngine::with_pool(model.clone(), pool.clone())?;
    let mut kvs = Vec::new();
    for _ in 0..n {
        let mut kv = engine.new_kv();
        engine.prefill(prompt, &mut kv)?;
        kvs.push(kv);
    }
    let one_seq = pool.bytes_for_positions(prompt.len());
    let stats = pool.stats();
    ensure!(
        stats.used_bytes == one_seq,
        "one-copy: {n} prefills hold {} B, want one sequence's {one_seq} B",
        stats.used_bytes
    );
    ensure!(
        stats.shared_bytes == (n - 1) * one_seq,
        "one-copy: shared_bytes {} != {} duplicate sequences",
        stats.shared_bytes,
        n - 1
    );
    drop(kvs);
    ensure!(
        pool.used_bytes() == 0,
        "one-copy: pool did not drain after the last reference dropped"
    );
    Ok(json::obj(vec![
        ("sequences", json::num(n as f64)),
        ("physical_bytes", json::num(one_seq as f64)),
        ("shared_bytes", json::num(((n - 1) * one_seq) as f64)),
        ("dedup_hits", json::num(stats.dedup_hits as f64)),
    ]))
}

/// One codec's shared-vs-unshared gate (see module docs, phase 1).
fn sharing_gate(
    label: &str,
    model: &Arc<PackedModel>,
    kv_cfg: &PerLayerQConfig,
    block_size: usize,
    opts: &TrafficOpts,
    rng: &mut Pcg64,
) -> crate::Result<Json> {
    let dims = *model.dims();
    let probe = KvPool::build_with(
        &dims, kv_cfg, block_size, opts.page_rows, usize::MAX, false,
    )?;
    let seq_bytes = probe.bytes_for_positions(dims.seq_len);
    // tight on purpose: ~1.2 full sequences forces admission blocking
    // and evict-and-requeue under both pools
    let budget = (seq_bytes as f64 * 1.2).ceil() as usize;
    let prefix = rand_prompt(rng, dims.vocab, opts.prefix_len);
    let max_new = if opts.smoke { 4 } else { 8 };
    let reqs: Vec<DecodeRequest> = (0..6u64)
        .map(|id| {
            let mut prompt = if id < 4 { prefix.clone() } else { Vec::new() };
            let tail = 1 + (rng.next_u64() % 3) as usize;
            prompt.extend(rand_prompt(rng, dims.vocab, tail));
            DecodeRequest {
                id,
                prompt,
                max_new_tokens: max_new,
                eos: None,
                sampling: Sampling::Temperature { temp: 0.9, seed: 0xA11 ^ id },
                priority: if id % 3 == 0 {
                    Priority::Batch
                } else {
                    Priority::Interactive
                },
            }
        })
        .collect();
    let cfg = SchedulerConfig {
        max_active: 3,
        max_prefill_per_step: 2,
        max_prefill_tokens: 2 * opts.page_rows,
        ..SchedulerConfig::default()
    };
    let mk = |sharing| {
        KvPool::build_with(
            &dims, kv_cfg, block_size, opts.page_rows, budget, sharing,
        )
    };

    let shared_pool = mk(true)?;
    let (shared_res, peak_shared_extra) =
        drive_backlog(model, &shared_pool, &reqs, cfg, 1, 3)?;
    let shared_stats = shared_pool.stats();
    let unshared_pool = mk(false)?;
    let (unshared_res, _) =
        drive_backlog(model, &unshared_pool, &reqs, cfg, 1, 3)?;
    let unshared_stats = unshared_pool.stats();

    ensure!(
        shared_res.len() == unshared_res.len(),
        "{label}: shared run finished {} requests, unshared {}",
        shared_res.len(),
        unshared_res.len()
    );
    for (a, b) in shared_res.iter().zip(&unshared_res) {
        ensure!(
            a.id == b.id && a.tokens == b.tokens && a.finish == b.finish,
            "{label}: request {} diverges under prefix sharing: {:?} vs {:?}",
            a.id,
            a.tokens,
            b.tokens
        );
    }
    ensure!(
        shared_stats.dedup_hits > 0,
        "{label}: shared-prefix backlog produced no dedup hits"
    );
    // NB: peak physical bytes are reported, not gated against each
    // other — sharing lowers resident bytes, which admits *more*
    // sequences, and prefill pages go in privately before they are
    // hash-consed, so the shared pool's transient high-water mark can
    // legitimately sit a page-granule above the unshared one. The hard
    // invariants are the budget bound and that real savings occurred.
    ensure!(
        shared_stats.peak_bytes <= budget
            && unshared_stats.peak_bytes <= budget,
        "{label}: a pool exceeded its budget (shared {} B, unshared {} \
         B, budget {budget} B)",
        shared_stats.peak_bytes,
        unshared_stats.peak_bytes
    );
    ensure!(
        peak_shared_extra > 0,
        "{label}: sharing never held a duplicate sequence's bytes"
    );
    ensure!(
        shared_pool.used_bytes() == 0 && unshared_pool.used_bytes() == 0,
        "{label}: a pool failed to drain (shared {} B, unshared {} B)",
        shared_pool.used_bytes(),
        unshared_pool.used_bytes()
    );

    let one_copy = one_copy_check(model, &mk(true)?, &prefix)?;
    println!(
        "   {label}: streams match, peak {} B shared vs {} B unshared \
         ({} dedup hits, {} B peak duplicate savings)",
        shared_stats.peak_bytes,
        unshared_stats.peak_bytes,
        shared_stats.dedup_hits,
        peak_shared_extra,
    );
    Ok(json::obj(vec![
        ("kv_codec", json::s(&shared_pool.codec_id(0))),
        ("streams_match", Json::Bool(true)),
        ("finished", json::num(shared_res.len() as f64)),
        ("budget_bytes", json::num(budget as f64)),
        ("shared_peak_bytes", json::num(shared_stats.peak_bytes as f64)),
        (
            "unshared_peak_bytes",
            json::num(unshared_stats.peak_bytes as f64),
        ),
        ("dedup_hits", json::num(shared_stats.dedup_hits as f64)),
        ("peak_shared_bytes", json::num(peak_shared_extra as f64)),
        ("drained", Json::Bool(true)),
        ("one_copy", one_copy),
    ]))
}

// ------------------------------------------------------------- clients

/// What one socket client measured.
#[derive(Debug)]
struct ClientOut {
    idx: usize,
    priority: Priority,
    /// The client hung up on purpose after `cancel_after` tokens.
    cancelled: bool,
    error: Option<String>,
    got_done: bool,
    /// Tokens from the final `done` event (authoritative).
    tokens: Vec<i32>,
    /// Tokens as streamed, one SSE event at a time.
    sse_tokens: Vec<i32>,
    ttft_ms: f64,
    itl_ms: Vec<f64>,
    queue_wait_ms: f64,
}

fn completion_body(tr: &TraceReq) -> String {
    json::obj(vec![
        (
            "prompt",
            json::arr(tr.prompt.iter().map(|&t| json::num(t as f64))),
        ),
        ("max_new_tokens", json::num(tr.max_new as f64)),
        ("temperature", json::num(0.9)),
        ("seed", json::num(tr.seed as f64)),
        ("priority", json::s(tr.priority.as_str())),
        ("stream", Json::Bool(true)),
    ])
    .to_string()
}

fn client_inner(
    addr: SocketAddr,
    tr: &TraceReq,
    out: &mut ClientOut,
) -> crate::Result<()> {
    let stream = TcpStream::connect(addr).context("connect")?;
    let mut w = &stream;
    let body = completion_body(tr);
    // one-shot socket per request (the arrival process owns connection
    // lifetimes here), so tell the server to close after responding
    net::write_request(
        &mut w,
        "POST",
        "/v1/completions",
        body.as_bytes(),
        false,
    )?;
    let sent = Instant::now();
    let mut r = BufReader::new(stream.try_clone().context("clone socket")?);
    let (status, _headers) = net::read_response_head(&mut r)?;
    ensure!(status == 200, "HTTP {status}");
    let mut last = sent;
    while let Some(chunk) = net::read_chunk(&mut r)? {
        let now = Instant::now();
        let text =
            std::str::from_utf8(&chunk).context("SSE chunk is not UTF-8")?;
        let payload = text
            .trim()
            .strip_prefix("data: ")
            .ok_or_else(|| anyhow!("not an SSE event: {text:?}"))?;
        let ev = Json::parse(payload).context("SSE payload")?;
        if let Some(tok) = ev.opt("token") {
            let gap_ms = now.duration_since(last).as_secs_f64() * 1e3;
            if out.sse_tokens.is_empty() {
                out.ttft_ms = gap_ms;
            } else {
                out.itl_ms.push(gap_ms);
            }
            last = now;
            out.sse_tokens.push(tok.as_i64()? as i32);
            if tr.cancel_after == Some(out.sse_tokens.len()) {
                out.cancelled = true;
                // dropping both socket halves IS the cancellation
                return Ok(());
            }
        } else if let Some(done) = ev.opt("done") {
            out.got_done = true;
            out.tokens = done
                .get("tokens")?
                .as_f64_vec()?
                .into_iter()
                .map(|v| v as i32)
                .collect();
            out.queue_wait_ms = done.get("queue_wait_ms")?.as_f64()?;
        } else {
            bail!("unexpected SSE event {payload:?}");
        }
    }
    ensure!(out.got_done, "stream ended without a done event");
    Ok(())
}

fn run_client(addr: SocketAddr, idx: usize, tr: &TraceReq) -> ClientOut {
    let mut out = ClientOut {
        idx,
        priority: tr.priority,
        cancelled: false,
        error: None,
        got_done: false,
        tokens: Vec::new(),
        sse_tokens: Vec::new(),
        ttft_ms: 0.0,
        itl_ms: Vec::new(),
        queue_wait_ms: 0.0,
    };
    if let Err(e) = client_inner(addr, tr, &mut out) {
        out.error = Some(format!("{e:#}"));
    }
    out
}

fn http_get(addr: SocketAddr, path: &str) -> crate::Result<Json> {
    let stream = TcpStream::connect(addr).context("connect")?;
    let mut w = &stream;
    net::write_request(&mut w, "GET", path, b"", false)?;
    let mut r = BufReader::new(stream.try_clone().context("clone socket")?);
    let resp = net::read_response(&mut r)?;
    ensure!(resp.status == 200, "GET {path}: HTTP {}", resp.status);
    Json::parse(std::str::from_utf8(&resp.body).context("stats body")?)
}

/// Percentile block for one priority class.
fn class_entry(outs: &[&ClientOut]) -> Json {
    let mut ttft: Vec<f64> = outs.iter().map(|o| o.ttft_ms).collect();
    let mut itl: Vec<f64> =
        outs.iter().flat_map(|o| o.itl_ms.iter().copied()).collect();
    let mut qw: Vec<f64> = outs.iter().map(|o| o.queue_wait_ms).collect();
    let [t50, t95, t99] = percentiles(&mut ttft, [50.0, 95.0, 99.0]);
    let [i50, i95, i99] = percentiles(&mut itl, [50.0, 95.0, 99.0]);
    let [q50, q95, q99] = percentiles(&mut qw, [50.0, 95.0, 99.0]);
    json::obj(vec![
        ("finished", json::num(outs.len() as f64)),
        ("ttft_p50_ms", json::num(t50)),
        ("ttft_p95_ms", json::num(t95)),
        ("ttft_p99_ms", json::num(t99)),
        ("itl_p50_ms", json::num(i50)),
        ("itl_p95_ms", json::num(i95)),
        ("itl_p99_ms", json::num(i99)),
        ("queue_wait_p50_ms", json::num(q50)),
        ("queue_wait_p95_ms", json::num(q95)),
        ("queue_wait_p99_ms", json::num(q99)),
    ])
}

/// Evaluate the opt-in SLO check against the interactive-class p95s.
///
/// With neither limit set the check is *disarmed*: `slo_verdict` stays
/// `null` and no `slo` object is emitted — latency is host-dependent,
/// so an unconditional verdict would flap across machines. With at
/// least one limit armed, returns a real boolean verdict plus an `slo`
/// object recording both the limits and the measured values. Either
/// way the verdict never feeds the host-independent `pass` field.
fn slo_eval(
    ttft_limit: Option<f64>,
    itl_limit: Option<f64>,
    ttft_p95: f64,
    itl_p95: f64,
) -> (Json, Json) {
    if ttft_limit.is_none() && itl_limit.is_none() {
        return (Json::Null, Json::Null);
    }
    let within = |limit: Option<f64>, measured: f64| match limit {
        Some(l) => measured <= l,
        None => true,
    };
    let ok = within(ttft_limit, ttft_p95) && within(itl_limit, itl_p95);
    let lim = |v: Option<f64>| match v {
        Some(l) => json::num(l),
        None => Json::Null,
    };
    let obj = json::obj(vec![
        ("class", json::s("interactive")),
        ("ttft_p95_limit_ms", lim(ttft_limit)),
        ("itl_p95_limit_ms", lim(itl_limit)),
        ("ttft_p95_ms", json::num(ttft_p95)),
        ("itl_p95_ms", json::num(itl_p95)),
    ]);
    (Json::Bool(ok), obj)
}

// ---------------------------------------------------------------- run

/// Run the bench and write the report; returns the report JSON.
pub fn run(opts: &TrafficOpts) -> crate::Result<Json> {
    ensure!(opts.requests >= 1, "--requests must be at least 1");
    ensure!(
        opts.prefix_len % opts.page_rows == 0 && opts.prefix_len > 0,
        "--prefix-len {} must be a positive multiple of --page-rows {} \
         (whole pages are the unit of sharing)",
        opts.prefix_len,
        opts.page_rows
    );
    let dims = bench_dims(opts.smoke);
    let block_size = if opts.smoke { 16 } else { 32 };
    ensure!(
        opts.prefix_len + opts.tail_max + opts.max_new_max.max(3)
            <= dims.seq_len,
        "prefix {} + tail {} + generation {} exceeds seq_len {}",
        opts.prefix_len,
        opts.tail_max,
        opts.max_new_max.max(3),
        dims.seq_len
    );
    let params = Params::init_surrogate(&dims, 2026);
    let weights = PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?);
    let model = Arc::new(PackedModel::build(
        &dims,
        &params,
        &weights,
        block_size,
        operand_cache(),
    )?);
    let mut rng = Pcg64::new(opts.seed);

    println!(
        "== traffic-bench ({}) : {} layers, d_model {}, seq {}, weights {}, \
         {} requests (prefix {} tokens, {:.0}% shared, {:.0}% batch, \
         {:.0}% disconnect), c{} ==",
        if opts.smoke { "smoke" } else { "full" },
        dims.n_layers,
        dims.d_model,
        dims.seq_len,
        weights.id(),
        opts.requests,
        opts.prefix_len,
        100.0 * opts.shared_ratio,
        100.0 * opts.batch_frac,
        100.0 * opts.cancel_frac,
        opts.concurrency,
    );

    // phase 1: deterministic sharing gates, every codec of the matrix
    println!("\n-- sharing gates ({{FP8,FP4}} x {{UE4M3,UE5M3}}) --");
    let mut gate_entries: Vec<(String, Json)> = Vec::new();
    for (label, kv_cfg) in gate_codecs()? {
        let entry =
            sharing_gate(label, &model, &kv_cfg, block_size, opts, &mut rng)?;
        gate_entries.push((label.to_string(), entry));
    }

    // phase 2: the timed loopback run, FP4/UE5M3 KV (the paper's
    // proposal), prefix sharing on
    let serve_cfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3")?);
    let probe = KvPool::build_with(
        &dims, &serve_cfg, block_size, opts.page_rows, usize::MAX, false,
    )?;
    let budget = (probe.bytes_for_positions(dims.seq_len) as f64
        * opts.budget_seqs)
        .ceil() as usize;
    let pool = KvPool::build_with(
        &dims, &serve_cfg, block_size, opts.page_rows, budget, true,
    )?;
    let shared_prefix = rand_prompt(&mut rng, dims.vocab, opts.prefix_len);
    let trace = build_trace(opts, dims.vocab, &shared_prefix, &mut rng);
    let planned_cancels =
        trace.iter().filter(|t| t.cancel_after.is_some()).count();

    let sched = Scheduler::new(
        DecodeEngine::with_pool(model.clone(), pool.clone())?,
        SchedulerConfig {
            max_active: opts.concurrency,
            max_prefill_per_step: opts.concurrency,
            max_prefill_tokens: 4 * opts.page_rows,
        },
    );
    let server = HttpServer::start(sched, "127.0.0.1:0")?;
    let addr = server.addr();
    println!("\n-- serving {} requests over {addr} --", trace.len());

    let t0 = Instant::now();
    let handles: Vec<_> = trace
        .iter()
        .enumerate()
        .map(|(idx, tr)| {
            let tr = tr.clone();
            thread::spawn(move || {
                let target = Duration::from_secs_f64(tr.at_ms / 1e3);
                let elapsed = t0.elapsed();
                if target > elapsed {
                    thread::sleep(target - elapsed);
                }
                run_client(addr, idx, &tr)
            })
        })
        .collect();
    let outs: Vec<ClientOut> = handles
        .into_iter()
        .map(|h| h.join().map_err(|_| anyhow!("client thread panicked")))
        .collect::<crate::Result<_>>()?;
    let wall_s = t0.elapsed().as_secs_f64();

    // the last disconnect may still be mid-cancel inside the
    // scheduler loop; poll until the pool drains (bounded)
    let mut final_stats = http_get(addr, "/stats")?;
    let drained = |s: &Json| -> crate::Result<bool> {
        Ok(s.get("pending")?.as_usize()? == 0
            && s.get("active")?.as_usize()? == 0
            && s.get("preempted")?.as_usize()? == 0
            && s.get("kv_used_bytes")?.as_usize()? == 0)
    };
    for _ in 0..250 {
        if drained(&final_stats)? {
            break;
        }
        thread::sleep(Duration::from_millis(20));
        final_stats = http_get(addr, "/stats")?;
    }
    let pool_drained = drained(&final_stats)?;
    server.shutdown();

    // sort the measurements back into trace order and split them
    let mut outs = outs;
    outs.sort_by_key(|o| o.idx);
    let errors: Vec<String> = outs
        .iter()
        .filter_map(|o| {
            o.error.as_ref().map(|e| format!("request {}: {e}", o.idx))
        })
        .collect();
    ensure!(errors.is_empty(), "client failures: {errors:?}");
    let completed: Vec<&ClientOut> =
        outs.iter().filter(|o| o.got_done).collect();
    let cancelled = outs.iter().filter(|o| o.cancelled).count();
    ensure!(
        completed.len() + cancelled == outs.len(),
        "{} completed + {} cancelled != {} requests",
        completed.len(),
        cancelled,
        outs.len()
    );
    // SSE events and the final result must tell the same story
    let sse_ok = completed.iter().all(|o| o.sse_tokens == o.tokens);
    ensure!(sse_ok, "an SSE stream disagrees with its done event");

    // replay the survivors through a direct scheduler on an UNSHARED
    // pool under a different prefill-chunking config: served streams
    // must be bit-identical (sharing + HTTP + scheduling invariance)
    let replay_pool = KvPool::build_with(
        &dims, &serve_cfg, block_size, opts.page_rows, budget, false,
    )?;
    let mut replay = Scheduler::new(
        DecodeEngine::with_pool(model.clone(), replay_pool.clone())?,
        SchedulerConfig {
            max_active: opts.concurrency,
            max_prefill_per_step: opts.concurrency,
            ..SchedulerConfig::default()
        },
    );
    for o in &completed {
        let tr = &trace[o.idx];
        replay.submit(DecodeRequest {
            id: o.idx as u64,
            prompt: tr.prompt.clone(),
            max_new_tokens: tr.max_new,
            eos: None,
            sampling: Sampling::Temperature { temp: 0.9, seed: tr.seed },
            priority: tr.priority,
        })?;
    }
    let direct = replay.run()?;
    ensure!(
        direct.len() == completed.len(),
        "replay finished {} of {} requests",
        direct.len(),
        completed.len()
    );
    let mut streams_ok = true;
    for (d, o) in direct.iter().zip(&completed) {
        if d.id != o.idx as u64 || d.tokens != o.tokens {
            streams_ok = false;
            println!(
                "   MISMATCH request {}: served {:?} vs direct {:?}",
                o.idx, o.tokens, d.tokens
            );
        }
    }

    let tokens: usize = completed.iter().map(|o| o.tokens.len()).sum();
    let goodput = tokens as f64 / wall_s.max(1e-9);
    let by_class = |p: Priority| -> Vec<&ClientOut> {
        completed.iter().copied().filter(|o| o.priority == p).collect()
    };
    let interactive = by_class(Priority::Interactive);
    let batch = by_class(Priority::Batch);
    let server_cancellations =
        final_stats.get("cancellations")?.as_usize()?;
    let kv_peak = final_stats.get("kv_peak_bytes")?.as_usize()?;
    let dedup_hits = final_stats.get("kv_dedup_hits")?.as_usize()?;

    println!(
        "   {} completed / {} disconnected, {goodput:8.1} tok/s goodput, \
         peak KV {kv_peak} B, {dedup_hits} dedup hits, drained: {}",
        completed.len(),
        cancelled,
        pool_drained,
    );

    // host-independent verdict: the sharing gates all passed (they
    // error out otherwise), served == direct bit for bit, SSE framing
    // agreed with results, every request accounted for, the pool
    // drained, and the server saw no more cancellations than clients
    // staged
    let pass = streams_ok
        && pool_drained
        && sse_ok
        && server_cancellations <= planned_cancels
        && (opts.shared_ratio == 0.0 || dedup_hits > 0);
    println!(
        "\n   verdict (gates + served-vs-direct streams + drain + \
         accounting): {}",
        if pass { "PASS" } else { "MISS" }
    );

    // opt-in SLO check (never part of `pass` — latency is the host's)
    let (int_ttft_p95, int_itl_p95) = {
        let mut ttft: Vec<f64> =
            interactive.iter().map(|o| o.ttft_ms).collect();
        let mut itl: Vec<f64> = interactive
            .iter()
            .flat_map(|o| o.itl_ms.iter().copied())
            .collect();
        let [t95] = percentiles(&mut ttft, [95.0]);
        let [i95] = percentiles(&mut itl, [95.0]);
        (t95, i95)
    };
    let (slo_verdict, slo_obj) = slo_eval(
        opts.slo_ttft_p95_ms,
        opts.slo_itl_p95_ms,
        int_ttft_p95,
        int_itl_p95,
    );
    if let Json::Bool(ok) = slo_verdict {
        println!(
            "   SLO (interactive ttft_p95 {int_ttft_p95:.2} ms, \
             itl_p95 {int_itl_p95:.2} ms): {}",
            if ok { "MET" } else { "MISSED" }
        );
    }

    let report = json::obj(vec![
        ("bench", json::s("traffic")),
        ("smoke", Json::Bool(opts.smoke)),
        ("simd_kernel", json::s(crate::util::simd::kernel_name())),
        (
            "model",
            json::obj(vec![
                ("vocab", json::num(dims.vocab as f64)),
                ("d_model", json::num(dims.d_model as f64)),
                ("n_heads", json::num(dims.n_heads as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
                ("block_size", json::num(block_size as f64)),
            ]),
        ),
        ("weights_qconfig", json::s(&weights.id())),
        ("kv_codec", json::s(&pool.codec_id(0))),
        (
            "workload",
            json::obj(vec![
                ("requests", json::num(opts.requests as f64)),
                ("seed", json::num(opts.seed as f64)),
                ("concurrency", json::num(opts.concurrency as f64)),
                ("prefix_len", json::num(opts.prefix_len as f64)),
                ("shared_ratio", json::num(opts.shared_ratio)),
                ("batch_frac", json::num(opts.batch_frac)),
                ("cancel_frac", json::num(opts.cancel_frac)),
                ("burst_len", json::num(opts.burst_len as f64)),
                ("rate_per_s", json::num(opts.rate_per_s)),
                ("burst_gap_ms", json::num(opts.burst_gap_ms)),
                ("page_rows", json::num(opts.page_rows as f64)),
                ("budget_bytes", json::num(budget as f64)),
                ("tail_max", json::num(opts.tail_max as f64)),
                ("max_new_max", json::num(opts.max_new_max as f64)),
            ]),
        ),
        ("sharing_gates", json::obj_owned(gate_entries)),
        (
            "http",
            json::obj(vec![
                ("completed", json::num(completed.len() as f64)),
                ("disconnected", json::num(cancelled as f64)),
                (
                    "server_cancellations",
                    json::num(server_cancellations as f64),
                ),
                ("streams_match_direct", Json::Bool(streams_ok)),
                ("sse_matches_result", Json::Bool(sse_ok)),
                ("drained", Json::Bool(pool_drained)),
                ("kv_peak_bytes", json::num(kv_peak as f64)),
                ("dedup_hits", json::num(dedup_hits as f64)),
                ("goodput_tok_s", json::num(goodput)),
                ("wall_s", json::num(wall_s)),
                (
                    "classes",
                    json::obj(vec![
                        ("interactive", class_entry(&interactive)),
                        ("batch", class_entry(&batch)),
                    ]),
                ),
            ]),
        ),
        // latency numbers above are SLO *inputs*, host-dependent by
        // nature — the pass verdict deliberately excludes them, and
        // slo_verdict stays null unless a limit was armed on the CLI
        ("slo", slo_obj),
        ("slo_verdict", slo_verdict),
        ("pass", Json::Bool(pass)),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_seeded_and_shaped() {
        let opts = TrafficOpts::new(true);
        let prefix: Vec<i32> = (0..opts.prefix_len as i32).collect();
        let mk = || {
            let mut rng = Pcg64::new(opts.seed);
            build_trace(&opts, 64, &prefix, &mut rng)
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.len(), opts.requests);
        // same seed, same trace — arrivals, prompts, classes, all of it
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_ms, y.at_ms);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.cancel_after, y.cancel_after);
        }
        // arrivals are non-decreasing and every prompt fits the model
        for w in a.windows(2) {
            assert!(w[1].at_ms >= w[0].at_ms);
        }
        let shared =
            a.iter().filter(|t| t.prompt.starts_with(&prefix)).count();
        assert!(shared > 0, "no request drew the shared prefix");
        for t in &a {
            assert!(!t.prompt.is_empty());
            assert!(t.max_new >= 3);
            assert!(
                t.prompt.len() + t.max_new
                    <= opts.prefix_len + opts.tail_max + opts.max_new_max
            );
        }
    }

    #[test]
    fn mixed_len_stays_in_range() {
        let mut rng = Pcg64::new(7);
        for max in [1usize, 2, 5, 16] {
            for _ in 0..200 {
                let v = mixed_len(&mut rng, max);
                assert!((1..=max).contains(&v), "{v} out of 1..={max}");
            }
        }
    }

    // Regression: slo_verdict used to be emitted unconditionally.
    // Disarmed (no CLI limit) must stay null; armed must judge the
    // interactive p95s against the given limits, partial limits too.
    #[test]
    fn slo_verdict_is_null_unless_armed() {
        let (verdict, obj) = slo_eval(None, None, 123.0, 45.0);
        assert!(matches!(verdict, Json::Null), "disarmed verdict");
        assert!(matches!(obj, Json::Null), "disarmed slo object");

        // both limits armed and met
        let (verdict, obj) = slo_eval(Some(200.0), Some(50.0), 123.0, 45.0);
        assert!(verdict.as_bool().unwrap());
        assert_eq!(obj.get("ttft_p95_ms").unwrap().as_f64().unwrap(), 123.0);
        assert_eq!(
            obj.get("ttft_p95_limit_ms").unwrap().as_f64().unwrap(),
            200.0
        );

        // one limit missed fails the whole verdict
        let (verdict, _) = slo_eval(Some(200.0), Some(40.0), 123.0, 45.0);
        assert!(!verdict.as_bool().unwrap());

        // a single armed limit judges only that axis; the other slot
        // is recorded as null
        let (verdict, obj) = slo_eval(Some(200.0), None, 123.0, 9999.0);
        assert!(verdict.as_bool().unwrap());
        assert!(matches!(
            obj.get("itl_p95_limit_ms").unwrap(),
            Json::Null
        ));
        let (verdict, _) = slo_eval(None, Some(40.0), 9999.0, 45.0);
        assert!(!verdict.as_bool().unwrap());
    }
}
