//! The `microscale spec-bench` driver: cross-precision speculative
//! decoding across the paper's format axis. A full-precision
//! (`bf16-exact`) target verifies windows proposed by a microscaled
//! draft, sweeping the draft codec over {FP4, FP8} × {UE4M3, UE5M3} ×
//! block size {4, 8, 16, 32} — the acceptance rate per cell is a
//! *behavioural* fidelity lens on the same grid the perplexity
//! experiments score: the fraction of greedy draft proposals the exact
//! target agrees with, measured on real decoding traffic instead of a
//! held-out loss.
//!
//! Per cell the driver (1) builds the draft [`PackedModel`] through
//! the shared operand cache, (2) gates on **stream invariance** — the
//! speculative stream (greedy *and* seeded temperature) must be
//! bit-identical to the cache-free [`generate_reforward`] stream of
//! the target model; nothing is timed otherwise — then (3) times
//! greedy speculative generation, recording acceptance, tok/s, the
//! draft-overhead fraction (draft wall time over draft + verify), and
//! the speedup against a non-speculative KV-cached baseline on the
//! same target. Greedy timing keeps every reported acceptance number
//! host-independent: it is a pure function of the weights and the
//! draft codec.
//!
//! Results land in machine-readable **`BENCH_spec.json`** (field map
//! in EXPERIMENTS.md §Perf). The acceptance line checks the best cell
//! at ≥ 1.3× the non-speculative baseline (full shapes only — smoke
//! runs record `pass: null`).
//!
//! Shared by the CLI subcommand and `cargo bench --bench spec_bench`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Context;

use super::cache::operand_cache;
use super::decode::{generate_reforward, DecodeEngine, Sampler, Sampling};
use super::decode_bench::bench_dims;
use super::packed_model::PackedModel;
use super::spec::SpecDecodeEngine;
use crate::dist::Pcg64;
use crate::model::weights::Params;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
use crate::util::json::{self, Json};

/// Driver options (CLI flags map onto these).
#[derive(Debug, Clone)]
pub struct SpecBenchOpts {
    /// CI-sized run: tiny model, shrunken grid, `pass: null`.
    pub smoke: bool,
    /// Report path (`BENCH_spec.json` in the working directory).
    pub out: PathBuf,
    /// Speculation depth (draft proposals per round).
    pub k: usize,
    /// Prompt tokens per request.
    pub prompt_len: usize,
    /// Generation budget per request.
    pub max_new: usize,
    /// Timed requests per grid cell.
    pub requests: usize,
    /// Draft-codec block sizes to sweep.
    pub block_sizes: Vec<usize>,
}

impl SpecBenchOpts {
    pub fn new(smoke: bool) -> SpecBenchOpts {
        SpecBenchOpts {
            smoke,
            out: PathBuf::from("BENCH_spec.json"),
            k: 4,
            prompt_len: if smoke { 4 } else { 32 },
            max_new: if smoke { 8 } else { 32 },
            requests: if smoke { 2 } else { 6 },
            block_sizes: if smoke {
                vec![8, 16]
            } else {
                vec![4, 8, 16, 32]
            },
        }
    }
}

/// The draft-codec element × scale axis (the paper's format matrix).
fn draft_formats() -> crate::Result<Vec<(String, QConfig)>> {
    let mut out = Vec::new();
    for elem in ["fp4_e2m1", "fp8_e4m3"] {
        for scale in ["ue4m3", "ue5m3"] {
            let short = if elem == "fp4_e2m1" { "fp4" } else { "fp8" };
            out.push((
                format!("{short}_{scale}"),
                QConfig::named(elem, scale, false)?,
            ));
        }
    }
    Ok(out)
}

fn prompt(rng: &mut Pcg64, vocab: usize, len: usize) -> Vec<i32> {
    (0..len).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

/// Non-speculative KV-cached generation on `engine` — the baseline a
/// speculative run must beat. Same stream as the speculative path by
/// construction (that is the whole invariance contract).
fn baseline_generate(
    engine: &DecodeEngine,
    prompt: &[i32],
    max_new: usize,
    sampling: &Sampling,
) -> crate::Result<Vec<i32>> {
    let mut sampler = Sampler::new(sampling)?;
    let mut kv = engine.new_kv();
    let mut logits = engine.prefill(prompt, &mut kv)?;
    let mut out = Vec::with_capacity(max_new);
    loop {
        let tok = sampler.pick(&logits);
        out.push(tok);
        if out.len() >= max_new {
            return Ok(out);
        }
        logits = engine.step(&[tok], std::slice::from_mut(&mut kv))?;
    }
}

/// Stream-invariance gate for one cell: speculative output must equal
/// the cache-free re-forward stream of the *target* model, greedy and
/// seeded temperature both. Run before any timing.
fn invariance_gate(
    label: &str,
    engine: &SpecDecodeEngine,
    target: &Arc<PackedModel>,
    prompt: &[i32],
    max_new: usize,
) -> crate::Result<()> {
    let policies = [
        Sampling::Greedy,
        Sampling::Temperature { temp: 0.9, seed: 0x5BEC },
    ];
    for sampling in &policies {
        let want = generate_reforward(target, prompt, max_new, None, sampling)?;
        let got = engine.generate(prompt, max_new, None, sampling)?;
        anyhow::ensure!(
            got.tokens == want,
            "{label}: speculative stream {:?} != re-forward stream {want:?} \
             under {sampling:?} — refusing to time",
            got.tokens
        );
    }
    Ok(())
}

/// Run the bench and write the report; returns the report JSON.
pub fn run(opts: &SpecBenchOpts) -> crate::Result<Json> {
    let dims = bench_dims(opts.smoke);
    anyhow::ensure!(opts.k >= 1, "--k must be at least 1");
    anyhow::ensure!(
        opts.prompt_len >= 1
            && opts.prompt_len + opts.max_new <= dims.seq_len,
        "prompt {} + max-new {} exceeds seq_len {}",
        opts.prompt_len,
        opts.max_new,
        dims.seq_len
    );
    let params = Params::init_surrogate(&dims, 2026);
    let formats = draft_formats()?;
    let mut rng = Pcg64::new(0x5BEC);

    println!(
        "== spec-bench ({}) : {} layers, d_model {}, seq {}, k={}, \
         prompt {}, {} new tokens/request, exact target ==",
        if opts.smoke { "smoke" } else { "full" },
        dims.n_layers,
        dims.d_model,
        dims.seq_len,
        opts.k,
        opts.prompt_len,
        opts.max_new,
    );

    // the verifier: one exact target shared by every cell (the draft
    // codec is the experiment; the target is the oracle)
    let target = Arc::new(PackedModel::build(
        &dims,
        &params,
        &PerLayerQConfig::uniform(QConfig::baseline()),
        16,
        operand_cache(),
    )?);

    // non-speculative KV-cached baseline on the same target
    let base_engine = DecodeEngine::new(target.clone())?;
    let base_prompts: Vec<Vec<i32>> = (0..opts.requests.max(1))
        .map(|_| prompt(&mut rng, dims.vocab, opts.prompt_len))
        .collect();
    let t0 = Instant::now();
    let mut base_tokens = 0usize;
    for p in &base_prompts {
        base_tokens +=
            baseline_generate(&base_engine, p, opts.max_new, &Sampling::Greedy)?
                .len();
    }
    let base_secs = t0.elapsed().as_secs_f64();
    let base_tok_s = base_tokens as f64 / base_secs.max(1e-9);
    println!(
        "   non-speculative baseline: {base_tok_s:8.1} tok/s \
         ({base_tokens} tokens)\n"
    );

    let mut cell_entries: Vec<(String, Json)> = Vec::new();
    let mut best: Option<(String, f64, f64)> = None; // (cell, speedup, acc)
    for (fmt_label, qcfg) in &formats {
        for &bs in &opts.block_sizes {
            let label = format!("{fmt_label}_bs{bs}");
            let draft = Arc::new(PackedModel::build(
                &dims,
                &params,
                &PerLayerQConfig::uniform(*qcfg),
                bs,
                operand_cache(),
            )?);
            let engine =
                SpecDecodeEngine::new(target.clone(), draft, opts.k)?;
            let gate_prompt = prompt(&mut rng, dims.vocab, opts.prompt_len);
            invariance_gate(
                &label,
                &engine,
                &target,
                &gate_prompt,
                opts.max_new.min(8),
            )?;

            // timed: greedy, so acceptance is a pure function of the
            // weights and the draft codec (host-independent)
            let t0 = Instant::now();
            let mut tokens = 0usize;
            let (mut proposed, mut accepted, mut rounds) = (0usize, 0, 0);
            let mut draft_s = 0.0f64;
            let mut verify_s = 0.0f64;
            for p in &base_prompts {
                let got =
                    engine.generate(p, opts.max_new, None, &Sampling::Greedy)?;
                tokens += got.tokens.len();
                proposed += got.proposed;
                accepted += got.accepted;
                rounds += got.rounds;
                draft_s += got.draft_time.as_secs_f64();
                verify_s += got.verify_time.as_secs_f64();
            }
            let secs = t0.elapsed().as_secs_f64();
            let tok_s = tokens as f64 / secs.max(1e-9);
            let acc = if proposed == 0 {
                1.0
            } else {
                accepted as f64 / proposed as f64
            };
            let overhead = draft_s / (draft_s + verify_s).max(1e-12);
            let speedup = tok_s / base_tok_s.max(1e-9);
            if best.as_ref().map(|(_, s, _)| speedup > *s).unwrap_or(true) {
                best = Some((label.clone(), speedup, acc));
            }
            println!(
                "   {label:<16}: acceptance {acc:5.3}  {tok_s:8.1} tok/s  \
                 ({speedup:.2}x vs non-spec, draft overhead {:.0}%)",
                overhead * 100.0
            );
            cell_entries.push((
                label,
                json::obj(vec![
                    ("draft_qconfig", json::s(&qcfg.id())),
                    ("block_size", json::num(bs as f64)),
                    ("stream_exact", Json::Bool(true)),
                    ("acceptance", json::num(acc)),
                    ("proposed", json::num(proposed as f64)),
                    ("accepted", json::num(accepted as f64)),
                    ("rounds", json::num(rounds as f64)),
                    ("tok_per_s", json::num(tok_s)),
                    ("speedup_vs_nonspec", json::num(speedup)),
                    ("draft_overhead_frac", json::num(overhead)),
                ]),
            ));
        }
    }

    let (best_cell, best_speedup, best_acc) =
        best.expect("grid cannot be empty");
    let pass = best_speedup >= 1.3;
    println!(
        "\n   acceptance target (best cell >= 1.30x non-speculative): {}",
        if opts.smoke {
            "n/a (smoke shapes)".to_string()
        } else if pass {
            format!("PASS ({best_cell} at {best_speedup:.2}x)")
        } else {
            format!(
                "MISS (best {best_cell} at {best_speedup:.2}x, \
                 host-dependent)"
            )
        }
    );

    let report = json::obj(vec![
        ("bench", json::s("spec")),
        ("smoke", Json::Bool(opts.smoke)),
        ("simd_kernel", json::s(crate::util::simd::kernel_name())),
        (
            "model",
            json::obj(vec![
                ("vocab", json::num(dims.vocab as f64)),
                ("d_model", json::num(dims.d_model as f64)),
                ("n_heads", json::num(dims.n_heads as f64)),
                ("n_layers", json::num(dims.n_layers as f64)),
                ("d_ff", json::num(dims.d_ff as f64)),
                ("seq_len", json::num(dims.seq_len as f64)),
            ]),
        ),
        ("target_qconfig", json::s(&QConfig::baseline().id())),
        ("k", json::num(opts.k as f64)),
        ("prompt_len", json::num(opts.prompt_len as f64)),
        ("max_new", json::num(opts.max_new as f64)),
        ("requests", json::num(opts.requests as f64)),
        ("baseline_tok_per_s", json::num(base_tok_s)),
        ("cells", json::obj_owned(cell_entries)),
        (
            "best",
            json::obj(vec![
                ("cell", json::s(&best_cell)),
                ("speedup_vs_nonspec", json::num(best_speedup)),
                ("acceptance", json::num(best_acc)),
            ]),
        ),
        ("target_speedup", json::num(1.3)),
        // the 1.3x target is defined on the full shapes only; smoke
        // runs record null so trajectory tooling can't misread
        // tiny-shape ratios as an acceptance verdict
        (
            "pass",
            if opts.smoke { Json::Null } else { Json::Bool(pass) },
        ),
    ]);
    std::fs::write(&opts.out, report.to_string())
        .with_context(|| format!("writing {}", opts.out.display()))?;
    println!("   wrote {}", opts.out.display());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_grid_covers_the_paper_format_matrix() {
        let formats = draft_formats().unwrap();
        let labels: Vec<&str> =
            formats.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(
            labels,
            ["fp4_ue4m3", "fp4_ue5m3", "fp8_ue4m3", "fp8_ue5m3"]
        );
        for (_, q) in &formats {
            assert!(q.quant_on, "grid cells must actually quantize");
        }
        let opts = SpecBenchOpts::new(false);
        assert_eq!(opts.block_sizes, [4, 8, 16, 32]);
        assert!(SpecBenchOpts::new(true).block_sizes.len() < 4);
    }

    #[test]
    fn baseline_generate_matches_the_reforward_oracle() {
        use crate::runtime::artifacts::ModelDims;
        let dims = ModelDims {
            vocab: 40,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 24,
        };
        let params = Params::init_surrogate(&dims, 9);
        let m = Arc::new(
            PackedModel::build(
                &dims,
                &params,
                &PerLayerQConfig::uniform(QConfig::baseline()),
                8,
                operand_cache(),
            )
            .unwrap(),
        );
        let engine = DecodeEngine::new(m.clone()).unwrap();
        let p = [3, 17, 5, 9];
        for sampling in [
            Sampling::Greedy,
            Sampling::Temperature { temp: 0.8, seed: 4 },
        ] {
            let want =
                generate_reforward(&m, &p, 6, None, &sampling).unwrap();
            let got =
                baseline_generate(&engine, &p, 6, &sampling).unwrap();
            assert_eq!(got, want, "{sampling:?}");
        }
    }
}
