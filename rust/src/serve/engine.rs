//! The multi-worker serving loop: submit/collect API over one shared
//! [`PackedModel`], with latency/throughput statistics.
//!
//! Worker threads block on the [`Batcher`], run one forward pass per
//! released batch, and deliver each request's logit slice through its
//! completion channel. With more than one worker, each marks itself
//! with the [`crate::util::par::WorkerGuard`] pool-worker protocol so
//! the packed GEMM inside stays serial (workers parallelize across
//! batches instead — the same no-ncpus²-oversubscription rule the
//! coordinator pool follows); a lone worker leaves the guard off and
//! lets the GEMM fan out across cores.
//!
//! A tensor-parallel model ([`PackedModel::build_sharded`]) composes
//! with both modes: each forward's shard fan-out is bounded by the
//! model's own [`crate::util::par::ShardPool`] (shards − 1 persistent
//! workers plus the calling engine worker, every slot marked), so
//! total threading is `workers + shards − 1`, never `workers ×
//! shards`, and logits stay bit-identical to the unsharded model for
//! any worker count.
//!
//! Determinism: request logits are identical for any worker count and
//! any arrival interleaving — batching invariance (see
//! [`super::packed_model`]) makes co-batch composition irrelevant, and
//! each forward pass is bitwise deterministic. `rust/tests/serve.rs`
//! pins this.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure};

use super::batcher::{Batcher, BatcherConfig, Request};
use super::packed_model::PackedModel;
use crate::util::par;

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Serving threads (each runs whole batches).
    pub workers: usize,
    pub batcher: BatcherConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: par::max_threads().min(4),
            batcher: BatcherConfig::default(),
        }
    }
}

/// Latency sample cap: percentiles are computed over a sliding window
/// of the most recent samples so a long-lived engine's memory and
/// `stats()` sort cost stay bounded.
const LATENCY_WINDOW: usize = 4096;

#[derive(Default)]
struct StatsInner {
    /// Ring buffer of the most recent `LATENCY_WINDOW` request
    /// latencies (submit → logits-ready).
    latencies_ns: Vec<u64>,
    lat_cursor: usize,
    requests: u64,
    tokens: u64,
    batches: u64,
    batched_requests: u64,
    errors: u64,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

impl StatsInner {
    fn record_latency(&mut self, ns: u64) {
        if self.latencies_ns.len() < LATENCY_WINDOW {
            self.latencies_ns.push(ns);
        } else {
            self.latencies_ns[self.lat_cursor] = ns;
            self.lat_cursor = (self.lat_cursor + 1) % LATENCY_WINDOW;
        }
    }

    fn snapshot(&self) -> ServeStats {
        let mut lat_ms: Vec<f64> =
            self.latencies_ns.iter().map(|&ns| ns as f64 / 1e6).collect();
        let [p50, p95, p99] =
            crate::stats::percentiles(&mut lat_ms, [50.0, 95.0, 99.0]);
        let window = match (self.first_submit, self.last_done) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64(),
            _ => 0.0,
        };
        let rate = |count: u64| -> f64 {
            if window > 0.0 {
                count as f64 / window
            } else {
                0.0
            }
        };
        ServeStats {
            requests: self.requests,
            tokens: self.tokens,
            batches: self.batches,
            errors: self.errors,
            mean_batch: if self.batches > 0 {
                self.batched_requests as f64 / self.batches as f64
            } else {
                0.0
            },
            p50_ms: p50,
            p95_ms: p95,
            p99_ms: p99,
            req_per_s: rate(self.requests),
            tok_per_s: rate(self.tokens),
        }
    }
}

/// Aggregate serving statistics. Latency percentiles cover the most
/// recent `LATENCY_WINDOW` (4096) requests (submit → logits-ready);
/// throughput is measured over the first-submit → last-completion
/// window.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub requests: u64,
    pub tokens: u64,
    pub batches: u64,
    pub errors: u64,
    /// Mean coalesced batch size.
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub req_per_s: f64,
    pub tok_per_s: f64,
}

/// Handle to one in-flight request.
pub struct ResponseHandle {
    pub id: u64,
    pub seq: usize,
    rx: mpsc::Receiver<crate::Result<Vec<f32>>>,
}

impl ResponseHandle {
    /// Block for the request's logits (`seq × vocab`, row-major).
    pub fn wait(self) -> crate::Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("serve worker dropped the request"))?
    }
}

/// The serving engine (see module docs).
pub struct ServeEngine {
    model: Arc<PackedModel>,
    batcher: Arc<Batcher>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    next_id: AtomicU64,
}

impl ServeEngine {
    /// Spawn `cfg.workers` serving threads over `model`.
    pub fn start(
        model: Arc<PackedModel>,
        cfg: EngineConfig,
    ) -> crate::Result<ServeEngine> {
        ensure!(cfg.workers >= 1, "need at least one worker");
        let batcher = Arc::new(Batcher::new(cfg.batcher));
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let mark = cfg.workers > 1;
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let m = model.clone();
            let b = batcher.clone();
            let st = stats.clone();
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || worker_loop(&m, &b, &st, mark))
                .map_err(|e| anyhow!("spawning serve worker: {e}"))?;
            workers.push(handle);
        }
        Ok(ServeEngine {
            model,
            batcher,
            workers,
            stats,
            next_id: AtomicU64::new(0),
        })
    }

    /// Admit one request (a full token sequence, `1..=seq_len` tokens).
    pub fn submit(&self, tokens: Vec<i32>) -> crate::Result<ResponseHandle> {
        let seq = tokens.len();
        let max = self.model.dims().seq_len;
        ensure!(
            seq >= 1 && seq <= max,
            "sequence length {seq} out of range 1..={max}"
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.stats.lock().unwrap();
            if st.first_submit.is_none() {
                st.first_submit = Some(Instant::now());
            }
        }
        let admitted = self.batcher.submit(Request {
            id,
            tokens,
            seq,
            enqueued: Instant::now(),
            done: tx,
        });
        ensure!(admitted, "engine is shut down");
        Ok(ResponseHandle { id, seq, rx })
    }

    /// Convenience: submit one request and block for its logits.
    pub fn infer(&self, tokens: Vec<i32>) -> crate::Result<Vec<f32>> {
        self.submit(tokens)?.wait()
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().snapshot()
    }

    /// Stop admissions, drain the queue, join workers; returns final
    /// stats. (Dropping the engine does the same minus the stats.)
    pub fn shutdown(mut self) -> ServeStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        self.batcher.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(
    model: &PackedModel,
    batcher: &Batcher,
    stats: &Mutex<StatsInner>,
    mark: bool,
) {
    // several workers -> each keeps its GEMM serial (pool-worker guard);
    // a lone worker lets the GEMM thread across cores instead
    let _guard = mark.then(par::WorkerGuard::enter);
    while let Some(batch) = batcher.next_batch() {
        serve_batch(model, batch, stats);
    }
}

fn serve_batch(model: &PackedModel, batch: Vec<Request>, stats: &Mutex<StatsInner>) {
    let n = batch.len();
    let seq = batch[0].seq;
    let mut tokens = Vec::with_capacity(n * seq);
    for r in &batch {
        tokens.extend_from_slice(&r.tokens);
    }
    let result = model.forward(&tokens, n, seq);
    let done_at = Instant::now();
    let vocab = model.dims().vocab;
    match result {
        Ok(logits) => {
            {
                let mut st = stats.lock().unwrap();
                st.batches += 1;
                st.batched_requests += n as u64;
                st.last_done = Some(done_at);
                for r in &batch {
                    st.requests += 1;
                    st.tokens += seq as u64;
                    st.record_latency(
                        done_at.duration_since(r.enqueued).as_nanos() as u64,
                    );
                }
            }
            for (i, r) in batch.into_iter().enumerate() {
                let slice =
                    logits[i * seq * vocab..(i + 1) * seq * vocab].to_vec();
                let _ = r.done.send(Ok(slice));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            {
                let mut st = stats.lock().unwrap();
                st.errors += n as u64;
                st.last_done = Some(done_at);
            }
            for r in batch {
                let _ = r.done.send(Err(anyhow!("forward failed: {msg}")));
            }
        }
    }
}
