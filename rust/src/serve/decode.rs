//! KV-cached autoregressive decoding over a [`PackedModel`].
//!
//! [`DecodeEngine`] is the thin, correctness-guarded entry to the
//! incremental forward spine ([`super::packed_model`] module docs):
//! prefill runs a sequence's prompt once, caching every position's
//! post-gain K/V rows ([`SeqKv`]); each subsequent [`DecodeEngine::step`]
//! feeds exactly one new token per live sequence and quantizes only that
//! token's activations through the packed GEMM. The load-bearing
//! contract — pinned step by step in `rust/tests/decode.rs` — is that
//! the cached step's logits are **bit-identical** to re-running
//! [`super::packed_model::reference_forward`] on the full prefix.
//!
//! The one configuration that contract cannot cover is per-tensor "-S"
//! *activation* scaling: its eq. 11 absmax spans the whole prefix,
//! which an incremental step never sees. [`DecodeEngine::new`] refuses
//! such configs up front (weight-only "-S" is fine — weights quantize
//! once at build time). Everything else the model builder accepts —
//! packed FP4/FP6/FP8 layers, reference-path INT4, `bf16-exact`
//! layers, mixed per-layer assignments — decodes exactly.
//!
//! Sampling ([`Sampler`]) is deterministic: greedy argmax (lowest index
//! on ties) or temperature sampling driven by a per-request
//! [`Pcg64`] seed, so a token stream is reproducible from
//! `(weights, qconfig, prompt, sampling)` alone — independent of
//! co-scheduled neighbors, admission order, and GEMM threading (see
//! [`super::scheduler`]). Tensor-parallel sharding joins that list:
//! the m == 1 decode step routes through the same sharded
//! [`super::packed_model`] linears as prefill, and shard fan-out is
//! bit-invariant (DESIGN.md §12), so a model built with
//! [`PackedModel::build_sharded`] emits the same token stream for
//! every shard count — `rust/tests/shard.rs` pins this end to end.

use std::sync::Arc;

use anyhow::ensure;

use crate::dist::Pcg64;

use super::kvpool::KvPool;
use super::packed_model::PackedModel;
pub use super::packed_model::SeqKv;

/// Token-selection policy for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax over the logits; ties break to the lowest token id.
    Greedy,
    /// Softmax at `temp` (> 0), sampled with a dedicated
    /// [`Pcg64`] stream — same seed, same stream, always.
    Temperature { temp: f64, seed: u64 },
}

/// A deterministic sampler instantiated from a [`Sampling`] policy.
#[derive(Debug, Clone)]
pub struct Sampler {
    rng: Option<Pcg64>,
    temp: f64,
}

impl Sampler {
    pub fn new(policy: &Sampling) -> crate::Result<Sampler> {
        match *policy {
            Sampling::Greedy => Ok(Sampler { rng: None, temp: 0.0 }),
            Sampling::Temperature { temp, seed } => {
                ensure!(
                    temp.is_finite() && temp > 0.0,
                    "sampling temperature {temp} must be positive"
                );
                Ok(Sampler { rng: Some(Pcg64::new(seed)), temp })
            }
        }
    }

    /// Pick the next token from one vocab-sized logit row.
    pub fn pick(&mut self, logits: &[f32]) -> i32 {
        match &mut self.rng {
            None => {
                // greedy: strict > keeps the lowest index on exact ties
                let mut best = 0usize;
                for (i, &l) in logits.iter().enumerate() {
                    if l > logits[best] {
                        best = i;
                    }
                }
                best as i32
            }
            Some(rng) => {
                // softmax in f64 with max subtraction; one uniform draw
                // walks the cumulative mass. All arithmetic is
                // deterministic, so streams replay exactly.
                let maxv =
                    logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = logits
                    .iter()
                    .map(|&l| (((l - maxv) as f64) / self.temp).exp())
                    .collect();
                let total: f64 = weights.iter().sum();
                let u = rng.uniform() * total;
                let mut cum = 0.0f64;
                for (i, &w) in weights.iter().enumerate() {
                    cum += w;
                    if u < cum {
                        return i as i32;
                    }
                }
                (logits.len() - 1) as i32
            }
        }
    }
}

/// KV-cached decoding facade over a shared [`PackedModel`] (module
/// docs). Cheap to clone-by-Arc into schedulers and benches.
/// Optionally backed by a byte-budgeted [`KvPool`]
/// ([`DecodeEngine::with_pool`]), in which case [`DecodeEngine::new_kv`]
/// hands out paged caches and the scheduler drives admission/eviction
/// from the pool's page accounting.
pub struct DecodeEngine {
    model: Arc<PackedModel>,
    pool: Option<Arc<KvPool>>,
}

impl DecodeEngine {
    /// Wrap `model`, refusing configurations whose cached step could
    /// not be bit-identical to the full-prefix reference (per-tensor
    /// "-S" activation scaling — see module docs). Caches come from
    /// unbounded inline storage; use [`DecodeEngine::with_pool`] for
    /// memory-bounded serving.
    pub fn new(model: Arc<PackedModel>) -> crate::Result<DecodeEngine> {
        Self::build(model, None)
    }

    /// Like [`DecodeEngine::new`], but caches allocate from `pool`.
    /// The pool must match the model's shape, and its budget must fit
    /// at least one full-context sequence — the invariant that makes
    /// the scheduler's evict-down-to-one policy deadlock-free.
    ///
    /// With an all-`Exact` pool the decode exactness contract holds
    /// unchanged; `Mx` page codecs trade it for the stated
    /// quantized-KV error model ([`super::kvpool`] docs).
    pub fn with_pool(
        model: Arc<PackedModel>,
        pool: Arc<KvPool>,
    ) -> crate::Result<DecodeEngine> {
        Self::build(model, Some(pool))
    }

    fn build(
        model: Arc<PackedModel>,
        pool: Option<Arc<KvPool>>,
    ) -> crate::Result<DecodeEngine> {
        for layer in 0..model.dims().n_layers {
            let cfg = model.qcfg().layer(layer);
            ensure!(
                !(cfg.quant_on && cfg.per_tensor && cfg.act_quant),
                "layer {layer} ({}): per-tensor activation scaling needs the \
                 whole-prefix absmax — KV-cached decode cannot reproduce it \
                 bit-exactly (use weight-only -S or a block scheme)",
                cfg.id()
            );
        }
        if let Some(p) = &pool {
            let dims = model.dims();
            ensure!(
                p.d_model() == dims.d_model && p.n_layers() == dims.n_layers,
                "KV pool shaped for d_model {} × {} layers, model is {} × {}",
                p.d_model(),
                p.n_layers(),
                dims.d_model,
                dims.n_layers
            );
            let worst = p.bytes_for_positions(dims.seq_len);
            ensure!(
                worst <= p.budget_bytes(),
                "KV pool budget {} cannot hold one full-context sequence \
                 ({worst} bytes for {} positions) — generation could \
                 deadlock at capacity",
                p.budget_bytes(),
                dims.seq_len
            );
        }
        Ok(DecodeEngine { model, pool })
    }

    pub fn model(&self) -> &Arc<PackedModel> {
        &self.model
    }

    /// The backing KV pool, when this engine is memory-bounded.
    pub fn pool(&self) -> Option<&Arc<KvPool>> {
        self.pool.as_ref()
    }

    /// A cache shaped for this model: paged when the engine has a
    /// [`KvPool`], inline (full `seq_len` capacity) otherwise.
    pub fn new_kv(&self) -> SeqKv {
        match &self.pool {
            Some(p) => p.seq(),
            None => self.model.new_kv(),
        }
    }

    /// Run `tokens` (appended after `kv.len()` cached positions —
    /// `kv.len() == 0` for a fresh prompt, more for chunked prefill)
    /// and return the **last** position's logits (`vocab`).
    pub fn prefill(
        &self,
        tokens: &[i32],
        kv: &mut SeqKv,
    ) -> crate::Result<Vec<f32>> {
        self.model.forward_ragged(
            tokens,
            &[tokens.len()],
            std::slice::from_mut(kv),
            true,
        )
    }

    /// One decode step: token `b` of `tokens` extends cache `b`.
    /// Returns `batch × vocab` next-token logits.
    pub fn step(
        &self,
        tokens: &[i32],
        kvs: &mut [SeqKv],
    ) -> crate::Result<Vec<f32>> {
        let lens = vec![1usize; kvs.len()];
        self.model.forward_ragged(tokens, &lens, kvs, true)
    }

    /// Mixed prefill + decode step (continuous batching): `lens[b]` new
    /// tokens for sequence `b`. Returns each sequence's final-position
    /// logits (`batch × vocab`).
    pub fn step_ragged(
        &self,
        tokens: &[i32],
        lens: &[usize],
        kvs: &mut [SeqKv],
    ) -> crate::Result<Vec<f32>> {
        self.model.forward_ragged(tokens, lens, kvs, true)
    }
}

/// The cache-free baseline: generate `max_new` tokens by re-running
/// [`PackedModel::forward`] on the **full prefix** for every token —
/// the decode-bench denominator, and the stream oracle the differential
/// tests compare scheduler output against. Stops early on `eos` or a
/// full context window.
pub fn generate_reforward(
    model: &PackedModel,
    prompt: &[i32],
    max_new: usize,
    eos: Option<i32>,
    sampling: &Sampling,
) -> crate::Result<Vec<i32>> {
    ensure!(!prompt.is_empty(), "empty prompt");
    let vocab = model.dims().vocab;
    let mut sampler = Sampler::new(sampling)?;
    let mut prefix = prompt.to_vec();
    let mut out = Vec::with_capacity(max_new);
    while out.len() < max_new {
        let logits = model.forward(&prefix, 1, prefix.len())?;
        let last = &logits[(prefix.len() - 1) * vocab..prefix.len() * vocab];
        let tok = sampler.pick(last);
        out.push(tok);
        if eos == Some(tok) || prefix.len() == model.dims().seq_len {
            break;
        }
        prefix.push(tok);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::Params;
    use crate::runtime::artifacts::ModelDims;
    use crate::runtime::qconfig::{PerLayerQConfig, QConfig};
    use crate::serve::cache::OperandCache;

    fn tiny() -> (ModelDims, Params) {
        let dims = ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        };
        let params = Params::init_surrogate(&dims, 21);
        (dims, params)
    }

    #[test]
    fn greedy_breaks_ties_to_lowest_index() {
        let mut s = Sampler::new(&Sampling::Greedy).unwrap();
        assert_eq!(s.pick(&[0.5, 2.0, 2.0, -1.0]), 1);
        assert_eq!(s.pick(&[3.0, 2.0, 3.0]), 0);
    }

    #[test]
    fn temperature_streams_replay_per_seed() {
        let logits = vec![0.1f32, 0.7, -0.3, 0.2];
        let draw = |seed: u64| -> Vec<i32> {
            let mut s = Sampler::new(&Sampling::Temperature {
                temp: 0.8,
                seed,
            })
            .unwrap();
            (0..32).map(|_| s.pick(&logits)).collect()
        };
        assert_eq!(draw(5), draw(5));
        assert_ne!(draw(5), draw(6)); // astronomically unlikely to match
        assert!(draw(5).iter().all(|&t| (0..4).contains(&t)));
        // zero/negative temperatures are refused
        assert!(Sampler::new(&Sampling::Temperature { temp: 0.0, seed: 1 })
            .is_err());
    }

    #[test]
    fn engine_refuses_per_tensor_activation_scaling() {
        let (dims, params) = tiny();
        let cache = OperandCache::new(32);
        let per_tensor = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap())
            .with_override(
                1,
                QConfig::named("fp4_e2m1", "ue4m3", true).unwrap(),
            );
        let model = Arc::new(
            PackedModel::build(&dims, &params, &per_tensor, 8, &cache).unwrap(),
        );
        assert!(DecodeEngine::new(model).is_err());
        // weight-only -S quantizes no activations: allowed
        let mut wonly = QConfig::named("fp4_e2m1", "ue4m3", true).unwrap();
        wonly.act_quant = false;
        let qcfg = PerLayerQConfig::uniform(wonly);
        let model = Arc::new(
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap(),
        );
        assert!(DecodeEngine::new(model).is_ok());
    }

    #[test]
    fn prefill_then_steps_match_whole_batch_forward() {
        let (dims, params) = tiny();
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap());
        let model = Arc::new(
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap(),
        );
        let engine = DecodeEngine::new(model.clone()).unwrap();
        let toks: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let full = model.forward(&toks, 1, toks.len()).unwrap();
        let v = dims.vocab;

        let mut kv = engine.new_kv();
        let got = engine.prefill(&toks[..3], &mut kv).unwrap();
        assert_eq!(kv.len(), 3);
        for (i, (a, b)) in got.iter().zip(&full[2 * v..3 * v]).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "prefill logit {i}");
        }
        for (t, &tok) in toks.iter().enumerate().skip(3) {
            let got =
                engine.step(&[tok], std::slice::from_mut(&mut kv)).unwrap();
            let want = &full[t * v..(t + 1) * v];
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "step {t} logit {i}");
            }
        }
        assert_eq!(kv.len(), toks.len());
        assert!(kv.resident_bytes() > 0);
        // context is full: another step must refuse
        assert!(engine.step(&[0], std::slice::from_mut(&mut kv)).is_err());
    }
}
