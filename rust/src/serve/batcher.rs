//! Admission queue with deadline/size-triggered micro-batching.
//!
//! Concurrent requests coalesce into one forward-batch matrix. The
//! release state machine (documented in DESIGN.md §9) is:
//!
//! 1. **Size trigger** — as soon as `max_batch` compatible requests are
//!    queued, a batch is released immediately.
//! 2. **Deadline trigger** — otherwise, once the *oldest* queued
//!    request has waited `max_wait`, whatever is compatible with it is
//!    released (latency is bounded by `max_wait` + one forward pass
//!    ahead of it in line).
//! 3. **Drain trigger** — after [`Batcher::close`], remaining requests
//!    release without waiting, then [`Batcher::next_batch`] returns
//!    `None` and workers exit.
//!
//! "Compatible" means equal sequence length: a batch is one
//! `(n, seq)` token matrix. The collector gives the head's length group
//! priority (the head always makes progress, so mixed-length traffic
//! cannot starve), but a **full** non-head group also releases on the
//! size trigger alone — a complete batch never idles behind an
//! incompatible head that hasn't reached its deadline. Coalescing never
//! changes results — see the batching-invariance notes in
//! [`super::packed_model`].

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request: a full token sequence plus its completion
/// channel (the engine sends the request's logits back through `done`).
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub seq: usize,
    pub enqueued: Instant,
    pub done: mpsc::Sender<crate::Result<Vec<f32>>>,
}

/// Micro-batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Coalesce at most this many requests into one forward batch.
    pub max_batch: usize,
    /// Oldest-request deadline: a non-full batch releases once the head
    /// of the queue has waited this long.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

struct State {
    queue: VecDeque<Request>,
    closed: bool,
}

/// The admission queue (see module docs).
pub struct Batcher {
    cfg: BatcherConfig,
    state: Mutex<State>,
    ready: Condvar,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg: BatcherConfig { max_batch: cfg.max_batch.max(1), ..cfg },
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Admit a request; returns `false` (dropping the request) if the
    /// batcher is closed.
    pub fn submit(&self, req: Request) -> bool {
        let mut g = self.state.lock().unwrap();
        if g.closed {
            return false;
        }
        g.queue.push_back(req);
        self.ready.notify_one();
        true
    }

    /// Queued (not yet collected) request count.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Stop admissions; queued requests still drain.
    pub fn close(&self) {
        let mut g = self.state.lock().unwrap();
        g.closed = true;
        self.ready.notify_all();
    }

    /// Block until a batch is ready per the release rules; `None` once
    /// the batcher is closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(batch) = self.try_collect(&mut g) {
                return Some(batch);
            }
            if g.closed && g.queue.is_empty() {
                return None;
            }
            if g.queue.is_empty() {
                g = self.ready.wait(g).unwrap();
            } else {
                // sleep until the head's deadline (or a new submission)
                let age = g.queue.front().unwrap().enqueued.elapsed();
                let left = self
                    .cfg
                    .max_wait
                    .saturating_sub(age)
                    .max(Duration::from_micros(50));
                let (g2, _timeout) = self.ready.wait_timeout(g, left).unwrap();
                g = g2;
            }
        }
    }

    /// The release rule: the head's same-sequence-length group releases
    /// on size/deadline/drain; a *full* non-head group releases on size
    /// alone, so a complete batch never waits behind an incompatible
    /// head (module docs).
    fn try_collect(&self, g: &mut State) -> Option<Vec<Request>> {
        let head = g.queue.front()?;
        let head_seq = head.seq;
        let deadline_hit = head.enqueued.elapsed() >= self.cfg.max_wait;
        let mut head_idxs = Vec::new();
        // non-head groups in first-seen order: (seq, queue indices)
        let mut others: Vec<(usize, Vec<usize>)> = Vec::new();
        for (i, r) in g.queue.iter().enumerate() {
            if r.seq == head_seq {
                head_idxs.push(i);
                if head_idxs.len() == self.cfg.max_batch {
                    break; // head priority satisfied
                }
            } else {
                let p = others.iter().position(|(s, _)| *s == r.seq);
                let grp = match p {
                    Some(p) => &mut others[p],
                    None => {
                        others.push((r.seq, Vec::new()));
                        others.last_mut().unwrap()
                    }
                };
                if grp.1.len() < self.cfg.max_batch {
                    grp.1.push(i);
                }
            }
        }
        let take = if head_idxs.len() == self.cfg.max_batch
            || deadline_hit
            || g.closed
        {
            head_idxs
        } else if let Some(p) = others
            .iter()
            .position(|(_, v)| v.len() >= self.cfg.max_batch)
        {
            others.swap_remove(p).1
        } else {
            return None;
        };
        // remove back-to-front so earlier indices stay valid
        let mut batch: Vec<Request> = Vec::with_capacity(take.len());
        for &i in take.iter().rev() {
            batch.push(g.queue.remove(i).unwrap());
        }
        batch.reverse(); // restore admission order
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, seq: usize) -> (Request, mpsc::Receiver<crate::Result<Vec<f32>>>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                id,
                tokens: vec![0; seq],
                seq,
                enqueued: Instant::now(),
                done: tx,
            },
            rx,
        )
    }

    #[test]
    fn size_trigger_releases_full_batch_in_order() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(60),
        });
        let mut rxs = Vec::new();
        for id in 0..4 {
            let (r, rx) = req(id, 8);
            assert!(b.submit(r));
            rxs.push(rx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), [0, 1, 2]);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn deadline_trigger_releases_partial_batch() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        let (r, _rx) = req(7, 4);
        assert!(b.submit(r));
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 7);
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn full_batch_is_not_blocked_by_incompatible_head() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(60),
        });
        let (r, _rx0) = req(0, 4);
        assert!(b.submit(r));
        let mut keep = Vec::new();
        for id in 1..=4 {
            let (r, rx) = req(id, 8);
            assert!(b.submit(r));
            keep.push(rx);
        }
        // the seq-8 group is complete: it must release on the size
        // trigger even though the seq-4 head is nowhere near deadline
        let batch = b.next_batch().unwrap();
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            [1, 2, 3, 4]
        );
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn mixed_lengths_split_into_uniform_batches() {
        let b = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(60),
        });
        let mut keep = Vec::new();
        for (id, seq) in [(0u64, 8usize), (1, 4), (2, 8), (3, 8), (4, 8)] {
            let (r, rx) = req(id, seq);
            assert!(b.submit(r));
            keep.push(rx);
        }
        // four seq-8 requests fill a batch around the seq-4 one
        let batch = b.next_batch().unwrap();
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            [0, 2, 3, 4]
        );
        assert!(batch.iter().all(|r| r.seq == 8));
        // the leftover seq-4 request drains on close
        b.close();
        let rest = b.next_batch().unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].id, 1);
        assert!(b.next_batch().is_none());
        // closed batcher refuses admissions
        let (r, _rx) = req(9, 8);
        assert!(!b.submit(r));
    }
}
