//! Minimal dependency-free HTTP/1.1 framing over buffered streams.
//!
//! The serving edge deliberately vendors its own wire layer instead of
//! pulling a server crate (the repo's dependency budget is `anyhow`
//! alone): everything here is plain `std` over `BufRead`/`Write`, and
//! — like the rest of the crate — small enough to read in one sitting.
//! Two sides live in this module:
//!
//! * **Server side** ([`read_request`], [`Response`] writers): parse
//!   one request off a connection, answer it either as a fixed
//!   `Content-Length` body or as a `Transfer-Encoding: chunked` stream
//!   ([`ChunkWriter`]) — the latter is what carries SSE token events
//!   out of `super::http` as they are emitted.
//! * **Client side** ([`write_request`], [`read_response`],
//!   [`read_chunk`]): enough of a client to drive the real server over
//!   loopback from tests and `microscale traffic-bench`, including
//!   incremental chunk reads so the bench can timestamp each token's
//!   arrival (TTFT/ITL are measured at the socket, not in-process).
//!
//! Parsing is strict and bounded: request/status lines and headers cap
//! at [`MAX_LINE_BYTES`], header count at [`MAX_HEADERS`], bodies at
//! [`MAX_BODY_BYTES`]; anything over is an error, not a truncation.
//! Header names are lowercased at parse time so lookups are
//! case-insensitive per RFC 9110.

use std::io::{BufRead, Write};

use anyhow::{anyhow, ensure, Context};

/// Longest accepted request/status/header line (bytes, CRLF excluded).
pub const MAX_LINE_BYTES: usize = 16 * 1024;
/// Most headers accepted per message.
pub const MAX_HEADERS: usize = 64;
/// Largest accepted message body (fixed-length or chunked total).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `HTTP/1.1` or `HTTP/1.0` (anything else is rejected at parse).
    pub version: String,
    /// `(lowercased name, value)` in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name` (give it lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        debug_assert_eq!(name, name.to_ascii_lowercase());
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client may reuse the connection after this request
    /// (RFC 9112 §9.3): HTTP/1.1 defaults to persistent unless the
    /// request says `Connection: close`; HTTP/1.0 defaults to close
    /// unless it says `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) if v.eq_ignore_ascii_case("close") => false,
            Some(v) if v.eq_ignore_ascii_case("keep-alive") => true,
            _ => self.version == "HTTP/1.1",
        }
    }
}

/// One parsed HTTP response (client side).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    /// `(lowercased name, value)` in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    /// First value of `name` (give it lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        debug_assert_eq!(name, name.to_ascii_lowercase());
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one CRLF- (or bare-LF-) terminated line, bounded by
/// [`MAX_LINE_BYTES`]. `Ok(None)` means clean EOF before any byte.
fn read_line<R: BufRead>(r: &mut R) -> crate::Result<Option<String>> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                anyhow::bail!("connection closed mid-line");
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    let s = String::from_utf8(buf)
                        .map_err(|_| anyhow!("non-UTF-8 header line"))?;
                    return Ok(Some(s));
                }
                ensure!(
                    buf.len() < MAX_LINE_BYTES,
                    "header line exceeds {MAX_LINE_BYTES} bytes"
                );
                buf.push(byte[0]);
            }
            // keep the io::Error as the source so callers can tell a
            // read timeout (idle keep-alive connection) from garbage
            Err(e) => {
                return Err(
                    anyhow::Error::new(e).context("reading header line")
                )
            }
        }
    }
}

/// Parse `Name: value` header lines until the blank separator.
fn read_headers<R: BufRead>(
    r: &mut R,
) -> crate::Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?
            .ok_or_else(|| anyhow!("connection closed inside headers"))?;
        if line.is_empty() {
            return Ok(headers);
        }
        ensure!(headers.len() < MAX_HEADERS, "more than {MAX_HEADERS} headers");
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed header line {line:?}"))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }
}

/// Read a `Content-Length` body (0 without the header), bounded by
/// [`MAX_BODY_BYTES`].
fn read_sized_body<R: BufRead>(
    r: &mut R,
    headers: &[(String, String)],
) -> crate::Result<Vec<u8>> {
    let len = match headers.iter().find(|(n, _)| n == "content-length") {
        None => 0usize,
        Some((_, v)) => v
            .parse::<usize>()
            .with_context(|| format!("bad content-length {v:?}"))?,
    };
    ensure!(len <= MAX_BODY_BYTES, "body of {len} bytes exceeds cap");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .map_err(|e| anyhow!("reading {len}-byte body: {e}"))?;
    Ok(body)
}

/// Parse one request off the connection. `Ok(None)` is a clean close
/// before the request line (keep-alive peer going away) — not an
/// error.
pub fn read_request<R: BufRead>(
    r: &mut R,
) -> crate::Result<Option<Request>> {
    let Some(line) = read_line(r)? else { return Ok(None) };
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (
        parts.next(),
        parts.next(),
        parts.next(),
    ) {
        (Some(m), Some(p), Some(v)) => (m, p, v),
        _ => anyhow::bail!("malformed request line {line:?}"),
    };
    ensure!(
        version == "HTTP/1.1" || version == "HTTP/1.0",
        "unsupported HTTP version {version:?}"
    );
    let headers = read_headers(r)?;
    let body = read_sized_body(r, &headers)?;
    Ok(Some(Request {
        method: method.to_string(),
        path: path.to_string(),
        version: version.to_string(),
        headers,
        body,
    }))
}

/// Write a complete fixed-length response. `keep_alive` picks the
/// `Connection` header: the server passes the client's negotiated
/// persistence ([`Request::keep_alive`], possibly overridden by its
/// requests-per-connection cap) so the advertised behavior always
/// matches what the connection loop actually does.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> crate::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    )
    .and_then(|()| w.write_all(body))
    .and_then(|()| w.flush())
    .map_err(|e| anyhow!("writing response: {e}"))
}

/// A `Transfer-Encoding: chunked` response in progress: the head goes
/// out at construction, each [`ChunkWriter::chunk`] flushes
/// immediately (token latency is the point), and [`ChunkWriter::end`]
/// writes the terminal chunk. Any write error surfaces to the caller —
/// that is the server's client-disconnect signal.
pub struct ChunkWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkWriter<W> {
    /// Write the response head. `keep_alive` as in [`write_response`]
    /// — chunked framing delimits the body, so a persistent connection
    /// stays usable after [`ChunkWriter::end`].
    pub fn start(
        mut w: W,
        status: u16,
        reason: &str,
        content_type: &str,
        keep_alive: bool,
    ) -> crate::Result<ChunkWriter<W>> {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        write!(
            w,
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
             Transfer-Encoding: chunked\r\nConnection: {conn}\r\n\r\n"
        )
        .and_then(|()| w.flush())
        .map_err(|e| anyhow!("writing chunked head: {e}"))?;
        Ok(ChunkWriter { w })
    }

    /// Send one chunk (empty input is skipped — a zero-length chunk
    /// would terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> crate::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())
            .and_then(|()| self.w.write_all(data))
            .and_then(|()| self.w.write_all(b"\r\n"))
            .and_then(|()| self.w.flush())
            .map_err(|e| anyhow!("writing chunk: {e}"))
    }

    /// Terminate the stream (the `0\r\n\r\n` chunk).
    pub fn end(mut self) -> crate::Result<()> {
        self.w
            .write_all(b"0\r\n\r\n")
            .and_then(|()| self.w.flush())
            .map_err(|e| anyhow!("writing terminal chunk: {e}"))
    }
}

/// Client side: write one request with an optional body. With
/// `keep_alive` the HTTP/1.1 default (persistent) applies and no
/// `Connection` header is sent; without it the request carries
/// `Connection: close`, telling the server to close after responding.
pub fn write_request<W: Write>(
    w: &mut W,
    method: &str,
    path: &str,
    body: &[u8],
    keep_alive: bool,
) -> crate::Result<()> {
    let conn =
        if keep_alive { "" } else { "Connection: close\r\n" };
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\n\
         Content-Length: {}\r\n{conn}\r\n",
        body.len()
    )
    .and_then(|()| w.write_all(body))
    .and_then(|()| w.flush())
    .map_err(|e| anyhow!("writing request: {e}"))
}

/// Client side: read one chunk of a chunked body. `Ok(None)` is the
/// terminal chunk. Trailer sections are not supported (the server
/// never sends them).
pub fn read_chunk<R: BufRead>(r: &mut R) -> crate::Result<Option<Vec<u8>>> {
    let line = read_line(r)?
        .ok_or_else(|| anyhow!("connection closed before chunk size"))?;
    let size = usize::from_str_radix(line.trim(), 16)
        .with_context(|| format!("bad chunk size {line:?}"))?;
    ensure!(size <= MAX_BODY_BYTES, "chunk of {size} bytes exceeds cap");
    if size == 0 {
        // consume the blank line after the terminal chunk
        let _ = read_line(r)?;
        return Ok(None);
    }
    let mut data = vec![0u8; size];
    r.read_exact(&mut data)
        .map_err(|e| anyhow!("reading {size}-byte chunk: {e}"))?;
    let blank = read_line(r)?
        .ok_or_else(|| anyhow!("connection closed after chunk"))?;
    ensure!(blank.is_empty(), "missing CRLF after chunk");
    Ok(Some(data))
}

/// Client side: read a response's status line and headers, leaving the
/// body unread — the hook for latency-measuring clients that need a
/// timestamp per [`read_chunk`] (the traffic bench's TTFT/ITL probes).
pub fn read_response_head<R: BufRead>(
    r: &mut R,
) -> crate::Result<(u16, Vec<(String, String)>)> {
    let line = read_line(r)?
        .ok_or_else(|| anyhow!("connection closed before status line"))?;
    let mut parts = line.split_whitespace();
    let (version, status) = match (parts.next(), parts.next()) {
        (Some(v), Some(s)) => (v, s),
        _ => anyhow::bail!("malformed status line {line:?}"),
    };
    ensure!(
        version.starts_with("HTTP/1."),
        "unsupported HTTP version {version:?}"
    );
    let status: u16 = status
        .parse()
        .with_context(|| format!("bad status code {status:?}"))?;
    Ok((status, read_headers(r)?))
}

/// Client side: read one full response — status line, headers, and the
/// whole body (`Content-Length` or chunked, concatenated).
pub fn read_response<R: BufRead>(r: &mut R) -> crate::Result<Response> {
    let (status, headers) = read_response_head(r)?;
    let chunked = headers
        .iter()
        .any(|(n, v)| n == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
    let body = if chunked {
        let mut body = Vec::new();
        while let Some(chunk) = read_chunk(r)? {
            ensure!(
                body.len() + chunk.len() <= MAX_BODY_BYTES,
                "chunked body exceeds {MAX_BODY_BYTES} bytes"
            );
            body.extend_from_slice(&chunk);
        }
        body
    } else {
        read_sized_body(r, &headers)?
    };
    Ok(Response { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_request_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\n\
                    Content-Type: application/json\r\nContent-Length: 2\r\n\
                    \r\n{}GET /next HTTP/1.1\r\n\r\n";
        let mut r = Cursor::new(&raw[..]);
        let req = read_request(&mut r).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"{}");
        // pipelined second request parses from where the body ended
        let next = read_request(&mut r).unwrap().unwrap();
        assert_eq!((next.method.as_str(), next.path.as_str()), ("GET", "/next"));
        assert!(next.body.is_empty());
        // clean EOF is None, not an error
        assert!(read_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn rejects_malformed_and_oversized_requests() {
        let cases: &[&[u8]] = &[
            b"GARBAGE\r\n\r\n",
            b"GET / HTTP/2\r\n\r\n",
            b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n",
            b"GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n",
            b"GET / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n",
            b"GET / HTTP/1.1\r\nContent-Length: 5\r\n\r\nab", // truncated
        ];
        for raw in cases {
            let mut r = Cursor::new(&raw[..]);
            assert!(read_request(&mut r).is_err(), "{:?}", &raw[..20]);
        }
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_LINE_BYTES));
        assert!(read_request(&mut Cursor::new(long.as_bytes())).is_err());
    }

    #[test]
    fn response_roundtrips_fixed_and_chunked() {
        // fixed-length
        let mut wire = Vec::new();
        write_response(
            &mut wire,
            200,
            "OK",
            "application/json",
            b"{\"a\":1}",
            false,
        )
        .unwrap();
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.header("connection"), Some("close"));
        assert_eq!(resp.body, b"{\"a\":1}");
        // keep-alive responses advertise it
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "OK", "application/json", b"{}", true)
            .unwrap();
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.header("connection"), Some("keep-alive"));
        // chunked: three chunks concatenate, and the incremental reader
        // sees each chunk separately (what the bench timestamps)
        let mut wire = Vec::new();
        let mut cw = ChunkWriter::start(
            &mut wire,
            200,
            "OK",
            "text/event-stream",
            true,
        )
        .unwrap();
        cw.chunk(b"data: 1\n\n").unwrap();
        cw.chunk(b"").unwrap(); // skipped, not terminal
        cw.chunk(b"data: 2\n\n").unwrap();
        cw.end().unwrap();
        let resp = read_response(&mut Cursor::new(&wire)).unwrap();
        assert_eq!(resp.body, b"data: 1\n\ndata: 2\n\n");
        let mut r = Cursor::new(&wire);
        let _head = read_response_head_for_test(&mut r);
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"data: 1\n\n");
        assert_eq!(read_chunk(&mut r).unwrap().unwrap(), b"data: 2\n\n");
        assert!(read_chunk(&mut r).unwrap().is_none());
    }

    /// Consume status line + headers, leaving the body for read_chunk.
    fn read_response_head_for_test<R: BufRead>(r: &mut R) {
        loop {
            let line = read_line(r).unwrap().unwrap();
            if line.is_empty() {
                return;
            }
        }
    }

    #[test]
    fn client_request_parses_back() {
        let mut wire = Vec::new();
        write_request(
            &mut wire,
            "POST",
            "/v1/completions",
            b"{\"p\":1}",
            true,
        )
        .unwrap();
        let req =
            read_request(&mut Cursor::new(&wire)).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"p\":1}");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to persistent");
        let mut wire = Vec::new();
        write_request(&mut wire, "GET", "/stats", b"", false).unwrap();
        let req =
            read_request(&mut Cursor::new(&wire)).unwrap().unwrap();
        assert!(!req.keep_alive(), "Connection: close honored");
    }

    #[test]
    fn keep_alive_follows_version_defaults_and_overrides() {
        let parse = |raw: &[u8]| {
            read_request(&mut Cursor::new(raw)).unwrap().unwrap()
        };
        assert!(parse(b"GET / HTTP/1.1\r\n\r\n").keep_alive());
        assert!(!parse(b"GET / HTTP/1.0\r\n\r\n").keep_alive());
        assert!(!parse(
            b"GET / HTTP/1.1\r\nConnection: Close\r\n\r\n"
        )
        .keep_alive());
        assert!(parse(
            b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n"
        )
        .keep_alive());
    }
}
