//! The surrogate transformer running natively on prepacked quantized
//! weights.
//!
//! [`PackedModel::build`] prepacks every linear weight **once** under a
//! per-layer quantization assignment; `forward()` quantizes activations
//! per batch and multiplies in the packed code domain. The forward math
//! mirrors `python/compile/model.py` exactly (embed + learned pos,
//! pre-LN blocks, full-precision attention and head per paper App. A,
//! per-tensor γ gains folded around every quantized linear).
//!
//! # Execution paths (decided per layer at build time)
//!
//! * **Packed** — minifloat elements, activations quantized, no eq. 11
//!   per-tensor scaling, contraction dim block-aligned: activations
//!   encode to a [`GemmOperand`] per batch and multiply through
//!   [`PackedGemm`] against the cached weight operand. Bit-identical to
//!   the reference path by the engine's exactness contract (DESIGN.md
//!   §8) — which the serve property suite re-pins end to end.
//! * **Reference** — INT elements, per-tensor "-S" scaling, or
//!   weight-only quantization: the prepacked weights are the scalar
//!   fake-quant of the transposed tensor, and the GEMM is the f32
//!   [`matmul_t`] reference.
//! * **Exact** — quantization off for this layer (`bf16-exact`):
//!   plain f32 GEMM on stored transposed weights.
//!
//! Set `MICROSCALE_SERVE=reference` to force every layer onto the
//! reference path when bisecting a discrepancy.
//!
//! # Batching invariance
//!
//! A request's logits never depend on its co-batched neighbors: token
//! embedding, LN, GELU and the residual stream are per-position;
//! attention and softmax are per-sequence; GEMM outputs are per-row
//! with a fixed accumulation order; block quantization of activations
//! is per-row (blocks never span rows in the [`GemmOperand`] layout);
//! and the one batch-global statistic in the system — the eq. 11
//! per-tensor absmax — is deliberately computed per *sequence*
//! ([`quantize_acts_by_sequence`]). `rust/tests/serve.rs` pins the
//! guarantee by re-batching the same request among different neighbors.

use std::sync::Arc;

use anyhow::ensure;

use crate::formats::ElemFormat;
use crate::model::weights::Params;
use crate::quant::gemm::{GemmOperand, PackedGemm};
use crate::quant::matmul::{matmul_t, transpose};
use crate::quant::{QuantKernel, QuantScheme, ScalarKernel};
use crate::runtime::artifacts::ModelDims;
use crate::runtime::qconfig::{PerLayerQConfig, QConfig};

use super::cache::OperandCache;

/// How one linear layer executes at serve time.
enum LinearPath {
    /// Quantization off: plain f32 GEMM on stored transposed weights.
    Exact { wt: Vec<f32> },
    /// Code-domain path: prepacked weight operand (shared through the
    /// [`OperandCache`]), activations quantized per batch.
    Packed { op: Arc<GemmOperand> },
    /// Scalar fake-quant fallback: prepacked fake-quantized transposed
    /// weights + f32 reference GEMM.
    Reference { wt_q: Vec<f32> },
}

/// One prepacked linear (`y = x @ w`, weights stored transposed).
struct Linear {
    path: LinearPath,
    cfg: QConfig,
    /// `Some` whenever quantization is on for this layer.
    scheme: Option<QuantScheme>,
    k: usize,
    n: usize,
}

impl Linear {
    fn build(
        cfg: &QConfig,
        block_size: usize,
        w: &[f32],
        k: usize,
        n: usize,
        cache: &OperandCache,
    ) -> crate::Result<Linear> {
        if !cfg.quant_on {
            return Ok(Linear {
                path: LinearPath::Exact { wt: transpose(w, k, n) },
                cfg: *cfg,
                scheme: None,
                k,
                n,
            });
        }
        let scheme = cfg.scheme(block_size);
        let forced_ref =
            std::env::var("MICROSCALE_SERVE").as_deref() == Ok("reference");
        // the packed engine is used only where it is provably
        // bit-identical to the reference (minifloat elements, no eq. 11
        // pre-scaling, both operands quantized, aligned contraction)
        let packed_ok = !forced_ref
            && cfg.act_quant
            && !scheme.per_tensor
            && matches!(scheme.elem, ElemFormat::Fp(_))
            && k % scheme.block_size == 0;
        let path = if packed_ok {
            LinearPath::Packed {
                op: cache.get_or_pack_transposed(&scheme, w, k, n)?,
            }
        } else {
            LinearPath::Reference {
                wt_q: ScalarKernel.fake_quant(&scheme, &transpose(w, k, n)),
            }
        };
        Ok(Linear { path, cfg: *cfg, scheme: Some(scheme), k, n })
    }

    /// `x` is row-major `rows × k` (rows = batch·seq); returns
    /// `rows × n`. `seq` bounds the per-sequence quantization chunks.
    fn apply(
        &self,
        x: &[f32],
        rows: usize,
        seq: usize,
        gemm: &PackedGemm,
    ) -> crate::Result<Vec<f32>> {
        debug_assert_eq!(x.len(), rows * self.k);
        match &self.path {
            LinearPath::Exact { wt } => {
                Ok(matmul_t(x, wt, rows, self.k, self.n))
            }
            LinearPath::Packed { op } => {
                let scheme = self.scheme.as_ref().unwrap();
                let xo = GemmOperand::quantize(scheme, x, rows, self.k)?;
                gemm.matmul(&xo, op)
            }
            LinearPath::Reference { wt_q } => {
                let scheme = self.scheme.as_ref().unwrap();
                if self.cfg.act_quant {
                    let xq = quantize_acts_by_sequence(
                        scheme, x, rows, seq, self.k,
                    );
                    Ok(matmul_t(&xq, wt_q, rows, self.k, self.n))
                } else {
                    Ok(matmul_t(x, wt_q, rows, self.k, self.n))
                }
            }
        }
    }
}

/// Counts of layers on each execution path (build diagnostics).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PathSummary {
    pub exact: usize,
    pub packed: usize,
    pub reference: usize,
}

/// The prepacked surrogate transformer (see module docs).
pub struct PackedModel {
    dims: ModelDims,
    qcfg: PerLayerQConfig,
    block_size: usize,
    gemm: PackedGemm,
    embed: Vec<f32>,
    pos: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    gains: Vec<f32>,
    /// Transposed unquantized head, `(vocab, d_model)` (paper App. A).
    head_t: Vec<f32>,
    /// `n_layers × 6` linears in [`Params::QUANTIZED`] order.
    linears: Vec<Linear>,
}

/// Contraction/output dims of quantized linear `which`
/// ([`Params::QUANTIZED`] order: wq wk wv wo w1 w2).
fn linear_dims(dims: &ModelDims, which: usize) -> (usize, usize) {
    let (d, f) = (dims.d_model, dims.d_ff);
    match which {
        4 => (d, f), // w1
        5 => (f, d), // w2
        _ => (d, d), // wq wk wv wo
    }
}

impl PackedModel {
    /// Prepack `params` under the per-layer config. Every linear weight
    /// encodes exactly once; packed operands are shared through `cache`,
    /// so sessions over the same (tensor, qconfig) pairs reuse one
    /// encode.
    pub fn build(
        dims: &ModelDims,
        params: &Params,
        qcfg: &PerLayerQConfig,
        block_size: usize,
        cache: &OperandCache,
    ) -> crate::Result<PackedModel> {
        ensure!(block_size > 0, "block size must be positive");
        ensure!(
            dims.n_heads > 0 && dims.d_model % dims.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            dims.d_model,
            dims.n_heads
        );
        ensure!(
            dims.d_model % block_size == 0 && dims.d_ff % block_size == 0,
            "block size {block_size} must divide d_model {} and d_ff {}",
            dims.d_model,
            dims.d_ff
        );
        let (l, d, f, v, s) =
            (dims.n_layers, dims.d_model, dims.d_ff, dims.vocab, dims.seq_len);
        let get = |name: &str, want: usize| -> crate::Result<Vec<f32>> {
            let (_, data) = params.get(name)?;
            ensure!(
                data.len() == want,
                "tensor {name}: {} elements, want {want}",
                data.len()
            );
            Ok(data.to_vec())
        };
        let head = get("head", d * v)?;
        let mut linears = Vec::with_capacity(l * 6);
        for layer in 0..l {
            let cfg = qcfg.layer(layer);
            for (which, name) in Params::QUANTIZED.iter().enumerate() {
                let (kd, nd) = linear_dims(dims, which);
                let (_, data) = params.get(name)?;
                let per = kd * nd;
                ensure!(
                    data.len() == l * per,
                    "tensor {name}: {} elements, want {l}x{per}",
                    data.len()
                );
                let w = &data[layer * per..(layer + 1) * per];
                linears.push(Linear::build(
                    &cfg, block_size, w, kd, nd, cache,
                )?);
            }
        }
        Ok(PackedModel {
            dims: *dims,
            qcfg: qcfg.clone(),
            block_size,
            gemm: PackedGemm::auto(),
            embed: get("embed", v * d)?,
            pos: get("pos", s * d)?,
            ln1_g: get("ln1_g", l * d)?,
            ln1_b: get("ln1_b", l * d)?,
            ln2_g: get("ln2_g", l * d)?,
            ln2_b: get("ln2_b", l * d)?,
            lnf_g: get("lnf_g", d)?,
            lnf_b: get("lnf_b", d)?,
            gains: get("gains", l * 6)?,
            head_t: transpose(&head, d, v),
            linears,
        })
    }

    /// Override the GEMM engine configuration (benches pin
    /// [`PackedGemm::serial`] for the single-thread baseline).
    pub fn with_gemm(mut self, gemm: PackedGemm) -> PackedModel {
        self.gemm = gemm;
        self
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    pub fn qcfg(&self) -> &PerLayerQConfig {
        &self.qcfg
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// How many linears landed on each execution path.
    pub fn path_summary(&self) -> PathSummary {
        let mut s = PathSummary::default();
        for lin in &self.linears {
            match lin.path {
                LinearPath::Exact { .. } => s.exact += 1,
                LinearPath::Packed { .. } => s.packed += 1,
                LinearPath::Reference { .. } => s.reference += 1,
            }
        }
        s
    }

    /// Total prepacked wire bytes across the packed-path weights.
    pub fn packed_weight_bytes(&self) -> usize {
        self.linears
            .iter()
            .map(|lin| match &lin.path {
                LinearPath::Packed { op } => op.payload_bytes(),
                _ => 0,
            })
            .sum()
    }

    /// Logits (`batch · seq · vocab`, row-major) for `batch` sequences
    /// of `seq` tokens each (`tokens.len() == batch · seq`,
    /// `1 <= seq <= dims.seq_len`).
    pub fn forward(
        &self,
        tokens: &[i32],
        batch: usize,
        seq: usize,
    ) -> crate::Result<Vec<f32>> {
        let ctx = self.ctx();
        forward_core(&ctx, tokens, batch, seq, |layer, which, x, rows| {
            self.linears[layer * 6 + which].apply(x, rows, seq, &self.gemm)
        })
    }

    fn ctx(&self) -> Ctx<'_> {
        Ctx {
            dims: &self.dims,
            embed: &self.embed,
            pos: &self.pos,
            ln1_g: &self.ln1_g,
            ln1_b: &self.ln1_b,
            ln2_g: &self.ln2_g,
            ln2_b: &self.ln2_b,
            lnf_g: &self.lnf_g,
            lnf_b: &self.lnf_b,
            gains: &self.gains,
            head_t: &self.head_t,
        }
    }
}

/// The non-GEMM tensors a forward pass reads — shared verbatim between
/// [`PackedModel::forward`] and [`reference_forward`] so bit-exactness
/// of the whole pass reduces to bit-exactness of the linears.
struct Ctx<'a> {
    dims: &'a ModelDims,
    embed: &'a [f32],
    pos: &'a [f32],
    ln1_g: &'a [f32],
    ln1_b: &'a [f32],
    ln2_g: &'a [f32],
    ln2_b: &'a [f32],
    lnf_g: &'a [f32],
    lnf_b: &'a [f32],
    gains: &'a [f32],
    head_t: &'a [f32],
}

/// The scalar fake-quant reference forward: identical math to
/// [`PackedModel::forward`] with every linear on the
/// [`ScalarKernel`]-quantized f32 path, recomputed from raw `params` on
/// each call (no prepacking, no packed engine anywhere). The serve test
/// suite pins the packed model bit-identical to this.
pub fn reference_forward(
    params: &Params,
    dims: &ModelDims,
    qcfg: &PerLayerQConfig,
    block_size: usize,
    tokens: &[i32],
    batch: usize,
    seq: usize,
) -> crate::Result<Vec<f32>> {
    let (d, v) = (dims.d_model, dims.vocab);
    let head_t = transpose(params.get("head")?.1, d, v);
    let ctx = Ctx {
        dims,
        embed: params.get("embed")?.1,
        pos: params.get("pos")?.1,
        ln1_g: params.get("ln1_g")?.1,
        ln1_b: params.get("ln1_b")?.1,
        ln2_g: params.get("ln2_g")?.1,
        ln2_b: params.get("ln2_b")?.1,
        lnf_g: params.get("lnf_g")?.1,
        lnf_b: params.get("lnf_b")?.1,
        gains: params.get("gains")?.1,
        head_t: &head_t,
    };
    forward_core(&ctx, tokens, batch, seq, |layer, which, x, rows| {
        let cfg = qcfg.layer(layer);
        let (kd, nd) = linear_dims(dims, which);
        let data = params.get(Params::QUANTIZED[which])?.1;
        let w = &data[layer * kd * nd..(layer + 1) * kd * nd];
        let wt = transpose(w, kd, nd);
        if !cfg.quant_on {
            return Ok(matmul_t(x, &wt, rows, kd, nd));
        }
        let scheme = cfg.scheme(block_size);
        let wt_q = ScalarKernel.fake_quant(&scheme, &wt);
        if cfg.act_quant {
            let xq = quantize_acts_by_sequence(&scheme, x, rows, seq, kd);
            Ok(matmul_t(&xq, &wt_q, rows, kd, nd))
        } else {
            Ok(matmul_t(x, &wt_q, rows, kd, nd))
        }
    })
}

/// Fake-quantize a `rows × k` activation matrix one sequence at a time
/// (`seq` rows per chunk). For per-tensor "-S" schemes the eq. 11
/// absmax then spans a single request, never its co-batched neighbors —
/// the batching-invariance guarantee. For plain block schemes
/// (`k % bs == 0`, blocks within rows) chunking changes nothing.
fn quantize_acts_by_sequence(
    scheme: &QuantScheme,
    x: &[f32],
    rows: usize,
    seq: usize,
    k: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(rows % seq.max(1), 0);
    let mut out = x.to_vec();
    for chunk in out.chunks_mut(seq.max(1) * k) {
        crate::quant::fake_quant_into(scheme, chunk);
    }
    out
}

/// The shared forward skeleton: everything except the quantized linears,
/// which are injected as `linear(layer, which, x, rows) -> rows × n`.
fn forward_core<L>(
    ctx: &Ctx,
    tokens: &[i32],
    batch: usize,
    seq: usize,
    mut linear: L,
) -> crate::Result<Vec<f32>>
where
    L: FnMut(usize, usize, &[f32], usize) -> crate::Result<Vec<f32>>,
{
    let dims = ctx.dims;
    let (d, v, nh) = (dims.d_model, dims.vocab, dims.n_heads);
    let hd = d / nh;
    ensure!(batch > 0, "empty batch");
    ensure!(
        seq >= 1 && seq <= dims.seq_len,
        "sequence length {seq} out of range 1..={}",
        dims.seq_len
    );
    ensure!(
        tokens.len() == batch * seq,
        "token count {} != batch {batch} x seq {seq}",
        tokens.len()
    );
    for &t in tokens {
        ensure!(
            t >= 0 && (t as usize) < v,
            "token {t} out of vocab range 0..{v}"
        );
    }
    let rows = batch * seq;

    // x = embed[tokens] + pos[:seq]
    let mut x = vec![0.0f32; rows * d];
    for r in 0..rows {
        let tok = tokens[r] as usize;
        let p = r % seq;
        let e = &ctx.embed[tok * d..(tok + 1) * d];
        let pp = &ctx.pos[p * d..(p + 1) * d];
        let xr = &mut x[r * d..(r + 1) * d];
        for c in 0..d {
            xr[c] = e[c] + pp[c];
        }
    }

    let att_scale = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0.0f32; seq];
    for layer in 0..dims.n_layers {
        let g = &ctx.gains[layer * 6..(layer + 1) * 6];
        let h1 = layer_norm(
            &x,
            &ctx.ln1_g[layer * d..(layer + 1) * d],
            &ctx.ln1_b[layer * d..(layer + 1) * d],
            d,
        );
        let q = scaled(linear(layer, 0, &h1, rows)?, g[0]);
        let ky = scaled(linear(layer, 1, &h1, rows)?, g[1]);
        let vv = scaled(linear(layer, 2, &h1, rows)?, g[2]);

        // causal attention, full precision (paper App. A)
        let mut o = vec![0.0f32; rows * d];
        for b in 0..batch {
            for head in 0..nh {
                let c0 = head * hd;
                for i in 0..seq {
                    let qi = (b * seq + i) * d + c0;
                    let mut maxv = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let kj = (b * seq + j) * d + c0;
                        let mut dot = 0.0f32;
                        for t in 0..hd {
                            dot += q[qi + t] * ky[kj + t];
                        }
                        let sc = dot * att_scale;
                        att[j] = sc;
                        if sc > maxv {
                            maxv = sc;
                        }
                    }
                    let mut denom = 0.0f32;
                    for a in att.iter_mut().take(i + 1) {
                        let e = (*a - maxv).exp();
                        *a = e;
                        denom += e;
                    }
                    for a in att.iter_mut().take(i + 1) {
                        *a /= denom;
                    }
                    let oi = (b * seq + i) * d + c0;
                    for t in 0..hd {
                        let mut acc = 0.0f32;
                        for j in 0..=i {
                            acc += att[j] * vv[(b * seq + j) * d + c0 + t];
                        }
                        o[oi + t] = acc;
                    }
                }
            }
        }

        let proj = scaled(linear(layer, 3, &o, rows)?, g[3]);
        add_into(&mut x, &proj);

        let h2 = layer_norm(
            &x,
            &ctx.ln2_g[layer * d..(layer + 1) * d],
            &ctx.ln2_b[layer * d..(layer + 1) * d],
            d,
        );
        let mut mid = scaled(linear(layer, 4, &h2, rows)?, g[4]);
        for m in mid.iter_mut() {
            *m = gelu(*m);
        }
        let proj2 = scaled(linear(layer, 5, &mid, rows)?, g[5]);
        add_into(&mut x, &proj2);
    }

    let xf = layer_norm(&x, ctx.lnf_g, ctx.lnf_b, d);
    // the model head is NOT quantized (paper App. A)
    Ok(matmul_t(&xf, ctx.head_t, rows, d, v))
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let rows = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mut mu = 0.0f32;
        for &v in xr {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0.0f32;
        for &v in xr {
            let dv = v - mu;
            var += dv * dv;
        }
        var /= d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for c in 0..d {
            or[c] = (xr[c] - mu) * inv * g[c] + b[c];
        }
    }
    out
}

/// tanh-approximation GELU (the `jax.nn.gelu` default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn scaled(mut y: Vec<f32>, gain: f32) -> Vec<f32> {
    if gain != 1.0 {
        for v in y.iter_mut() {
            *v *= gain;
        }
    }
    y
}

fn add_into(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Pcg64;
    use crate::serve::cache::OperandCache;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            vocab: 32,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            seq_len: 8,
        }
    }

    fn tokens(rng: &mut Pcg64, dims: &ModelDims, rows: usize) -> Vec<i32> {
        (0..rows)
            .map(|_| (rng.next_u64() % dims.vocab as u64) as i32)
            .collect()
    }

    #[test]
    fn packed_forward_matches_reference_smoke() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 11);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        assert_eq!(model.path_summary().packed, 2 * 6);
        assert!(model.packed_weight_bytes() > 0);
        let mut rng = Pcg64::new(12);
        let toks = tokens(&mut rng, &dims, 2 * dims.seq_len);
        let got = model.forward(&toks, 2, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            2,
            dims.seq_len,
        )
        .unwrap();
        assert_eq!(got.len(), 2 * dims.seq_len * dims.vocab);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: {a} vs {b}");
        }
    }

    #[test]
    fn baseline_config_bypasses_quantization() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 13);
        let cache = OperandCache::new(8);
        let qcfg = PerLayerQConfig::uniform(QConfig::baseline());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let s = model.path_summary();
        assert_eq!((s.exact, s.packed, s.reference), (12, 0, 0));
        // no operands were packed for exact layers
        assert_eq!(cache.stats().misses, 0);
    }

    #[test]
    fn mixed_layers_take_their_own_paths() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 14);
        let cache = OperandCache::new(32);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue5m3").unwrap())
            .with_override(
                1,
                QConfig::named("int4", "ue4m3", false).unwrap(),
            );
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        let s = model.path_summary();
        // layer 0: packed FP4; layer 1: INT4 -> reference
        assert_eq!((s.exact, s.packed, s.reference), (0, 6, 6));
        let mut rng = Pcg64::new(15);
        let toks = tokens(&mut rng, &dims, dims.seq_len);
        let got = model.forward(&toks, 1, dims.seq_len).unwrap();
        let want = reference_forward(
            &params,
            &dims,
            &qcfg,
            8,
            &toks,
            1,
            dims.seq_len,
        )
        .unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn forward_validates_inputs() {
        let dims = tiny_dims();
        let params = Params::init_surrogate(&dims, 16);
        let cache = OperandCache::new(8);
        let qcfg = PerLayerQConfig::uniform(QConfig::fp4("ue4m3").unwrap());
        let model =
            PackedModel::build(&dims, &params, &qcfg, 8, &cache).unwrap();
        // token out of range
        assert!(model.forward(&[99; 8], 1, 8).is_err());
        // wrong token count
        assert!(model.forward(&[0; 7], 1, 8).is_err());
        // seq too long
        assert!(model.forward(&[0; 16], 1, 16).is_err());
        // short sequences are fine
        assert!(model.forward(&[0; 4], 1, 4).is_ok());
        // misaligned block size refused at build
        assert!(
            PackedModel::build(&dims, &params, &qcfg, 24, &cache).is_err()
        );
    }
}
